"""Paper Table 3 (supplementary): unbiased vs min vs median estimators.

The paper finds: unbiased best overall; median close (better on
ImageNet); min worst.  We reproduce the ranking on the synthetic task
with one trained model, evaluating all three estimators on the same
meta-probabilities.
"""

from __future__ import annotations

import jax

from benchmarks.common import accuracy, make_dataset, train_linear
from repro.core import MACHConfig, MACHLinear


def run(report) -> None:
    K, D = 1024, 256
    ds = make_dataset(K, D)
    cfg = MACHConfig(K, 32, 8)
    m = MACHLinear(cfg, D)
    params, _ = train_linear(ds, m, m.init(jax.random.key(0)))
    accs = {}
    for est in ("unbiased", "min", "median"):
        accs[est] = accuracy(ds, lambda x, e=est: m.predict(params, x,
                                                            estimator=e))
        report(f"table3/{est}", 0.0, f"acc={accs[est]:.4f}")
    ranking_ok = (accs["unbiased"] >= accs["min"] - 0.02)
    report("table3/ranking", 0.0,
           f"unbiased_beats_min={ranking_ok} "
           f"(paper: unbiased {15.446} vs min {12.212} on ODP)")
