"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MACHConfig, MACHLinear, OAAClassifier
from repro.data import ExtremeDataConfig, ExtremeDataset
from repro.optim import adamw, apply_updates


def intermediate_avals(jaxpr, skip_primitives=("pallas_call",)):
    """All avals produced by a jaxpr's equations, recursing into
    sub-jaxprs (jit, custom_vjp, scan, ...) but not into Pallas kernels
    — their tiles are VMEM-resident, not HBM.  Shared by the memory
    accounting in bench_train_xent and the no-(N, R·B)-tensor test."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name not in skip_primitives:
            for sub in jax.core.jaxprs_in_params(eqn.params):
                out.extend(intermediate_avals(sub, skip_primitives))
        out.extend(v.aval for v in eqn.outvars)
    return out


def timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def train_linear(ds: ExtremeDataset, model, params, steps: int = 150,
                 lr: float = 0.05, bs: int = 512):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    t0 = time.perf_counter()
    for s in range(steps):
        x, y = ds.batch_at(s, bs)
        params, state, _ = step(params, state, x, y)
    jax.block_until_ready(params)
    return params, time.perf_counter() - t0


def accuracy(ds: ExtremeDataset, predict_fn, steps: int = 4,
             bs: int = 512) -> float:
    accs = []
    for s in range(steps):
        x, y = ds.batch_at(2000 + s, bs, "test")
        accs.append(float(jnp.mean(predict_fn(x) == y)))
    return float(np.mean(accs))


def make_dataset(num_classes: int = 1024, dim: int = 256,
                 noise: float = 0.1) -> ExtremeDataset:
    return ExtremeDataset(ExtremeDataConfig(num_classes=num_classes,
                                            dim=dim, noise=noise,
                                            zipf_a=0.0))
