"""Shared benchmark utilities."""

from __future__ import annotations

import json
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MACHConfig, MACHLinear, OAAClassifier
from repro.data import ExtremeDataConfig, ExtremeDataset
from repro.optim import adamw, apply_updates


def intermediate_avals(jaxpr, skip_primitives=("pallas_call",)):
    """All avals produced by a jaxpr's equations, recursing into
    sub-jaxprs (jit, custom_vjp, scan, ...) but not into Pallas kernels
    — their tiles are VMEM-resident, not HBM.  Shared by the memory
    accounting in bench_train_xent and the no-(N, R·B)-tensor test."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name not in skip_primitives:
            for sub in jax.core.jaxprs_in_params(eqn.params):
                out.extend(intermediate_avals(sub, skip_primitives))
        out.extend(v.aval for v in eqn.outvars)
    return out


def make_dense_case(n, d, r, b, seed=0, dtype=jnp.float32):
    """Shared dense fused-xent fixture — the case maker behind the
    bench parity gate (bench_train_xent) and tests/test_fused_xent.py,
    so both validate on the same input distribution.

    Returns (h (n, d), w (d, R·B), bias (R·B,), y (n, R), g (n,)):
    h/w/bias in ``dtype``, labels int32 bucket ids, cotangent g f32."""
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.key(seed + n + r), 5)
    h = (jax.random.normal(k1, (n, d)) / np.sqrt(d)).astype(dtype)
    w = (jax.random.normal(k2, (d, r * b)) / np.sqrt(d)).astype(dtype)
    y = jax.random.randint(k3, (n, r), 0, b)
    g = jax.random.normal(k4, (n,))
    bias = (jax.random.normal(k5, (r * b,)) * 0.1).astype(dtype)
    return h, w, bias, y, g


def make_csr_case(n, d, r, b, nnz_max, seed=0, dtype=jnp.float32,
                  ragged=True):
    """Ragged-row CSR batch + MACH head operands — the shared fixture
    behind the sparse-xent parity gate (bench_sparse_xent) and the
    kernel tests, so both validate on the same input distribution.

    Returns (indptr, indices, values, w, bias, y, g): row lengths in
    [1, nnz_max] (or exactly nnz_max when ragged=False), feature ids in
    [0, d), values/w in ``dtype``, bias (R·B,) f32, labels (n, R),
    cotangent g (n,)."""
    rng = np.random.default_rng(seed + n + d)
    row_len = (rng.integers(1, nnz_max + 1, n) if ragged
               else np.full(n, nnz_max))
    indptr = jnp.asarray(np.concatenate([[0], np.cumsum(row_len)]),
                         jnp.int32)
    nnz = int(indptr[-1])
    indices = jnp.asarray(rng.integers(0, d, nnz), jnp.int32)
    values = jnp.asarray(rng.normal(size=nnz) / np.sqrt(nnz_max), dtype)
    w = jnp.asarray(rng.normal(size=(d, r * b)) / np.sqrt(nnz_max), dtype)
    bias = jnp.asarray(rng.normal(size=r * b) * 0.1, jnp.float32)
    y = jnp.asarray(rng.integers(0, b, (n, r)), jnp.int32)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    return indptr, indices, values, w, bias, y, g


def load_committed_bench(path: str):
    """The last *committed* version of a BENCH_*.json (via ``git show
    HEAD:path``), or None when the file is untracked / unparsable.
    The regression gate compares fresh numbers against this, so the
    perf trajectory is measured against what the repo actually records,
    not against a possibly-dirty working tree."""
    try:
        out = subprocess.run(["git", "show", f"HEAD:{path}"],
                             capture_output=True, text=True, check=True)
        return json.loads(out.stdout)
    except (subprocess.CalledProcessError, FileNotFoundError,
            json.JSONDecodeError):
        return None


def flatten_bench_times(doc, prefix: str = "") -> dict:
    """All positive ``us_*`` leaves of a BENCH json, keyed by their
    path (dict keys / list indices joined with '.')."""
    out = {}
    if isinstance(doc, dict):
        for key, v in doc.items():
            if isinstance(v, (dict, list)):
                out.update(flatten_bench_times(v, f"{prefix}{key}."))
            elif (isinstance(v, (int, float)) and not isinstance(v, bool)
                  and key.startswith("us_") and v > 0):
                out[f"{prefix}{key}"] = float(v)
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            out.update(flatten_bench_times(v, f"{prefix}{i}."))
    return out


def bench_regression(old_doc, new_doc, fail_ratio: float = 1.25):
    """Regression delta between two BENCH jsons.

    Returns (median_ratio, per_key_ratios, ok): the per-key new/old
    ratios of every ``us_*`` field present in both documents, their
    median (the window statistic — a single noisy config can't fail the
    gate, a broad slowdown does), and ok = median <= fail_ratio.
    (None, {}, True) when there is nothing to compare.
    """
    old = flatten_bench_times(old_doc) if old_doc else {}
    new = flatten_bench_times(new_doc) if new_doc else {}
    ratios = {key: new[key] / old[key] for key in sorted(old) if key in new}
    if not ratios:
        return None, {}, True
    med = float(np.median(list(ratios.values())))
    return med, ratios, med <= fail_ratio


def timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def train_linear(ds: ExtremeDataset, model, params, steps: int = 150,
                 lr: float = 0.05, bs: int = 512):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    t0 = time.perf_counter()
    for s in range(steps):
        x, y = ds.batch_at(s, bs)
        params, state, _ = step(params, state, x, y)
    jax.block_until_ready(params)
    return params, time.perf_counter() - t0


def accuracy(ds: ExtremeDataset, predict_fn, steps: int = 4,
             bs: int = 512) -> float:
    accs = []
    for s in range(steps):
        x, y = ds.batch_at(2000 + s, bs, "test")
        accs.append(float(jnp.mean(predict_fn(x) == y)))
    return float(np.mean(accs))


def make_dataset(num_classes: int = 1024, dim: int = 256,
                 noise: float = 0.1) -> ExtremeDataset:
    return ExtremeDataset(ExtremeDataConfig(num_classes=num_classes,
                                            dim=dim, noise=noise,
                                            zipf_a=0.0))
