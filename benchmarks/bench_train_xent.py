"""Training-loss hot path: fused projection+CE vs materialized logits.

Sweeps (N, d, R, B) — N = B·T flattened tokens — and records, per config:

  * ``us_materialized`` — value_and_grad of the materializing path
                  (``head matmul → (N, R·B) logits → mach_xent``), i.e.
                  what ``model.loss`` runs with ``mach_fused_loss=False``.
  * ``us_fused``  — value_and_grad of ``ops.mach_fused_xent`` as
                  dispatched on this backend.  On TPU that is the fused
                  Pallas kernel; on CPU the dispatcher falls back to the
                  same materializing reference math, so the two columns
                  coincide — ``fused_is_kernel`` records which one ran.
  * ``peak_act_bytes_*`` — the largest *activation* in the jaxpr of
                  each path's forward+backward: intermediates carrying
                  the batch dimension (leading dim in [N, N+block)).
                  Parameter-shaped intermediates (the padded W, dW) are
                  parameter/gradient memory — the paper's O(d log K)
                  budget — and Pallas kernel internals are VMEM tiles;
                  both are excluded.  The structural claim: the
                  materialized path peaks at the N·R·B·4-byte logits
                  tensor, the fused path's peak is h/dh-sized —
                  independent of R·B.
  * ``has_nrb_tensor_*`` — whether any batch-carrying intermediate of
                  ≥ N·R·B elements exists in the pass.
  * ``parity_max_abs_err`` / ``grad_allclose`` — interpret-mode kernel
                  vs reference on this config (loss |Δ| and dh/dW/dbias
                  at rtol 1e-4): the PR's acceptance gate, checked on
                  every sweep entry (``--quick`` skips the largest).

The **d-sweep gate** (ISSUE 4): for d ∈ {1k, 4k, 12k} at the
mistral-large-scale head (R=32, B=512), ``choose_fused_blocks`` must
yield a tiling whose accounted VMEM tile bytes (``dense_tile_bytes``)
fit the default 6 MB budget — the old lane-floor clamp silently blew
it ~2x at d=12288 — and interpret-mode parity (values + dh/dW/dbias)
must hold through the d-blocked kernels.  ``--quick`` runs the budget
accounting at every d but parity only at d=1k (interpret-mode grids at
d=12k are minutes-slow on CPU); the full run checks parity at all
three.

The **500k-label gate** (ISSUE 8): the commodity-GPU workload of
arXiv 2306.03725 — K = 500k Zipf classes over sparse bag-of-words
features (``SparseExtremeDataset``) — trained through the fused CSR
loss with and without dynamic bucket selection at ≥ 5× C-axis
reduction.  Quick mode gates the *per-step* wall-clock ratio
(selected/full < 1) and parity within the documented one-sided bias
bound (``ref.mach_selected_bias_bound_ref``); the full run also races
both paths to the full loss's bucket-accuracy target and gates
**wall-clock-to-target** (selected strictly faster).

Writes ``BENCH_xent.json`` (see ``--out``) so the train-loss perf and
memory trajectory is tracked from this PR forward.

    PYTHONPATH=src python benchmarks/bench_train_xent.py [--quick]
"""

from __future__ import annotations

BENCH_FILE = "BENCH_xent.json"        # regression-gated by benchmarks/run.py

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals, make_dense_case, timeit
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import (DEFAULT_VMEM_BUDGET,
                                           choose_fused_blocks,
                                           dense_tile_bytes,
                                           mach_fused_xent_pallas)

# (N, d, R, B): acceptance config, paper's ODP (R=25, B=32) and
# ImageNet-21k (R=20, B=512) heads, and a 32k-column ODP-scale head
# that only the fused path can train without an (N, 32k) activation.
# N != d everywhere so batch-carrying and param-shaped intermediates
# are distinguishable by leading dim in the jaxpr scan.
SWEEP = [
    (256, 128, 16, 512),       # the PR's acceptance case (R·B = 8192)
    (512, 256, 25, 32),        # ODP-like head
    (320, 256, 20, 512),       # imagenet-21k-like head
    (192, 128, 16, 2048),      # R·B = 32768: ODP-scale column count
]
QUICK_SWEEP = SWEEP[:2]

# d-sweep (ISSUE 4): LM-trunk widths at the (R=32, B=512) head.  The
# chooser is asked at N=256 (the confirmed-blowout shape); parity runs
# at N=16 — the (C/bc)·(D/bd) grid axes, which the gate exercises, are
# N-independent, and interpret mode pays per grid step.
D_SWEEP = [1024, 4096, 12288]
D_SWEEP_RB = (32, 512)

# 500k-label workload (ISSUE 8): K Zipf classes hashed to R heads of B
# buckets (C = R·B fused columns); c_sel = B/8 → 8× C-axis cut (the
# gate requires ≥ 5×).  d/nnz are the sparse bag-of-words regime the
# gather kernel exists for.  N matters: the selected path pays a
# per-step O(d·R·c_sel) W-column gather/scatter that the batch must
# amortize — N = 512 is the realistic large-batch regime (and the
# smallest power of two where the 8× matmul saving clearly dominates
# the column traffic on CPU).
EXTREME_500K = {"num_labels": 500_000, "num_buckets": 4096, "R": 8,
                "d": 1024, "nnz": 64, "N": 512, "c_sel": 512,
                "refresh_every": 10}


def _memory_model(fn, args, n: int, nrb: int) -> dict:
    """Activation accounting over the traced jaxpr: intermediates whose
    leading dim is the (possibly block-padded) batch dim N.  Kernel
    block sizes never exceed 128, so padding adds < 128 rows."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args).jaxpr)
    acts = [a for a in avals
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]
    return {"peak_act_bytes": max(a.size * a.dtype.itemsize for a in acts),
            "has_nrb_tensor": any(a.size >= nrb for a in acts)}


def _verify(h, w, bias, y, g, b, block_c=None, block_d=None
            ) -> tuple[float, bool]:
    """Interpret-mode kernel vs reference with the in-kernel bias:
    (max |Δloss|, dh/dW/dbias grads ok)."""
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, block_c, block_d,
                                True)
    loss_err = float(jnp.max(jnp.abs(lr - lk)))
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, block_c, block_d,
                               True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    grads_ok = all(
        np.allclose(np.asarray(a), np.asarray(k), rtol=1e-4, atol=1e-6)
        for a, k in zip(dr, dk))
    return loss_err, grads_ok


def _d_sweep_gate(quick: bool, report=None) -> dict:
    """ISSUE 4's acceptance gate: budget accounting at every d, parity
    through the d-blocked kernels (at N=16; the d-blocked grid axes are
    N-independent)."""
    r, b = D_SWEEP_RB
    rows = []
    for d in D_SWEEP:
        bn, bc, bd, rp, bp = choose_fused_blocks(256, d, r, b)
        acct = dense_tile_bytes(bn, bc, bd, rp)
        row = {"d": d, "R": r, "B": b, "bn": bn, "bc": bc, "bd": bd,
               "rp": rp, "tile_bytes": acct,
               "within_budget": bool(acct <= DEFAULT_VMEM_BUDGET)}
        if not quick or d == D_SWEEP[0]:
            # parity at the N=256 choice's (bc, bd) — the exact tiling
            # the budget row is about (bn tracks the smaller N)
            h, w, bias, y, g = make_dense_case(16, d, r, b)
            loss_err, grads_ok = _verify(h, w, bias, y, g, b,
                                         block_c=bc, block_d=bd)
            row["parity_max_abs_err"] = loss_err
            row["grad_allclose"] = bool(grads_ok)
        rows.append(row)
        if report:
            report(f"train_xent/d_sweep_d{d}", 0.0,
                   f"blocks=({bn},{bc},{bd}) tile_kb={acct // 1024} "
                   f"within_budget={row['within_budget']} "
                   f"parity={row.get('parity_max_abs_err', 'skipped')} "
                   f"grads_ok={row.get('grad_allclose', 'skipped')}")
    ok = all(r_["within_budget"] for r_ in rows) and all(
        r_.get("grad_allclose", True) and
        r_.get("parity_max_abs_err", 0.0) <= 1e-4
        for r_ in rows)
    return {"rows": rows, "ok": bool(ok)}


def _bench_500k(quick: bool, report=None) -> dict:
    """ISSUE 8's acceptance gate: dynamic bucket selection on the
    500k-label sparse workload.

    Quick (CI): per-step value_and_grad wall-clock, selected (cached
    proxy — the trainer's steady state) vs full, must come in < 1× at
    the ≥ 5× C-axis reduction, and the per-example gap ``full − sel``
    must be one-sided and within ``mach_selected_bias_bound_ref``.
    Full run adds the wall-clock-to-target-accuracy race: both paths
    train (adamw) from the same init until the full path's final
    bucket accuracy; selected must get there in strictly less
    accumulated train-step time."""
    import time as _time

    from repro.core.mach import MACHConfig, MACHLinear
    from repro.data.extreme import (SparseExtremeDataConfig,
                                    SparseExtremeDataset)

    p = EXTREME_500K
    k, b, r = p["num_labels"], p["num_buckets"], p["R"]
    d, nnz, n, c_sel = p["d"], p["nnz"], p["N"], p["c_sel"]
    reduction = b // c_sel
    mcfg = MACHConfig(k, b, r)
    ds = SparseExtremeDataset(SparseExtremeDataConfig(
        num_classes=k, num_features=d, nnz=nnz, sig_features=16))
    head = MACHLinear(mcfg, d, fused=True)
    params = head.init(jax.random.key(0))
    sb, y = ds.batch_at(0, n)

    # cached proxy scores — what Trainer injects between refreshes
    proxy = jax.block_until_ready(head.bucket_proxy_scores(params, sb))

    def full_vag(params_):
        return jax.value_and_grad(
            lambda pp: head.fused_loss(pp, sb, y))(params_)

    def sel_vag(params_):
        return jax.value_and_grad(lambda pp: head.fused_loss(
            pp, sb, y, bucket_select=(c_sel, p["refresh_every"]),
            bucket_proxy=proxy))(params_)

    us_full = timeit(jax.jit(full_vag), params, iters=3)
    us_sel = timeit(jax.jit(sel_vag), params, iters=3)
    step_ratio = us_sel / us_full

    # parity within the documented one-sided bias bound (per example)
    hashed = jnp.moveaxis(mcfg.hash_labels(y), 0, -1).astype(jnp.int32)
    w2 = params["w"].reshape(d, -1)
    bias = params["b"].reshape(-1)
    selected = ops.mach_select_buckets(proxy, hashed, num_buckets=b,
                                       c_sel=c_sel)
    full_nll = ops.mach_fused_xent_csr(
        sb.indptr, sb.indices, sb.values, w2, hashed, num_buckets=b,
        nnz_max=sb.nnz_max, bias=bias)
    sel_nll = ops.mach_fused_xent_csr_selected(
        sb.indptr, sb.indices, sb.values, w2, hashed, selected,
        num_buckets=b, nnz_max=sb.nnz_max, bias=bias)
    bound = ref.mach_selected_bias_bound_ref(
        sb.to_dense(), w2, hashed, selected, b, bias=bias)
    gap = np.asarray(full_nll - sel_nll)
    tol = 1e-3 * float(np.max(np.asarray(full_nll)))     # f32 at ~R·log B
    one_sided = bool(np.all(gap >= -tol))
    within_bound = bool(np.all(gap <= np.asarray(bound) + tol))

    out = {"num_labels": k, "num_buckets": b, "R": r, "C": r * b,
           "d": d, "nnz": nnz, "N": n, "c_sel": c_sel,
           "c_axis_reduction": reduction,
           "us_full_step": us_full, "us_selected_step": us_sel,
           "step_ratio": step_ratio,
           "gap_one_sided": one_sided, "gap_within_bound": within_bound,
           "max_gap": float(np.max(gap)),
           "max_bound": float(np.max(np.asarray(bound)))}
    ok = step_ratio < 1.0 and reduction >= 5 and one_sided and within_bound
    if report:
        report("train_xent/extreme500k_step", us_sel,
               f"full={us_full:.0f}us ratio={step_ratio:.2f} "
               f"reduction={reduction}x one_sided={one_sided} "
               f"within_bound={within_bound}")

    if not quick:
        # wall-clock-to-target race, same init, fresh batch per step
        from repro.optim import (apply_updates, make_optimizer,
                                 make_schedule)
        opt = make_optimizer("adamw", make_schedule("constant", value=3e-2),
                             weight_decay=0.0)
        test_sb, test_y = ds.batch_at(10_000, 128, "test")
        test_x = test_sb.to_dense()
        test_hash = jnp.moveaxis(mcfg.hash_labels(test_y), 0, -1)

        @jax.jit
        def bucket_acc(params_):
            logits = jnp.einsum("nd,drb->nrb", test_x, params_["w"]) \
                + params_["b"]
            return jnp.mean((jnp.argmax(logits, -1) == test_hash)
                            .astype(jnp.float32))

        def race(select: bool, steps: int = 30, eval_every: int = 5):
            prms = head.init(jax.random.key(0))
            ost = opt.init(prms)
            prx = None

            @jax.jit
            def step(prms_, ost_, sb_, y_, prx_):
                def lf(pp):
                    if select:
                        return head.fused_loss(
                            pp, sb_, y_,
                            bucket_select=(c_sel, p["refresh_every"]),
                            bucket_proxy=prx_)
                    return head.fused_loss(pp, sb_, y_)
                loss, g = jax.value_and_grad(lf)(prms_)
                upd, ost_ = opt.update(g, ost_, prms_)
                return apply_updates(prms_, upd), ost_, loss

            trace, spent = [], 0.0
            for s in range(steps):
                sb_, y_ = ds.batch_at(1 + s, n)
                if select and s % p["refresh_every"] == 0:
                    prx = jax.block_until_ready(
                        head.bucket_proxy_scores(prms, sb_))
                t0 = _time.perf_counter()
                prms, ost, _ = step(prms, ost, sb_, y_, prx)
                jax.block_until_ready(prms)
                if s:                       # skip the compile step
                    spent += _time.perf_counter() - t0
                if (s + 1) % eval_every == 0:
                    trace.append((spent, float(bucket_acc(prms))))
            return trace

        full_trace = race(False)
        sel_trace = race(True)
        target = full_trace[-1][1]
        t_full = next(t for t, a in full_trace if a >= target)
        t_sel = next((t for t, a in sel_trace if a >= target), None)
        race_ok = t_sel is not None and t_sel < t_full
        out["wallclock"] = {
            "target_bucket_acc": target,
            "s_full_to_target": t_full,
            "s_selected_to_target": t_sel,
            "selected_final_acc": sel_trace[-1][1],
            "ok": bool(race_ok)}
        ok = ok and race_ok
        if report:
            report("train_xent/extreme500k_wallclock", 0.0,
                   f"target_acc={target:.3f} full={t_full:.1f}s "
                   f"selected={t_sel if t_sel is None else round(t_sel, 1)}s "
                   f"ok={race_ok}")

    out["ok"] = bool(ok)
    return out


def bench(quick: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    sweep = QUICK_SWEEP if quick else SWEEP
    for (n, d, r, b) in sweep:
        h, w, bias, y, g = make_dense_case(n, d, r, b)
        nrb = n * r * b

        def mat_vag(h_, w_, bias_):
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                ref.mach_fused_xent_ref(hh, ww, y, b, bias=bb) * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        def fused_vag(h_, w_, bias_):
            # backend dispatch (kernel on TPU, reference elsewhere)
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                ops.mach_fused_xent(hh, ww, y, num_buckets=b, bias=bb)
                * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        def kernel_vag(h_, w_, bias_):
            # the kernel path regardless of backend (for the jaxpr scan)
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                mach_fused_xent_pallas(hh, ww, bb, y, b, None, None,
                                       None, True) * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        us_mat = timeit(jax.jit(mat_vag), h, w, bias, iters=5)
        us_fused = timeit(jax.jit(fused_vag), h, w, bias, iters=5)
        mem_mat = _memory_model(mat_vag, (h, w, bias), n, nrb)
        mem_fused = _memory_model(kernel_vag, (h, w, bias), n, nrb)
        loss_err, grads_ok = _verify(h, w, bias, y, g, b)

        row = {"N": n, "d": d, "R": r, "B": b, "RB": r * b,
               "us_materialized": us_mat, "us_fused": us_fused,
               "fused_is_kernel": on_tpu,
               "peak_act_bytes_materialized": mem_mat["peak_act_bytes"],
               "peak_act_bytes_fused": mem_fused["peak_act_bytes"],
               "has_nrb_tensor_materialized": mem_mat["has_nrb_tensor"],
               "has_nrb_tensor_fused": mem_fused["has_nrb_tensor"],
               "act_ratio": mem_mat["peak_act_bytes"]
               / mem_fused["peak_act_bytes"],
               "parity_max_abs_err": loss_err,
               "grad_allclose": bool(grads_ok)}
        rows.append(row)
        if report:
            report(f"train_xent/N{n}_d{d}_R{r}_B{b}", us_fused,
                   f"mat={us_mat:.0f}us act_ratio={row['act_ratio']:.1f}x "
                   f"loss_err={loss_err:.1e} grads_ok={grads_ok} "
                   f"kernel={on_tpu}")

    d_sweep = _d_sweep_gate(quick, report)
    extreme = _bench_500k(quick, report)
    verified = all(r["grad_allclose"] and r["parity_max_abs_err"] <= 1e-5
                   for r in rows)
    no_nrb = all(not r["has_nrb_tensor_fused"] for r in rows)
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified),
           "fused_free_of_nrb_tensor": bool(no_nrb),
           "d_sweep_ok": d_sweep["ok"],
           "d_sweep": d_sweep["rows"],
           "extreme_500k_ok": extreme["ok"],
           "extreme_500k": extreme,
           "configs": rows}
    if report:
        report("train_xent/verified", 0.0,
               f"interpret_match={verified} no_nrb_tensor={no_nrb} "
               f"d_sweep_ok={d_sweep['ok']} "
               f"extreme_500k_ok={extreme['ok']}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_xent.json", "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sweep (CI)")
    ap.add_argument("--out", default="BENCH_xent.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']}, "
          f"no_nrb_tensor={result['fused_free_of_nrb_tensor']}, "
          f"d_sweep_ok={result['d_sweep_ok']}, "
          f"extreme_500k_ok={result['extreme_500k_ok']})")
    return 0 if (result["verified_interpret"]
                 and result["fused_free_of_nrb_tensor"]
                 and result["d_sweep_ok"]
                 and result["extreme_500k_ok"]) else 1


if __name__ == "__main__":
    sys.exit(main())
