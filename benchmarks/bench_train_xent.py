"""Training-loss hot path: fused projection+CE vs materialized logits.

Sweeps (N, d, R, B) — N = B·T flattened tokens — and records, per config:

  * ``us_materialized`` — value_and_grad of the materializing path
                  (``head matmul → (N, R·B) logits → mach_xent``), i.e.
                  what ``model.loss`` runs with ``mach_fused_loss=False``.
  * ``us_fused``  — value_and_grad of ``ops.mach_fused_xent`` as
                  dispatched on this backend.  On TPU that is the fused
                  Pallas kernel; on CPU the dispatcher falls back to the
                  same materializing reference math, so the two columns
                  coincide — ``fused_is_kernel`` records which one ran.
  * ``peak_act_bytes_*`` — the largest *activation* in the jaxpr of
                  each path's forward+backward: intermediates carrying
                  the batch dimension (leading dim in [N, N+block)).
                  Parameter-shaped intermediates (the padded W, dW) are
                  parameter/gradient memory — the paper's O(d log K)
                  budget — and Pallas kernel internals are VMEM tiles;
                  both are excluded.  The structural claim: the
                  materialized path peaks at the N·R·B·4-byte logits
                  tensor, the fused path's peak is h/dh-sized —
                  independent of R·B.
  * ``has_nrb_tensor_*`` — whether any batch-carrying intermediate of
                  ≥ N·R·B elements exists in the pass.
  * ``parity_max_abs_err`` / ``grad_allclose`` — interpret-mode kernel
                  vs reference on this config (loss |Δ| and dh/dW/dbias
                  at rtol 1e-4): the PR's acceptance gate, checked on
                  every sweep entry (``--quick`` skips the largest).

The **d-sweep gate** (ISSUE 4): for d ∈ {1k, 4k, 12k} at the
mistral-large-scale head (R=32, B=512), ``choose_fused_blocks`` must
yield a tiling whose accounted VMEM tile bytes (``dense_tile_bytes``)
fit the default 6 MB budget — the old lane-floor clamp silently blew
it ~2x at d=12288 — and interpret-mode parity (values + dh/dW/dbias)
must hold through the d-blocked kernels.  ``--quick`` runs the budget
accounting at every d but parity only at d=1k (interpret-mode grids at
d=12k are minutes-slow on CPU); the full run checks parity at all
three.

Writes ``BENCH_xent.json`` (see ``--out``) so the train-loss perf and
memory trajectory is tracked from this PR forward.

    PYTHONPATH=src python benchmarks/bench_train_xent.py [--quick]
"""

from __future__ import annotations

BENCH_FILE = "BENCH_xent.json"        # regression-gated by benchmarks/run.py

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals, make_dense_case, timeit
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import (DEFAULT_VMEM_BUDGET,
                                           choose_fused_blocks,
                                           dense_tile_bytes,
                                           mach_fused_xent_pallas)

# (N, d, R, B): acceptance config, paper's ODP (R=25, B=32) and
# ImageNet-21k (R=20, B=512) heads, and a 32k-column ODP-scale head
# that only the fused path can train without an (N, 32k) activation.
# N != d everywhere so batch-carrying and param-shaped intermediates
# are distinguishable by leading dim in the jaxpr scan.
SWEEP = [
    (256, 128, 16, 512),       # the PR's acceptance case (R·B = 8192)
    (512, 256, 25, 32),        # ODP-like head
    (320, 256, 20, 512),       # imagenet-21k-like head
    (192, 128, 16, 2048),      # R·B = 32768: ODP-scale column count
]
QUICK_SWEEP = SWEEP[:2]

# d-sweep (ISSUE 4): LM-trunk widths at the (R=32, B=512) head.  The
# chooser is asked at N=256 (the confirmed-blowout shape); parity runs
# at N=16 — the (C/bc)·(D/bd) grid axes, which the gate exercises, are
# N-independent, and interpret mode pays per grid step.
D_SWEEP = [1024, 4096, 12288]
D_SWEEP_RB = (32, 512)


def _memory_model(fn, args, n: int, nrb: int) -> dict:
    """Activation accounting over the traced jaxpr: intermediates whose
    leading dim is the (possibly block-padded) batch dim N.  Kernel
    block sizes never exceed 128, so padding adds < 128 rows."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args).jaxpr)
    acts = [a for a in avals
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]
    return {"peak_act_bytes": max(a.size * a.dtype.itemsize for a in acts),
            "has_nrb_tensor": any(a.size >= nrb for a in acts)}


def _verify(h, w, bias, y, g, b, block_c=None, block_d=None
            ) -> tuple[float, bool]:
    """Interpret-mode kernel vs reference with the in-kernel bias:
    (max |Δloss|, dh/dW/dbias grads ok)."""
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, block_c, block_d,
                                True)
    loss_err = float(jnp.max(jnp.abs(lr - lk)))
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, block_c, block_d,
                               True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    grads_ok = all(
        np.allclose(np.asarray(a), np.asarray(k), rtol=1e-4, atol=1e-6)
        for a, k in zip(dr, dk))
    return loss_err, grads_ok


def _d_sweep_gate(quick: bool, report=None) -> dict:
    """ISSUE 4's acceptance gate: budget accounting at every d, parity
    through the d-blocked kernels (at N=16; the d-blocked grid axes are
    N-independent)."""
    r, b = D_SWEEP_RB
    rows = []
    for d in D_SWEEP:
        bn, bc, bd, rp, bp = choose_fused_blocks(256, d, r, b)
        acct = dense_tile_bytes(bn, bc, bd, rp)
        row = {"d": d, "R": r, "B": b, "bn": bn, "bc": bc, "bd": bd,
               "rp": rp, "tile_bytes": acct,
               "within_budget": bool(acct <= DEFAULT_VMEM_BUDGET)}
        if not quick or d == D_SWEEP[0]:
            # parity at the N=256 choice's (bc, bd) — the exact tiling
            # the budget row is about (bn tracks the smaller N)
            h, w, bias, y, g = make_dense_case(16, d, r, b)
            loss_err, grads_ok = _verify(h, w, bias, y, g, b,
                                         block_c=bc, block_d=bd)
            row["parity_max_abs_err"] = loss_err
            row["grad_allclose"] = bool(grads_ok)
        rows.append(row)
        if report:
            report(f"train_xent/d_sweep_d{d}", 0.0,
                   f"blocks=({bn},{bc},{bd}) tile_kb={acct // 1024} "
                   f"within_budget={row['within_budget']} "
                   f"parity={row.get('parity_max_abs_err', 'skipped')} "
                   f"grads_ok={row.get('grad_allclose', 'skipped')}")
    ok = all(r_["within_budget"] for r_ in rows) and all(
        r_.get("grad_allclose", True) and
        r_.get("parity_max_abs_err", 0.0) <= 1e-4
        for r_ in rows)
    return {"rows": rows, "ok": bool(ok)}


def bench(quick: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    sweep = QUICK_SWEEP if quick else SWEEP
    for (n, d, r, b) in sweep:
        h, w, bias, y, g = make_dense_case(n, d, r, b)
        nrb = n * r * b

        def mat_vag(h_, w_, bias_):
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                ref.mach_fused_xent_ref(hh, ww, y, b, bias=bb) * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        def fused_vag(h_, w_, bias_):
            # backend dispatch (kernel on TPU, reference elsewhere)
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                ops.mach_fused_xent(hh, ww, y, num_buckets=b, bias=bb)
                * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        def kernel_vag(h_, w_, bias_):
            # the kernel path regardless of backend (for the jaxpr scan)
            return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
                mach_fused_xent_pallas(hh, ww, bb, y, b, None, None,
                                       None, True) * g),
                argnums=(0, 1, 2))(h_, w_, bias_)

        us_mat = timeit(jax.jit(mat_vag), h, w, bias, iters=5)
        us_fused = timeit(jax.jit(fused_vag), h, w, bias, iters=5)
        mem_mat = _memory_model(mat_vag, (h, w, bias), n, nrb)
        mem_fused = _memory_model(kernel_vag, (h, w, bias), n, nrb)
        loss_err, grads_ok = _verify(h, w, bias, y, g, b)

        row = {"N": n, "d": d, "R": r, "B": b, "RB": r * b,
               "us_materialized": us_mat, "us_fused": us_fused,
               "fused_is_kernel": on_tpu,
               "peak_act_bytes_materialized": mem_mat["peak_act_bytes"],
               "peak_act_bytes_fused": mem_fused["peak_act_bytes"],
               "has_nrb_tensor_materialized": mem_mat["has_nrb_tensor"],
               "has_nrb_tensor_fused": mem_fused["has_nrb_tensor"],
               "act_ratio": mem_mat["peak_act_bytes"]
               / mem_fused["peak_act_bytes"],
               "parity_max_abs_err": loss_err,
               "grad_allclose": bool(grads_ok)}
        rows.append(row)
        if report:
            report(f"train_xent/N{n}_d{d}_R{r}_B{b}", us_fused,
                   f"mat={us_mat:.0f}us act_ratio={row['act_ratio']:.1f}x "
                   f"loss_err={loss_err:.1e} grads_ok={grads_ok} "
                   f"kernel={on_tpu}")

    d_sweep = _d_sweep_gate(quick, report)
    verified = all(r["grad_allclose"] and r["parity_max_abs_err"] <= 1e-5
                   for r in rows)
    no_nrb = all(not r["has_nrb_tensor_fused"] for r in rows)
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified),
           "fused_free_of_nrb_tensor": bool(no_nrb),
           "d_sweep_ok": d_sweep["ok"],
           "d_sweep": d_sweep["rows"],
           "configs": rows}
    if report:
        report("train_xent/verified", 0.0,
               f"interpret_match={verified} no_nrb_tensor={no_nrb} "
               f"d_sweep_ok={d_sweep['ok']}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_xent.json", "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sweep (CI)")
    ap.add_argument("--out", default="BENCH_xent.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']}, "
          f"no_nrb_tensor={result['fused_free_of_nrb_tensor']}, "
          f"d_sweep_ok={result['d_sweep_ok']})")
    return 0 if (result["verified_interpret"]
                 and result["fused_free_of_nrb_tensor"]
                 and result["d_sweep_ok"]) else 1


if __name__ == "__main__":
    sys.exit(main())
