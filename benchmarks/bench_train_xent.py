"""Training-loss hot path: fused projection+CE vs materialized logits.

Sweeps (N, d, R, B) — N = B·T flattened tokens — and records, per config:

  * ``us_materialized`` — value_and_grad of the materializing path
                  (``head matmul → (N, R·B) logits → mach_xent``), i.e.
                  what ``model.loss`` runs with ``mach_fused_loss=False``.
  * ``us_fused``  — value_and_grad of ``ops.mach_fused_xent`` as
                  dispatched on this backend.  On TPU that is the fused
                  Pallas kernel; on CPU the dispatcher falls back to the
                  same materializing reference math, so the two columns
                  coincide — ``fused_is_kernel`` records which one ran.
  * ``peak_act_bytes_*`` — the largest *activation* in the jaxpr of
                  each path's forward+backward: intermediates carrying
                  the batch dimension (leading dim in [N, N+block)).
                  Parameter-shaped intermediates (the padded W, dW) are
                  parameter/gradient memory — the paper's O(d log K)
                  budget — and Pallas kernel internals are VMEM tiles;
                  both are excluded.  The structural claim: the
                  materialized path peaks at the N·R·B·4-byte logits
                  tensor, the fused path's peak is h/dh-sized —
                  independent of R·B.
  * ``has_nrb_tensor_*`` — whether any batch-carrying intermediate of
                  ≥ N·R·B elements exists in the pass.
  * ``parity_max_abs_err`` / ``grad_allclose`` — interpret-mode kernel
                  vs reference on this config (loss |Δ| and dh/dW at
                  rtol 1e-4): the PR's acceptance gate, checked on every
                  sweep entry (``--quick`` skips the largest).

Writes ``BENCH_xent.json`` (see ``--out``) so the train-loss perf and
memory trajectory is tracked from this PR forward.

    PYTHONPATH=src python benchmarks/bench_train_xent.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals, timeit
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import mach_fused_xent_pallas

# (N, d, R, B): acceptance config, paper's ODP (R=25, B=32) and
# ImageNet-21k (R=20, B=512) heads, and a 32k-column ODP-scale head
# that only the fused path can train without an (N, 32k) activation.
# N != d everywhere so batch-carrying and param-shaped intermediates
# are distinguishable by leading dim in the jaxpr scan.
SWEEP = [
    (256, 128, 16, 512),       # the PR's acceptance case (R·B = 8192)
    (512, 256, 25, 32),        # ODP-like head
    (320, 256, 20, 512),       # imagenet-21k-like head
    (192, 128, 16, 2048),      # R·B = 32768: ODP-scale column count
]
QUICK_SWEEP = SWEEP[:2]


def _memory_model(fn, args, n: int, nrb: int) -> dict:
    """Activation accounting over the traced jaxpr: intermediates whose
    leading dim is the (possibly block-padded) batch dim N.  Kernel
    block sizes never exceed 128, so padding adds < 128 rows."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args).jaxpr)
    acts = [a for a in avals
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]
    return {"peak_act_bytes": max(a.size * a.dtype.itemsize for a in acts),
            "has_nrb_tensor": any(a.size >= nrb for a in acts)}


def _make_case(n, d, r, b, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed + n), 4)
    h = jax.random.normal(k1, (n, d)) / np.sqrt(d)
    w = jax.random.normal(k2, (d, r * b)) / np.sqrt(d)
    y = jax.random.randint(k3, (n, r), 0, b)
    g = jax.random.normal(k4, (n,))
    return h, w, y, g


def _verify(h, w, y, g, b) -> tuple[float, bool]:
    """Interpret-mode kernel vs reference: (max |Δloss|, grads ok)."""
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, y, b, None, None, True)
    loss_err = float(jnp.max(jnp.abs(lr - lk)))
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, y, b, None, None, True) * g),
        argnums=(0, 1))(h, w)
    grads_ok = all(
        np.allclose(np.asarray(a), np.asarray(k), rtol=1e-4, atol=1e-6)
        for a, k in zip(dr, dk))
    return loss_err, grads_ok


def bench(quick: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    sweep = QUICK_SWEEP if quick else SWEEP
    for (n, d, r, b) in sweep:
        h, w, y, g = _make_case(n, d, r, b)
        nrb = n * r * b

        def mat_vag(h_, w_):
            return jax.value_and_grad(lambda hh, ww: jnp.sum(
                ref.mach_fused_xent_ref(hh, ww, y, b) * g),
                argnums=(0, 1))(h_, w_)

        def fused_vag(h_, w_):
            # backend dispatch (kernel on TPU, reference elsewhere)
            return jax.value_and_grad(lambda hh, ww: jnp.sum(
                ops.mach_fused_xent(hh, ww, y, num_buckets=b) * g),
                argnums=(0, 1))(h_, w_)

        def kernel_vag(h_, w_):
            # the kernel path regardless of backend (for the jaxpr scan)
            return jax.value_and_grad(lambda hh, ww: jnp.sum(
                mach_fused_xent_pallas(hh, ww, y, b, None, None, True) * g),
                argnums=(0, 1))(h_, w_)

        us_mat = timeit(jax.jit(mat_vag), h, w, iters=5)
        us_fused = timeit(jax.jit(fused_vag), h, w, iters=5)
        mem_mat = _memory_model(mat_vag, (h, w), n, nrb)
        mem_fused = _memory_model(kernel_vag, (h, w), n, nrb)
        loss_err, grads_ok = _verify(h, w, y, g, b)

        row = {"N": n, "d": d, "R": r, "B": b, "RB": r * b,
               "us_materialized": us_mat, "us_fused": us_fused,
               "fused_is_kernel": on_tpu,
               "peak_act_bytes_materialized": mem_mat["peak_act_bytes"],
               "peak_act_bytes_fused": mem_fused["peak_act_bytes"],
               "has_nrb_tensor_materialized": mem_mat["has_nrb_tensor"],
               "has_nrb_tensor_fused": mem_fused["has_nrb_tensor"],
               "act_ratio": mem_mat["peak_act_bytes"]
               / mem_fused["peak_act_bytes"],
               "parity_max_abs_err": loss_err,
               "grad_allclose": bool(grads_ok)}
        rows.append(row)
        if report:
            report(f"train_xent/N{n}_d{d}_R{r}_B{b}", us_fused,
                   f"mat={us_mat:.0f}us act_ratio={row['act_ratio']:.1f}x "
                   f"loss_err={loss_err:.1e} grads_ok={grads_ok} "
                   f"kernel={on_tpu}")

    verified = all(r["grad_allclose"] and r["parity_max_abs_err"] <= 1e-5
                   for r in rows)
    no_nrb = all(not r["has_nrb_tensor_fused"] for r in rows)
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified),
           "fused_free_of_nrb_tensor": bool(no_nrb),
           "configs": rows}
    if report:
        report("train_xent/verified", 0.0,
               f"interpret_match={verified} no_nrb_tensor={no_nrb}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_xent.json", "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sweep (CI)")
    ap.add_argument("--out", default="BENCH_xent.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']}, "
          f"no_nrb_tensor={result['fused_free_of_nrb_tensor']})")
    return 0 if (result["verified_interpret"]
                 and result["fused_free_of_nrb_tensor"]) else 1


if __name__ == "__main__":
    sys.exit(main())
