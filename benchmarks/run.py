"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (shared report hook).

  fig1_tradeoff     paper Figure 1  (accuracy vs B, R)
  table2_resources  paper Table 2   (model size / time / accuracy)
  table3_estimators paper Table 3   (unbiased / min / median)
  bench_kernels     decode-cost claims (O(RBd+KR) vs O(Kd))
  bench_decode_topk streaming top-k decode vs (B, V) reference
                    (also writes BENCH_decode.json)
  bench_train_xent  fused projection+CE training loss vs materialized
                    logits, plus the 500k-label dynamic bucket-selection
                    gate: selected step must beat the full step at ≥5×
                    C-axis reduction with the NLL gap inside the
                    one-sided bias bound (also writes BENCH_xent.json)
  bench_sparse_xent fused CSR projection+CE vs densified reference —
                    the ODP sparse-feature path (also writes
                    BENCH_sparse.json)
  bench_serve       serving suite on Zipf ragged workloads: continuous
                    (slot) vs lockstep scheduler, paged KV pool vs
                    contiguous strips at equal HBM (4× slots + exact
                    parity + no-max_len-strip jaxpr gate), and
                    sustained Poisson traffic (p50/p99 latency ticks,
                    tokens/step) — also writes BENCH_serve.json
  roofline          §Roofline aggregation from the dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import traceback


def _report(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark module names")
    args = ap.parse_args()

    from benchmarks import (bench_decode_topk, bench_kernels, bench_serve,
                            bench_sparse_xent, bench_train_xent,
                            fig1_tradeoff, roofline, table2_resources,
                            table3_estimators)
    modules = {
        "table2_resources": table2_resources,
        "table3_estimators": table3_estimators,
        "bench_kernels": bench_kernels,
        "bench_decode_topk": bench_decode_topk,
        "bench_train_xent": bench_train_xent,
        "bench_sparse_xent": bench_sparse_xent,
        "bench_serve": bench_serve,
        "roofline": roofline,
        "fig1_tradeoff": fig1_tradeoff,
    }
    failed = []
    for name, mod in modules.items():
        if args.only and name not in args.only:
            continue
        try:
            mod.run(_report)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            _report(f"{name}/FAILED", 0.0, repr(e))
            continue
        if not _check_regression(name, mod):
            failed.append(name)
    return 1 if failed else 0


def _check_regression(name: str, mod, fail_ratio: float = 1.25) -> bool:
    """Compare the module's freshly written BENCH file against the last
    committed version (median new/old ratio over all shared ``us_*``
    fields).  A median slowdown beyond ``fail_ratio`` fails the run —
    the perf trajectory is a gate, not a snapshot.  Modules without a
    ``BENCH_FILE``, or files with no committed baseline yet, pass."""
    import json

    from benchmarks.common import bench_regression, load_committed_bench

    bench_file = getattr(mod, "BENCH_FILE", None)
    if bench_file is None:
        return True
    old = load_committed_bench(bench_file)
    try:
        with open(bench_file) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError):
        return True
    med, ratios, ok = bench_regression(old, new, fail_ratio)
    if med is None:
        # warning, not a crash: the suite ran, but its perf trajectory
        # is NOT gated until a baseline is committed
        print(f"WARNING: {bench_file} has no committed baseline "
              f"(`git show HEAD:{bench_file}` failed) — regression gate "
              f"skipped for {name}; commit the freshly written "
              f"{bench_file} to put this suite under the gate.",
              file=sys.stderr, flush=True)
        _report(f"{name}/regression", 0.0,
                f"WARNING: no committed baseline for {bench_file} — "
                "gate skipped")
        return True
    worst_key = max(ratios, key=ratios.get)
    _report(f"{name}/regression", 0.0,
            f"median={med:.2f}x over {len(ratios)} fields vs HEAD:"
            f"{bench_file} worst={worst_key}@{ratios[worst_key]:.2f}x "
            f"{'ok' if ok else f'FAIL(>{fail_ratio}x)'}")
    return ok


if __name__ == "__main__":
    sys.exit(main())
