"""Kernel-level microbenchmarks (CPU reference-path timings).

Pallas timings are meaningless in interpret mode; what IS measurable on
CPU is the algorithmic claim of the paper: MACH decode work O(RBd + KR)
vs OAA O(Kd).  We time the jnp reference implementations of both at
paper-like ratios, and report the per-cell dry-run FLOP counts for the
fused kernel's MXU recast (from DESIGN.md §3 arithmetic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import MACHConfig
from repro.kernels import ops


def run(report) -> None:
    # ODP-scale head comparison (d=4096 stand-in for the LM case):
    # OAA next-token: h (N, d) @ W (d, K) + argmax
    # MACH next-token: h @ W' (d, RB) + softmax + fused decode
    n, d, k = 32, 1024, 105033
    b, r = 32, 25
    key = jax.random.key(0)
    h = jax.random.normal(key, (n, d), jnp.float32)
    w_oaa = jax.random.normal(key, (d, k), jnp.float32) * 0.02
    w_mach = jax.random.normal(key, (d, r * b), jnp.float32) * 0.02
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()

    oaa_step = jax.jit(lambda h: jnp.argmax(h @ w_oaa, -1))
    us_oaa = timeit(oaa_step, h)
    report("kernels/oaa_next_token", us_oaa, f"N={n} d={d} K={k}")

    def mach_step(h):
        nn = h.shape[0]
        logits = (h @ w_mach).reshape(nn, r, b)
        probs = jax.nn.softmax(logits, -1)
        return ops.mach_top1(probs, tab, num_classes=k, use_pallas=False)[1]

    us_mach = timeit(jax.jit(mach_step), h)
    report("kernels/mach_next_token", us_mach,
           f"B={b} R={r} speedup_vs_oaa={us_oaa/us_mach:.2f}x "
           f"(theory_ops_ratio={(k*d)/(b*r*d + k*r):.1f}x; at N={n} both "
           f"are bound by the NK gather vs Kd weight read — see N=1)")

    # N=1: the latency-critical single-query case the paper targets.
    # OAA must still read the whole d x K matrix (~430 MB); MACH reads
    # d x RB (~3 MB) + an O(KR) gather (~10 MB).
    h1 = h[:1]
    us_oaa1 = timeit(jax.jit(lambda h: jnp.argmax(h @ w_oaa, -1)), h1)
    us_mach1 = timeit(jax.jit(mach_step), h1)
    report("kernels/mach_next_token_N1", us_mach1,
           f"oaa_N1={us_oaa1:.0f}us speedup_vs_oaa={us_oaa1/us_mach1:.1f}x "
           f"(weight-read ratio={k/(b*r):.0f}x)")

    # decode-kernel arithmetic: MXU one-hot recast FLOPs vs gather ops
    flops_mxu = 2 * n * k * r * b
    gathers = n * k * r
    report("kernels/decode_mxu_recast", 0.0,
           f"mxu_flops={flops_mxu:.2e} gather_ops={gathers:.2e} "
           f"flop_inflation={b}x traded_for_MXU_rate")

    # lru_scan reference throughput (memory-bound op)
    bsz, t, dd = 4, 512, 1024
    a = jax.random.uniform(key, (bsz, t, dd), minval=0.5, maxval=0.99)
    x = jax.random.normal(key, (bsz, t, dd)) * 0.1
    h0 = jnp.zeros((bsz, dd))
    us_lru = timeit(jax.jit(lambda a, x, h0: ops.lru_scan(
        a, x, h0, use_pallas=False)), a, x, h0)
    gb = 3 * bsz * t * dd * 4 / 1e9
    report("kernels/lru_scan_ref", us_lru,
           f"shape=({bsz},{t},{dd}) cpu_GBps={gb/(us_lru/1e6):.1f}")
