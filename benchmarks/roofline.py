"""Roofline aggregation: dry-run JSON artifacts -> §Roofline tables.

Reads artifacts/dryrun/<mesh>/<arch>__<shape>.json (produced by
launch/dryrun.py) and emits the per-(arch × shape) roofline table:
compute / memory / collective terms in seconds, the dominant bottleneck,
MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference), and the
useful-FLOPs fraction.  Output: artifacts/roofline.md (+ CSV via the
benchmark report hook).
"""

from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str) -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x: float) -> str:
    if x <= 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table_rows(mesh: str) -> list:
    rows = []
    cells = load_cells(mesh)
    key = lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"])
                     if c["shape"] in SHAPE_ORDER else 9)
    for c in sorted(cells, key=key):
        if c.get("skipped"):
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "skipped": True, "reason": c["reason"]})
            continue
        d = c["data"]
        r = d["roofline"]
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "skipped": False,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "model_flops": r["model_flops"],
            "useful_frac": r["useful_flops_fraction"],
            "fits": d["memory"]["fits_hbm"],
            "peak_gb": (d["memory"]["per_device_argument_bytes"]
                        + d["memory"]["per_device_temp_bytes"]) / 2**30,
        })
    return rows


def to_markdown(mesh: str) -> str:
    rows = table_rows(mesh)
    out = [f"### Roofline — mesh {mesh}", "",
           "| arch | shape | compute | memory | collective | bottleneck "
           "| useful FLOPs frac | fits HBM |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["skipped"]:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |")
            continue
        fits = "yes" if r["fits"] else f"NO ({r['peak_gb']:.1f}GiB)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_frac']:.2f} | {fits} |")
    return "\n".join(out)


def run(report) -> None:
    md_parts = []
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table_rows(mesh)
        done = [r for r in rows if not r["skipped"]]
        skipped = [r for r in rows if r["skipped"]]
        if not rows:
            report(f"roofline/{mesh}", 0.0, "NO ARTIFACTS (run dryrun --all)")
            continue
        bcounts = {}
        for r in done:
            bcounts[r["bottleneck"]] = bcounts.get(r["bottleneck"], 0) + 1
        fits = sum(1 for r in done if r["fits"])
        report(f"roofline/{mesh}", 0.0,
               f"cells={len(done)} skipped={len(skipped)} "
               f"fits_hbm={fits}/{len(done)} bottlenecks={bcounts}")
        for r in done:
            report(f"roofline/{mesh}/{r['arch']}/{r['shape']}", 0.0,
                   f"compute={fmt_s(r['compute_s'])} "
                   f"memory={fmt_s(r['memory_s'])} "
                   f"coll={fmt_s(r['collective_s'])} -> {r['bottleneck']} "
                   f"useful={r['useful_frac']:.2f} fits={r['fits']}")
        md_parts.append(to_markdown(mesh))
    out_path = os.path.join(ART, "..", "roofline.md")
    with open(out_path, "w") as f:
        f.write("\n\n".join(md_parts) + "\n")
    report("roofline/markdown", 0.0, f"written={os.path.abspath(out_path)}")
