"""Paper Table 2: model size reduction / training time / prediction time.

Full-scale model-size arithmetic uses the paper's exact configurations
(ODP: K=105033, d=422713, B=32, R=25 → 125–131x; ImageNet: K=21841,
d=6144, B=512, R=20 → ~2.1x).  Wall-clock numbers are measured on the
reduced-scale stand-ins (single CPU here vs the paper's Titan X — the
derived column carries the ratios, which is what the table is about).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import accuracy, make_dataset, timeit, train_linear
from repro.configs.odp_mach import IMAGENET, ODP
from repro.core import MACHConfig, MACHLinear, OAAClassifier
from repro.kernels import ops


def run(report) -> None:
    # --- full-scale model-size arithmetic (paper's headline numbers) ---
    for task in (ODP, IMAGENET):
        mach_params = task.dim * task.mach_b * task.mach_r
        oaa_params = task.dim * task.num_classes
        report(f"table2/{task.name}_size", 0.0,
               f"model_size_reduction={oaa_params/mach_params:.0f}x "
               f"oaa_bytes={oaa_params*4/1e9:.1f}GB "
               f"mach_bytes={mach_params*4/1e9:.3f}GB")
        # inference op-count reduction: O(Kd) -> O(BRd + KR)
        oaa_ops = task.num_classes * task.dim
        mach_ops = task.mach_b * task.mach_r * task.dim \
            + task.num_classes * task.mach_r
        report(f"table2/{task.name}_inference_ops", 0.0,
               f"op_reduction={oaa_ops/mach_ops:.1f}x")

    # --- measured wall-clock on the reduced stand-in ---
    K, D = 1024, 256
    ds = make_dataset(K, D)
    cfg = MACHConfig(K, 32, 8)
    m = MACHLinear(cfg, D)
    params, t_train = train_linear(ds, m, m.init(jax.random.key(0)))
    acc = accuracy(ds, lambda x: m.predict(params, x))
    x, _ = ds.batch_at(999, 512, "test")

    pred_mach = jax.jit(lambda x: m.predict(params, x))
    us_mach = timeit(pred_mach, x)
    report("table2/mach_predict_512q", us_mach,
           f"acc={acc:.3f} train_s={t_train:.1f} "
           f"us_per_query={us_mach/512:.1f}")

    oaa = OAAClassifier(K, D)
    po, _ = train_linear(ds, oaa, oaa.init(jax.random.key(1)), steps=50)
    pred_oaa = jax.jit(lambda x: oaa.predict(po, x))
    us_oaa = timeit(pred_oaa, x)
    report("table2/oaa_predict_512q", us_oaa,
           f"us_per_query={us_oaa/512:.1f}")

    # fused decode kernel (interpret mode — correctness timing only)
    meta = jax.nn.softmax(m.logits(params, x), -1)
    tab = cfg.table()
    fused = jax.jit(lambda p: ops.mach_top1(p, tab, num_classes=K,
                                            use_pallas=False))
    us_fused = timeit(fused, meta)
    report("table2/mach_decode_from_meta_512q", us_fused,
           f"decode_only_us_per_query={us_fused/512:.2f}")
