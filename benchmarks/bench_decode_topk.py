"""Streaming top-k decode vs the candidate-filtered path.

Sweeps (K, R, B, k) at serving-like batch sizes and records, per config:

  * ``us_ref``   — the reference sampling path: estimator scores over the
                   full (N, K) matrix (the gather) + ``jax.lax.top_k``;
                   this is what ``sample_token`` used to run per token.
  * ``us_fused`` — ``ops.mach_topk`` as dispatched on this backend.  On
                   TPU that is the streaming Pallas kernel; on CPU the
                   blocked-scan streaming fallback (same semantics,
                   bounded memory — the old full-matrix fallback was
                   3.2x *slower* than the reference at K=50k, n=32).
                   ``fused_over_ref`` is the headline ratio (<= 1.0
                   required at the biggest-K point).
  * ``hbm_bytes_*`` — the traffic model behind the paper's O(RBd + KR)
                   claim: the reference moves the (N, K) f32 score
                   matrix (plus the (R, N, K) gather intermediate);
                   the kernel moves meta-probs + table + (N, k) out.
  * ``verified`` — interpret-mode kernel == reference on this config
                   (indices up to tie order, values to 1e-5).

The ``gate`` section is the K >= 1M candidate-filter gate: filtered
(``candidate_mode=(m, t)``) vs streaming wall-clock, recall@k on a
planted-signal workload (20 boosted classes per row — a trained,
confident head; a flat-random row is also reported as the adversarial
case), candidate-set-size stats, and exact-mode parity stamps.
Acceptance: filtered >= 5x faster than streaming with recall@10 >= 0.99
at the default (m, t).

Writes ``BENCH_decode.json`` (see ``--out``); ``benchmarks/run.py``
diffs it against the last committed copy (median us_* ratio > 1.25x
fails).

    PYTHONPATH=src python benchmarks/bench_decode_topk.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import MACHConfig
from repro.core.hashing import inverted_table
from repro.kernels import ops, ref
from repro.kernels.mach_candidates import bucket_topm, candidate_chunks
from repro.kernels.mach_topk import mach_topk_pallas

BENCH_FILE = "BENCH_decode.json"   # regression-gated by benchmarks/run.py

# (K, R, B, k) sweep: ODP-/imagenet-/LM-vocab-like shapes
SWEEP = [
    (10_000, 8, 32, 16),
    (50_000, 16, 64, 50),
    (105_033, 25, 32, 64),     # paper's ODP config
    (21_841, 20, 512, 10),     # paper's fine-grained imagenet config
]
QUICK_SWEEP = SWEEP[:2]
BATCHES = (8, 32)
VERIFY_N = 4                   # rows for the interpret-mode check

# The K >= 1M candidate-filter gate (retrieval-scale decode).
GATE_K, GATE_R, GATE_B, GATE_N, GATE_TOPK = 1_048_576, 16, 8192, 8, 10
# Default (m, t) per estimator.  t=1 for unbiased: its oracle top-k
# legitimately contains single-repetition-collision classes, which any
# t >= 2 filter would drop (recall caps ~0.96); min/median suppress
# those intrinsically, so t=2 costs them no recall.
DEFAULT_MT = {"unbiased": (12, 1), "min": (12, 2), "median": (12, 2)}


def _traffic_model(n: int, k_cls: int, r: int, b: int, k: int) -> dict:
    f32 = 4
    ref_bytes = n * r * b * f32 + r * k_cls * f32 \
        + r * n * k_cls * f32 + n * k_cls * f32      # gather intermediate + G
    fused_bytes = n * r * b * f32 + r * k_cls * f32 + n * k * (f32 + 4)
    return {"hbm_bytes_ref": ref_bytes, "hbm_bytes_fused": fused_bytes,
            "traffic_ratio": ref_bytes / fused_bytes}


def _verify(cfg: MACHConfig, k: int) -> bool:
    """Interpret-mode kernel == reference, for all three estimators."""
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(1),
                          (VERIFY_N, cfg.num_repetitions, cfg.num_buckets)),
        -1)
    tab = cfg.table()
    for estimator in ("unbiased", "min", "median"):
        rv, ri = ref.mach_topk_ref(probs, tab, k, estimator)
        kv, ki = mach_topk_pallas(probs, tab, num_classes=cfg.num_classes,
                                  k=k, estimator=estimator, interpret=True)
        if not np.allclose(np.asarray(rv), np.asarray(kv),
                           rtol=1e-5, atol=1e-6):
            return False
        if np.array_equal(np.asarray(ri), np.asarray(ki)):
            continue
        # tie-order tolerance: ref scores at the kernel's ids must match
        scores = np.asarray(ref.mach_estimator_scores_ref(probs, tab,
                                                          estimator))
        if not np.allclose(
                scores[np.arange(VERIFY_N)[:, None], np.asarray(ki)],
                np.asarray(rv), rtol=1e-5, atol=1e-6):
            return False
    return True


def _planted_probs(key, n, r, b, coeffs, shift, num_classes,
                   n_plant: int = 20, lo: float = 5.0, hi: float = 9.0):
    """Trained-head-like workload: per row, ``n_plant`` random classes
    get a logit boost U(lo, hi) added to every repetition's noise
    logits before the softmax — well above the noise ceiling, the way a
    confident trained head concentrates mass on few classes."""
    kc, kw, kn = jax.random.split(key, 3)
    classes = jax.random.randint(kc, (n, n_plant), 0, num_classes,
                                 jnp.uint32)
    w = jax.random.uniform(kw, (n, n_plant), minval=lo, maxval=hi)
    hc = jax.lax.shift_right_logical(
        classes[:, None, :] * coeffs[None, :, None],
        jnp.uint32(shift)).astype(jnp.int32)                  # (n, r, plant)
    noise = jax.random.normal(kn, (n, r, b))
    boost = jnp.zeros((n, r, b)).at[
        jnp.arange(n)[:, None, None], jnp.arange(r)[None, :, None], hc
    ].add(w[:, None, :])
    return jax.nn.softmax(noise + boost, -1)


def _candidate_stats(meta, inv, m, t, coeffs, shift, num_classes) -> dict:
    """Candidate-set sizes behind a (m, t) setting: pool entries, mean
    claimed (distinct candidate classes) and mean count>=t survivors
    per row."""
    n, r, b = meta.shape
    ell = inv.shape[1]
    tau, ids = bucket_topm(meta, m)
    pool = jnp.take(inv, candidate_chunks(ids, b), axis=0).reshape(n, -1)
    h = jax.lax.shift_right_logical(
        pool[..., None].astype(jnp.uint32) * coeffs[None, None, :],
        jnp.uint32(shift)).astype(jnp.int32)
    g = jnp.take_along_axis(
        meta.reshape(n, r * b),
        (h + (jnp.arange(r) * b)[None, None, :]).reshape(n, -1),
        -1).reshape(n, pool.shape[1], r)
    member = g >= tau[:, None, :]
    count = member.sum(-1)
    first = jnp.argmax(member, -1)
    claimed = (first == (jnp.arange(pool.shape[1]) // (m * ell))[None]) \
        & (pool < num_classes)
    return {"pool_entries": int(pool.shape[1]),
            "mean_claimed": float(jnp.mean(claimed.sum(-1))),
            "mean_valid": float(jnp.mean((claimed & (count >= t)).sum(-1)))}


def _recall(cand_idx, stream_idx, k: int) -> float:
    ci, si = np.asarray(cand_idx), np.asarray(stream_idx)
    return float(np.mean([
        len(set(ci[i].tolist()) & set(si[i].tolist())) / k
        for i in range(ci.shape[0])]))


def _exact_parity() -> dict:
    """Exact-mode stamps on a small config: the "exact" knob is
    bit-identical to the streaming path, and the full-top-m/t=R tuple
    matches the streaming oracle's values."""
    # K <= compact_cap (2048): min/median order statistics compute on a
    # count-prioritized compaction of the pool, exact only while the
    # claimed-candidate count fits the cap — at (m=B, t=R) that count
    # is K itself.
    k_cls, b, r, n, k = 2000, 32, 8, 6, 10
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    fam = cfg.family
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(17), (n, r, b)), -1)
    sv, si = ops.mach_topk(probs, tab, num_classes=k_cls, k=k,
                           use_pallas=False)
    ev, ei = ops.mach_topk(probs, tab, num_classes=k_cls, k=k,
                           candidate_mode="exact", use_pallas=False)
    exact_bits = bool(np.array_equal(np.asarray(si), np.asarray(ei))
                      and np.array_equal(np.asarray(sv), np.asarray(ev)))
    full = True
    for est in ("unbiased", "min", "median"):
        svv, _ = ops.mach_topk(probs, tab, num_classes=k_cls, k=k,
                               estimator=est, use_pallas=False)
        cvv, _ = ops.mach_topk(probs, tab, num_classes=k_cls, k=k,
                               estimator=est, candidate_mode=(b, r),
                               inverted=inv, use_pallas=False)
        full &= bool(np.allclose(np.asarray(svv), np.asarray(cvv),
                                 rtol=1e-5, atol=1e-6))
    return {"exact_mode_bit_parity": exact_bits,
            "full_topm_tR_matches_streaming": bool(full)}


def gate(report=None, iters: int = 3) -> dict:
    """The K >= 1M filtered-vs-streaming gate (see module docstring)."""
    cfg = MACHConfig(GATE_K, GATE_B, GATE_R, hash_kind="mult_shift")
    fam = cfg.family
    coeffs = jnp.asarray(fam.coeffs())
    shift = fam.shift
    tab = jnp.asarray(cfg.table_np())
    inv = inverted_table(cfg.table_np(), GATE_B)
    meta = _planted_probs(jax.random.key(7), GATE_N, GATE_R, GATE_B,
                          coeffs, shift, GATE_K)
    flat = jax.nn.softmax(
        jax.random.normal(jax.random.key(9), (GATE_N, GATE_R, GATE_B)), -1)

    rows = []
    for est, (m, t) in DEFAULT_MT.items():
        stream_fn = jax.jit(lambda p, tb, e=est: ops.mach_topk(
            p, tb, num_classes=GATE_K, k=GATE_TOPK, estimator=e))
        us_stream = timeit(stream_fn, meta, tab, warmup=1, iters=iters)
        _, si = stream_fn(meta, tab)

        filt_fn = jax.jit(lambda p, iv, e=est, mm=m, tt=t: ops.mach_topk(
            p, num_classes=GATE_K, k=GATE_TOPK, estimator=e,
            candidate_mode=(mm, tt), inverted=iv, inline_coeffs=coeffs,
            inline_shift=shift))
        us_filt = timeit(filt_fn, meta, inv, warmup=1, iters=iters)
        _, ci = filt_fn(meta, inv)

        _, fsi = stream_fn(flat, tab)
        _, fci = filt_fn(flat, inv)

        row = {"estimator": est, "m": m, "t": t,
               "us_stream": us_stream, "us_filtered": us_filt,
               "speedup": us_stream / us_filt,
               "recall_at_k": _recall(ci, si, GATE_TOPK),
               "recall_at_k_flat_random": _recall(fci, fsi, GATE_TOPK),
               **_candidate_stats(meta, inv, m, t, coeffs, shift, GATE_K)}
        rows.append(row)
        if report:
            report(f"decode_topk/gate_K{GATE_K}_{est}_m{m}_t{t}", us_filt,
                   f"stream={us_stream:.0f}us speedup={row['speedup']:.1f}x "
                   f"recall@{GATE_TOPK}={row['recall_at_k']:.3f} "
                   f"cands={row['mean_claimed']:.0f}")

    parity = _exact_parity()
    if report:
        report("decode_topk/gate_exact_parity", 0.0, json.dumps(parity))
    return {"K": GATE_K, "R": GATE_R, "B": GATE_B, "n": GATE_N,
            "k": GATE_TOPK, "inverted_table_mb":
                round(inv.size * 4 / 2**20, 1),
            "rows": rows, **parity}


def bench(quick: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    for (k_cls, r, b, k) in (QUICK_SWEEP if quick else SWEEP):
        cfg = MACHConfig(k_cls, b, r)
        tab = cfg.table()
        for n in BATCHES:
            probs = jax.nn.softmax(
                jax.random.normal(jax.random.key(n), (n, r, b)), -1)

            ref_fn = jax.jit(lambda p, t: ref.mach_topk_ref(p, t, k))
            us_ref = timeit(ref_fn, probs, tab, iters=5)

            fused_fn = jax.jit(lambda p, t: ops.mach_topk(
                p, t, num_classes=k_cls, k=k))
            us_fused = timeit(fused_fn, probs, tab, iters=5)

            row = {"K": k_cls, "R": r, "B": b, "k": k, "n": n,
                   "us_ref": us_ref, "us_fused": us_fused,
                   "fused_over_ref": us_fused / us_ref,
                   "fused_is_kernel": on_tpu,
                   **_traffic_model(n, k_cls, r, b, k)}
            rows.append(row)
            if report:
                report(f"decode_topk/K{k_cls}_R{r}_B{b}_k{k}_n{n}",
                       us_fused,
                       f"ref={us_ref:.0f}us ratio="
                       f"{row['fused_over_ref']:.2f}x traffic_ratio="
                       f"{row['traffic_ratio']:.1f}x kernel={on_tpu}")
    # interpret-mode correctness stamp on the smallest sweep entry
    vk, vr, vb, vkk = (QUICK_SWEEP if quick else SWEEP)[0]
    verified = _verify(MACHConfig(vk, vb, vr), vkk)
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified), "configs": rows,
           "gate": gate(report)}
    if report:
        report("decode_topk/verified", 0.0, f"interpret_match={verified}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open(BENCH_FILE, "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI)")
    ap.add_argument("--out", default=BENCH_FILE)
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    g = result["gate"]
    worst = min(r["speedup"] for r in g["rows"])
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']}, "
          f"gate_min_speedup={worst:.1f}x)")
    return 0 if result["verified_interpret"] else 1


if __name__ == "__main__":
    sys.exit(main())
