"""Streaming top-k decode: fused path vs (B, V)-materializing reference.

Sweeps (K, R, B, k) at serving-like batch sizes and records, per config:

  * ``us_ref``   — the reference sampling path: estimator scores over the
                   full (N, K) matrix (the gather) + ``jax.lax.top_k``;
                   this is what ``sample_token`` used to run per token.
  * ``us_fused`` — ``ops.mach_topk`` as dispatched on this backend.  On
                   TPU that is the streaming Pallas kernel; on CPU the
                   dispatcher falls back to the same reference math, so
                   the two columns coincide — the JSON records
                   ``fused_is_kernel`` so trend lines across backends
                   aren't misread.
  * ``hbm_bytes_*`` — the traffic model behind the paper's O(RBd + KR)
                   claim: the reference moves the (N, K) f32 score
                   matrix (plus the (R, N, K) gather intermediate);
                   the kernel moves meta-probs + table + (N, k) out.
  * ``verified`` — interpret-mode kernel == reference on this config
                   (indices up to tie order, values to 1e-5).

Writes ``BENCH_decode.json`` (see ``--out``) so the perf trajectory of
the serving hot path is tracked from this PR forward.

    PYTHONPATH=src python benchmarks/bench_decode_topk.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.core import MACHConfig
from repro.kernels import ops, ref
from repro.kernels.mach_topk import mach_topk_pallas

# (K, R, B, k) sweep: ODP-/imagenet-/LM-vocab-like shapes
SWEEP = [
    (10_000, 8, 32, 16),
    (50_000, 16, 64, 50),
    (105_033, 25, 32, 64),     # paper's ODP config
    (21_841, 20, 512, 10),     # paper's fine-grained imagenet config
]
QUICK_SWEEP = SWEEP[:2]
BATCHES = (8, 32)
VERIFY_N = 4                   # rows for the interpret-mode check


def _traffic_model(n: int, k_cls: int, r: int, b: int, k: int) -> dict:
    f32 = 4
    ref_bytes = n * r * b * f32 + r * k_cls * f32 \
        + r * n * k_cls * f32 + n * k_cls * f32      # gather intermediate + G
    fused_bytes = n * r * b * f32 + r * k_cls * f32 + n * k * (f32 + 4)
    return {"hbm_bytes_ref": ref_bytes, "hbm_bytes_fused": fused_bytes,
            "traffic_ratio": ref_bytes / fused_bytes}


def _verify(cfg: MACHConfig, k: int) -> bool:
    """Interpret-mode kernel == reference, for all three estimators."""
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(1),
                          (VERIFY_N, cfg.num_repetitions, cfg.num_buckets)),
        -1)
    tab = cfg.table()
    for estimator in ("unbiased", "min", "median"):
        rv, ri = ref.mach_topk_ref(probs, tab, k, estimator)
        kv, ki = mach_topk_pallas(probs, tab, num_classes=cfg.num_classes,
                                  k=k, estimator=estimator, interpret=True)
        if not np.allclose(np.asarray(rv), np.asarray(kv),
                           rtol=1e-5, atol=1e-6):
            return False
        if np.array_equal(np.asarray(ri), np.asarray(ki)):
            continue
        # tie-order tolerance: ref scores at the kernel's ids must match
        scores = np.asarray(ref.mach_estimator_scores_ref(probs, tab,
                                                          estimator))
        if not np.allclose(
                scores[np.arange(VERIFY_N)[:, None], np.asarray(ki)],
                np.asarray(rv), rtol=1e-5, atol=1e-6):
            return False
    return True


def bench(quick: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    for (k_cls, r, b, k) in (QUICK_SWEEP if quick else SWEEP):
        cfg = MACHConfig(k_cls, b, r)
        tab = cfg.table()
        for n in BATCHES:
            probs = jax.nn.softmax(
                jax.random.normal(jax.random.key(n), (n, r, b)), -1)

            ref_fn = jax.jit(lambda p, t: ref.mach_topk_ref(p, t, k))
            us_ref = timeit(ref_fn, probs, tab, iters=5)

            fused_fn = jax.jit(lambda p, t: ops.mach_topk(
                p, t, num_classes=k_cls, k=k))
            us_fused = timeit(fused_fn, probs, tab, iters=5)

            row = {"K": k_cls, "R": r, "B": b, "k": k, "n": n,
                   "us_ref": us_ref, "us_fused": us_fused,
                   "fused_is_kernel": on_tpu,
                   **_traffic_model(n, k_cls, r, b, k)}
            rows.append(row)
            if report:
                report(f"decode_topk/K{k_cls}_R{r}_B{b}_k{k}_n{n}",
                       us_fused,
                       f"ref={us_ref:.0f}us traffic_ratio="
                       f"{row['traffic_ratio']:.1f}x kernel={on_tpu}")
    # interpret-mode correctness stamp on the smallest sweep entry
    vk, vr, vb, vkk = (QUICK_SWEEP if quick else SWEEP)[0]
    verified = _verify(MACHConfig(vk, vb, vr), vkk)
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified), "configs": rows}
    if report:
        report("decode_topk/verified", 0.0, f"interpret_match={verified}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_decode.json", "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI)")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']})")
    return 0 if result["verified_interpret"] else 1


if __name__ == "__main__":
    sys.exit(main())
