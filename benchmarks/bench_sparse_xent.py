"""Sparse-feature training-loss hot path: fused CSR projection+CE.

The claim under test is the paper's single-GPU ODP story: with CSR
inputs at fixed nnz, peak *training activation* memory is independent
of the feature dimension d — the fused kernel densifies per tile in
VMEM, so neither a dense (N, d) activation nor an (N, R·B) logits
tensor ever exists in HBM.  Sweeps (N, d, R, B, nnz) — including a
fixed-nnz d-progression — and records, per config:

  * ``us_fused`` / ``us_densified`` — value_and_grad wrt (W, bias) of
                  ``ops.mach_fused_xent_csr`` as dispatched on this
                  backend, vs the densifying reference (which scatters
                  the batch into a dense (N, d) activation first).  On
                  CPU the dispatcher itself falls back to that same
                  reference — ``fused_is_kernel`` records which ran.
  * ``peak_act_bytes_*`` — the largest batch-carrying intermediate
                  (leading dim in [N, N+block)) in each path's
                  fwd+bwd jaxpr.  Parameter/gradient-shaped tensors
                  (W, dW — the O(d log K) budget) and Pallas VMEM
                  tiles are excluded.  The structural claims: the
                  sparse path's peak is ELL-sized (O(N·nnz_max), d
                  never enters), equal across the fixed-nnz d sweep;
                  the densified path's peak is the (N, d) activation.
  * ``has_nrb_tensor_*`` / ``has_nd_tensor_*`` — whether any
                  batch-carrying intermediate of ≥ N·R·B (resp. ≥ N·d)
                  elements exists in the pass.
  * ``parity_rel_err`` / ``grad_allclose`` — interpret-mode kernel
                  vs densified reference (relative loss error and
                  dW/dbias at rtol 1e-4) on ragged-row CSR batches: the
                  PR's acceptance gate, checked on every sweep entry.

Writes ``BENCH_sparse.json`` (see ``--out``).

    PYTHONPATH=src python benchmarks/bench_sparse_xent.py [--smoke]
"""

from __future__ import annotations

BENCH_FILE = "BENCH_sparse.json"        # regression-gated by benchmarks/run.py

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals, make_csr_case, timeit
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import GATHER_NNZ_THRESHOLD

# (N, d, R, B, nnz_max): the first three share (N, R, B, nnz) and sweep
# d only — the d-independence claim; the fourth is an ODP-like head
# (R=25, B=32) at a d no dense (N, d) scatter should be paid for; the
# last crosses GATHER_NNZ_THRESHOLD so the dispatcher routes it to the
# scalar-prefetch gather kernel (no (bn, jp, bd) one-hot densification
# — the regime the padded-ELL path could not block).  N is small there
# because the interpret-mode grid pays per example row.
SWEEP = [
    (64, 512, 8, 64, 16),
    (64, 2048, 8, 64, 16),
    (64, 8192, 8, 64, 16),
    (128, 4096, 25, 32, 32),
    (4, 1024, 8, 128, 512),     # high-nnz: gather path (nnz < R·B and
    #                             nnz < d, so the ELL operands stay
    #                             under the N·R·B / N·d thresholds)
]
SMOKE_SWEEP = SWEEP[:2] + SWEEP[-1:]
D_SWEEP_KEY = (64, 8, 64, 16)      # (N, R, B, nnz) of the d-progression


def _memory_model(fn, args, n: int, nrb: int, nd: int) -> dict:
    """Batch-carrying intermediates (leading dim in [N, N+128)) of the
    traced fwd+bwd jaxpr; kernel block sizes never exceed 128."""
    avals = intermediate_avals(jax.make_jaxpr(fn)(*args).jaxpr)
    acts = [a for a in avals
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]
    return {"peak_act_bytes": max(a.size * a.dtype.itemsize for a in acts),
            "has_nrb_tensor": any(a.size >= nrb for a in acts),
            "has_nd_tensor": any(a.size >= nd for a in acts)}


def bench(smoke: bool = False, report=None) -> dict:
    backend = jax.default_backend()
    on_tpu = backend == "tpu"
    rows = []
    sweep = SMOKE_SWEEP if smoke else SWEEP
    for (n, d, r, b, nnz_max) in sweep:
        indptr, indices, values, w, bias, y, g = make_csr_case(
            n, d, r, b, nnz_max)
        nrb, nd = n * r * b, n * d

        def densified_vag(w_, bias_):
            return jax.value_and_grad(lambda ww, bb: jnp.sum(
                ref.mach_fused_xent_csr_ref(indptr, indices, values, ww,
                                            y, b, bias=bb) * g),
                argnums=(0, 1))(w_, bias_)

        def fused_vag(w_, bias_):
            # backend dispatch (kernel on TPU, densified ref elsewhere)
            return jax.value_and_grad(lambda ww, bb: jnp.sum(
                ops.mach_fused_xent_csr(indptr, indices, values, ww, y,
                                        num_buckets=b, nnz_max=nnz_max,
                                        bias=bb) * g),
                argnums=(0, 1))(w_, bias_)

        def kernel_vag(w_, bias_):
            # the kernel path regardless of backend (for the jaxpr scan)
            return jax.value_and_grad(lambda ww, bb: jnp.sum(
                ops.mach_fused_xent_csr(indptr, indices, values, ww, y,
                                        num_buckets=b, nnz_max=nnz_max,
                                        bias=bb, use_pallas=True,
                                        interpret=True) * g),
                argnums=(0, 1))(w_, bias_)

        us_dense = timeit(jax.jit(densified_vag), w, bias, iters=5)
        us_fused = timeit(jax.jit(fused_vag), w, bias, iters=5)
        mem_dense = _memory_model(densified_vag, (w, bias), n, nrb, nd)
        mem_fused = _memory_model(kernel_vag, (w, bias), n, nrb, nd)

        # parity gate: interpret-mode kernel vs densified reference
        # (lr/lk are g-weighted SUMS over the batch, so the loss gate is
        # relative — absolute error scales with N·R·log B)
        (lr, dr) = densified_vag(w, bias)
        (lk, dk) = kernel_vag(w, bias)
        loss_err = float(jnp.abs(lr - lk) / jnp.maximum(jnp.abs(lr), 1.0))
        grads_ok = all(
            np.allclose(np.asarray(a), np.asarray(k), rtol=1e-4, atol=1e-6)
            for a, k in zip(dr, dk))

        impl = "gather" if nnz_max >= GATHER_NNZ_THRESHOLD else "densify"
        row = {"N": n, "d": d, "R": r, "B": b, "RB": r * b,
               "nnz_max": nnz_max, "sparse_impl": impl,
               "us_densified": us_dense, "us_fused": us_fused,
               "fused_is_kernel": on_tpu,
               "peak_act_bytes_densified": mem_dense["peak_act_bytes"],
               "peak_act_bytes_fused": mem_fused["peak_act_bytes"],
               "has_nrb_tensor_densified": mem_dense["has_nrb_tensor"],
               "has_nrb_tensor_fused": mem_fused["has_nrb_tensor"],
               "has_nd_tensor_densified": mem_dense["has_nd_tensor"],
               "has_nd_tensor_fused": mem_fused["has_nd_tensor"],
               "act_ratio": mem_dense["peak_act_bytes"]
               / mem_fused["peak_act_bytes"],
               "parity_rel_err": loss_err,
               "grad_allclose": bool(grads_ok)}
        rows.append(row)
        if report:
            report(f"sparse_xent/N{n}_d{d}_R{r}_B{b}_nnz{nnz_max}",
                   us_fused,
                   f"densified={us_dense:.0f}us "
                   f"act_ratio={row['act_ratio']:.1f}x "
                   f"loss_err={loss_err:.1e} grads_ok={grads_ok} "
                   f"impl={impl} kernel={on_tpu}")

    verified = all(r["grad_allclose"] and r["parity_rel_err"] <= 1e-5
                   for r in rows)
    clean = all(not r["has_nrb_tensor_fused"]
                and not r["has_nd_tensor_fused"] for r in rows)
    d_peaks = {r["peak_act_bytes_fused"] for r in rows
               if (r["N"], r["R"], r["B"], r["nnz_max"]) == D_SWEEP_KEY}
    d_independent = len(d_peaks) == 1
    out = {"backend": backend, "fused_is_kernel": on_tpu,
           "verified_interpret": bool(verified),
           "fused_free_of_nrb_and_nd_tensors": bool(clean),
           "peak_act_independent_of_d": bool(d_independent),
           "configs": rows}
    if report:
        report("sparse_xent/verified", 0.0,
               f"interpret_match={verified} no_nrb_or_nd={clean} "
               f"d_independent={d_independent}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(smoke=True, report=report)
    with open("BENCH_sparse.json", "w") as f:
        json.dump(result, f, indent=2)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    help="small sweep (CI)")
    ap.add_argument("--out", default="BENCH_sparse.json")
    args = ap.parse_args()
    result = bench(smoke=args.smoke,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} ({len(result['configs'])} configs, "
          f"backend={result['backend']}, "
          f"verified={result['verified_interpret']}, "
          f"clean={result['fused_free_of_nrb_and_nd_tensors']}, "
          f"d_independent={result['peak_act_independent_of_d']})")
    return 0 if (result["verified_interpret"]
                 and result["fused_free_of_nrb_and_nd_tensors"]
                 and result["peak_act_independent_of_d"]) else 1


if __name__ == "__main__":
    sys.exit(main())
