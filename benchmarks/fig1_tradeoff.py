"""Paper Figure 1: accuracy–resource tradeoff with varying B and R.

Reduced-scale reproduction (K=1024, d=256 synthetic with known Bayes
optimum — ODP itself is not redistributable offline): for a grid of
(B, R) train MACHLinear and report accuracy, parameters, and the model-
size ratio vs OAA.  The paper's qualitative claims checked here:
  * accuracy increases monotonically-ish in both B and R,
  * MACH trades memory for accuracy smoothly (no cliff),
  * even at BR << K, accuracy >> random.
"""

from __future__ import annotations

import jax

from benchmarks.common import accuracy, make_dataset, train_linear
from repro.core import MACHConfig, MACHLinear, OAAClassifier

GRID = [(16, 2), (16, 4), (32, 4), (64, 4), (32, 8), (64, 8)]
K, D = 1024, 256


def run(report) -> None:
    ds = make_dataset(K, D)
    oaa = OAAClassifier(K, D)
    po, t_oaa = train_linear(ds, oaa, oaa.init(jax.random.key(2)))
    acc_oaa = accuracy(ds, lambda x: oaa.predict(po, x))
    report("fig1/oaa", t_oaa * 1e6 / 150,
           f"acc={acc_oaa:.3f} params={oaa.param_count()}")

    prev_by_r: dict = {}
    for b, r in GRID:
        cfg = MACHConfig(K, b, r)
        m = MACHLinear(cfg, D)
        params, t = train_linear(ds, m, m.init(jax.random.key(0)))
        acc = accuracy(ds, lambda x: m.predict(params, x))
        red = oaa.param_count() / m.param_count()
        report(f"fig1/mach_B{b}_R{r}", t * 1e6 / 150,
               f"acc={acc:.3f} size_reduction={red:.1f}x "
               f"acc_vs_oaa={acc/max(acc_oaa,1e-9):.2f}")
        prev_by_r.setdefault(r, []).append((b, acc))

    # monotonicity in B at fixed R (paper Fig. 1 shape)
    for r, pts in prev_by_r.items():
        pts.sort()
        accs = [a for _, a in pts]
        ok = all(accs[i] <= accs[i + 1] + 0.03 for i in range(len(accs) - 1))
        report(f"fig1/monotone_R{r}", 0.0, f"monotone_in_B={ok} {accs}")
