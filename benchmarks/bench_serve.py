"""Serving scheduler benchmark: schedulers, paged KV pool, sustained load.

Drives the ``ServingEngine`` over Zipf-ragged workloads (prompt and
output lengths each varying ≥ 8×) and gates the serving stack's claims:

  * **strictly fewer decode steps** — the slot scheduler frees a slot
    the moment a request finishes and admits the next queued request
    into it, so on ragged workloads it completes the same requests in
    strictly fewer pooled decode steps than the lockstep baseline
    (which holds every slot until the whole chunk drains);
  * **exact greedy token parity** — scheduling must not change tokens:
    per-request prefill (no padding) + per-slot cache writes mean each
    request's continuation is bit-identical under both schedulers;
  * **paged ≥4× slots at equal HBM** — at byte-identical KV-pool size
    the paged engine (shared page pool + per-slot page tables) runs
    ≥ 4× the contiguous engine's num_slots concurrently on the ragged
    workload, with exact greedy token parity vs the contiguous engine;
  * **sustained traffic** — Poisson arrivals over ≥ 256 Zipf-ragged
    requests, reporting p50/p99 request latency in scheduler ticks and
    tokens/step for the paged and contiguous engines.

Also re-checks the acceptance jaxpr properties: the unified serve step
(greedy *and* sampled rows, through the fused streaming top-k kernel
path) never materializes a (batch, V) score tensor, and the *paged*
decode step never materializes a per-slot max_len strip — no
intermediate carries both the slot dim and the logical max_len dim.

Writes ``BENCH_serve.json`` (``us_*`` fields are regression-gated by
``benchmarks/run.py`` at median ratio ≤ 1.25×).

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""

from __future__ import annotations

BENCH_FILE = "BENCH_serve.json"        # regression-gated by benchmarks/run.py

import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals
from repro.core.mach import MACHConfig
from repro.kernels import ops
from repro.models import LanguageModel, ModelConfig
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.engine import make_serve_step_fn

VOCAB = 4096                   # distinctive V for the jaxpr scan
SLOTS = 4
MAX_LEN = 64
# ladders keep the jit cache small while spanning the ragged regime
PROMPT_LADDER = (2, 3, 4, 6, 8, 16)      # 8× spread
OUTPUT_LADDER = (2, 3, 4, 6, 8, 16, 32)  # 16× spread
# paged configuration at KV-byte parity with the contiguous engine:
# SLOTS × MAX_LEN = 256 token rows/layer == NUM_PAGES × PAGE_SIZE,
# but the page pool runs 4× the slots (the acceptance gate)
PAGE_SIZE = 8
NUM_PAGES = SLOTS * MAX_LEN // PAGE_SIZE          # 32
SLOTS_PAGED = 4 * SLOTS                           # 16
SUSTAINED_REQUESTS = 256
ARRIVAL_RATE = 2.0             # mean Poisson arrivals per scheduler tick


def build_model():
    cfg = ModelConfig(name="bench-serve", num_layers=2, d_model=48,
                      num_heads=4, num_kv_heads=2, d_ff=96,
                      vocab_size=VOCAB, dtype=jnp.float32,
                      mach=MACHConfig(VOCAB, 32, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return model, params


def build_workload(n_requests: int, seed: int = 0,
                   out_ladder: tuple = OUTPUT_LADDER,
                   a: float = 1.5) -> list:
    """[(prompt, max_new), ...] with Zipf(a)-weighted ragged lengths.

    Both ladders' extremes are forced in so the ≥8× spread the gate
    talks about is a property of the workload, not luck."""
    rng = np.random.default_rng(seed)

    def zipf_pick(ladder, n):
        idx = np.minimum(rng.zipf(a, n) - 1, len(ladder) - 1)
        return [ladder[i] for i in idx]

    plens = zipf_pick(PROMPT_LADDER, n_requests)
    outs = zipf_pick(out_ladder, n_requests)
    plens[0], plens[1] = min(PROMPT_LADDER), max(PROMPT_LADDER)
    outs[0], outs[1] = max(out_ladder), min(out_ladder)
    assert max(plens) / min(plens) >= 8 and max(outs) / min(outs) >= 8
    work = []
    for pl, mn in zip(plens, outs):
        work.append((list(rng.integers(1, VOCAB, pl)), int(mn)))
    return work


def _make_engine(model, params, scheduler="continuous", num_slots=SLOTS,
                 page_size=0, num_pages=0):
    return ServingEngine(model, params,
                         ServeConfig(max_len=MAX_LEN, num_slots=num_slots,
                                     max_new_tokens=max(OUTPUT_LADDER),
                                     seed=0, scheduler=scheduler,
                                     page_size=page_size,
                                     num_pages=num_pages))


def _result_record(eng, results, dt) -> dict:
    lat = [r.latency_steps for r in results]
    m = eng.metrics
    out = {
        "tokens": {r.request_id: list(r.tokens) for r in results},
        "decode_steps": m.decode_steps,
        "tokens_generated": m.tokens_generated,
        "occupancy": m.occupancy,
        "tokens_per_decode_step": m.tokens_per_decode_step,
        "peak_live_slots": m.peak_live_slots,
        "tokens_per_s_wall": m.tokens_generated / dt,
        "latency_p50_steps": float(np.percentile(lat, 50)),
        "latency_p99_steps": float(np.percentile(lat, 99)),
        "wall_s": dt,
        "us_wall": dt * 1e6,
    }
    if m.num_pages:
        out["pages"] = {"num_pages": m.num_pages,
                        "pages_peak": m.pages_peak,
                        "pages_in_use_end": m.pages_in_use,
                        "pages_reserved_end": m.pages_reserved,
                        "fragmentation_end": m.fragmentation,
                        "reservation_failures": m.reservation_failures}
    return out


def run_engine(model, params, workload, scheduler: str = "continuous",
               **kw) -> dict:
    eng = _make_engine(model, params, scheduler=scheduler, **kw)
    for prompt, max_new in workload:
        eng.submit(Request(prompt=prompt, max_new_tokens=max_new))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    return _result_record(eng, results, dt)


def run_sustained(model, params, workload, rate: float = ARRIVAL_RATE,
                  seed: int = 0, **kw) -> dict:
    """Sustained-traffic mode: Poisson arrivals instead of an up-front
    drain.  Inter-arrival gaps are exponential with mean 1/rate ticks;
    a request is submitted on the first tick at or past its arrival
    time, then the engine is driven one ``step()`` per tick until the
    backlog drains.  Latency percentiles are submit→finish ticks, so
    queueing delay under backpressure is included."""
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate,
                                                  len(workload))))
    eng = _make_engine(model, params, **kw)
    results, nxt = [], 0
    t0 = time.perf_counter()
    while nxt < len(workload) or eng.queue_depth or \
            any(s is not None for s in eng._slots):
        while nxt < len(workload) and arrivals[nxt] <= eng._tick:
            prompt, max_new = workload[nxt]
            eng.submit(Request(prompt=prompt, max_new_tokens=max_new))
            nxt += 1
        results.extend(eng.step())
    dt = time.perf_counter() - t0
    rec = _result_record(eng, sorted(results, key=lambda r: r.request_id),
                         dt)
    rec["arrival_rate_per_tick"] = rate
    rec["ticks"] = eng._tick
    return rec


def check_no_bv_tensor(model) -> dict:
    """Trace the unified serve step on the *kernel* path (interpret
    Pallas, any backend) and assert no intermediate carries both the
    slot-batch dim and the V dim — the (batch, V) score matrix must not
    exist for greedy or sampled rows."""
    serve_step = make_serve_step_fn(model, top_k=8)
    pool = model.init_caches(SLOTS, MAX_LEN)
    toks = jnp.zeros((SLOTS, 1), jnp.int32)
    z = jnp.zeros((SLOTS,), jnp.int32)
    temps = jnp.full((SLOTS,), 0.9, jnp.float32)
    row_k = jnp.full((SLOTS,), 4, jnp.int32)
    key = jax.random.key(0)

    def trace(estimators):
        fn = functools.partial(serve_step, estimators=estimators,
                               max_len=MAX_LEN)
        return jax.make_jaxpr(fn)(model.init(jax.random.key(0))[0], pool,
                                  None, {"tokens": toks}, z, key, z, z,
                                  temps, row_k, z)

    orig = ops.mach_topk
    ops.mach_topk = functools.partial(orig, use_pallas=True, interpret=True)
    try:
        out = {}
        for name, ests in (("greedy_or_sampled", ("unbiased",)),
                           ("mixed_estimators", ("median", "unbiased"))):
            jaxpr = trace(ests).jaxpr
            bad = [tuple(a.shape) for a in intermediate_avals(jaxpr)
                   if hasattr(a, "shape") and VOCAB in a.shape
                   and SLOTS in a.shape]
            out[name] = {"ok": not bad, "offending_shapes": bad[:4]}
    finally:
        ops.mach_topk = orig
    return out


def check_paged_no_strip(model) -> dict:
    """Trace the *paged* decode step and assert no intermediate carries
    both the slot dim and the logical per-slot max_len dim — the
    (num_slots, max_len) worst-case strip the paged layout exists to
    kill must not be materialized even transiently (the paged attend is
    an online-softmax scan over pages), and the (batch, V) scores stay
    dead too.  PAGE_SIZE and NUM_PAGES are chosen so no honest paged
    shape collides with MAX_LEN."""
    assert PAGE_SIZE != MAX_LEN and NUM_PAGES != MAX_LEN
    serve_step = make_serve_step_fn(model, top_k=8)
    pool = model.init_paged_caches(SLOTS_PAGED, MAX_LEN, PAGE_SIZE,
                                   NUM_PAGES)
    toks = jnp.zeros((SLOTS_PAGED, 1), jnp.int32)
    z = jnp.zeros((SLOTS_PAGED,), jnp.int32)
    temps = jnp.full((SLOTS_PAGED,), 0.9, jnp.float32)
    row_k = jnp.full((SLOTS_PAGED,), 4, jnp.int32)
    fn = functools.partial(serve_step, estimators=("unbiased",),
                           max_len=MAX_LEN)
    orig = ops.mach_topk
    ops.mach_topk = functools.partial(orig, use_pallas=True, interpret=True)
    try:
        jaxpr = jax.make_jaxpr(fn)(
            model.init(jax.random.key(0))[0], pool, None,
            {"tokens": toks}, z, jax.random.key(0), z, z, temps, row_k,
            z).jaxpr
    finally:
        ops.mach_topk = orig
    strips = [tuple(a.shape) for a in intermediate_avals(jaxpr)
              if hasattr(a, "shape") and SLOTS_PAGED in a.shape
              and MAX_LEN in a.shape]
    bv = [tuple(a.shape) for a in intermediate_avals(jaxpr)
          if hasattr(a, "shape") and SLOTS_PAGED in a.shape
          and VOCAB in a.shape]
    return {"no_max_len_strip": {"ok": not strips,
                                 "offending_shapes": strips[:4]},
            "no_bv_tensor": {"ok": not bv, "offending_shapes": bv[:4]}}


def _kv_pool_bytes(model, num_slots, page_size=0, num_pages=0) -> int:
    """Resident bytes of the float (k/v) leaves of a decode pool."""
    if page_size:
        shapes = jax.eval_shape(lambda: model.init_paged_caches(
            num_slots, MAX_LEN, page_size, num_pages))
    else:
        shapes = jax.eval_shape(lambda: model.init_caches(num_slots,
                                                          MAX_LEN))
    return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(shapes)
               if jnp.issubdtype(s.dtype, jnp.floating))


def bench(quick: bool = False, report=None) -> dict:
    model, params = build_model()
    workload = build_workload(8 if quick else 20)
    runs = {s: run_engine(model, params, workload, scheduler=s)
            for s in ("continuous", "lockstep")}
    cont, lock = runs["continuous"], runs["lockstep"]

    parity = cont["tokens"] == lock["tokens"]
    fewer_steps = cont["decode_steps"] < lock["decode_steps"]
    jaxpr_gates = check_no_bv_tensor(model)
    no_bv = all(v["ok"] for v in jaxpr_gates.values())

    # ---- paged gate: 4× slots at byte-identical KV pool, exact parity.
    # Output ladder capped at 16 and a sharper Zipf exponent (spread is
    # still 8× — the extremes are forced in): the reservation is
    # worst-case prompt+max_new, so a tail-heavy mix of 4-6-page
    # budgets caps concurrency below the 16 slots the gate measures —
    # raggedness, not giant budgets, is what's under test.
    wl_paged = build_workload(48 if quick else 64, seed=1,
                              out_ladder=PROMPT_LADDER, a=2.5)
    cont_bytes = _kv_pool_bytes(model, SLOTS)
    paged_bytes = _kv_pool_bytes(model, SLOTS_PAGED, PAGE_SIZE, NUM_PAGES)
    base = run_engine(model, params, wl_paged)
    paged = run_engine(model, params, wl_paged, num_slots=SLOTS_PAGED,
                       page_size=PAGE_SIZE, num_pages=NUM_PAGES)
    paged_parity = base["tokens"] == paged["tokens"]
    slots_4x = paged["peak_live_slots"] >= 4 * SLOTS
    equal_bytes = cont_bytes == paged_bytes
    paged_jaxpr = check_paged_no_strip(model)
    no_strip = all(v["ok"] for v in paged_jaxpr.values())

    # ---- sustained traffic: Poisson arrivals, paged vs contiguous
    wl_sust = build_workload(SUSTAINED_REQUESTS, seed=2)
    sust_paged = run_sustained(model, params, wl_sust,
                               num_slots=SLOTS_PAGED, page_size=PAGE_SIZE,
                               num_pages=NUM_PAGES)
    sust_cont = run_sustained(model, params, wl_sust)

    out = {
        "backend": jax.default_backend(),
        "workload": {"requests": len(workload),
                     "prompt_lens": [len(p) for p, _ in workload],
                     "max_new": [n for _, n in workload],
                     "slots": SLOTS},
        "continuous": {k: v for k, v in cont.items() if k != "tokens"},
        "lockstep": {k: v for k, v in lock.items() if k != "tokens"},
        "paged": {
            "config": {"num_slots": SLOTS_PAGED, "page_size": PAGE_SIZE,
                       "num_pages": NUM_PAGES,
                       "kv_pool_bytes": paged_bytes,
                       "contiguous_kv_pool_bytes": cont_bytes,
                       "workload_requests": len(wl_paged)},
            "contiguous_baseline": {k: v for k, v in base.items()
                                    if k != "tokens"},
            "paged": {k: v for k, v in paged.items() if k != "tokens"},
        },
        "sustained": {
            "requests": len(wl_sust),
            "arrival_rate_per_tick": ARRIVAL_RATE,
            "paged": {k: v for k, v in sust_paged.items()
                      if k != "tokens"},
            "contiguous": {k: v for k, v in sust_cont.items()
                           if k != "tokens"},
        },
        "step_speedup": lock["decode_steps"] / cont["decode_steps"],
        "greedy_token_parity": bool(parity),
        "strictly_fewer_steps": bool(fewer_steps),
        "jaxpr_no_bv_tensor": jaxpr_gates,
        "jaxpr_paged_decode": paged_jaxpr,
        "paged_token_parity": bool(paged_parity),
        "paged_4x_slots_at_equal_hbm": bool(slots_4x and equal_bytes),
        "gates_pass": bool(parity and fewer_steps and no_bv
                           and paged_parity and slots_4x and equal_bytes
                           and no_strip),
    }
    if report:
        report("serve/continuous", cont["wall_s"] * 1e6,
               f"steps={cont['decode_steps']} occ={cont['occupancy']:.2f} "
               f"p50={cont['latency_p50_steps']:.0f} "
               f"p99={cont['latency_p99_steps']:.0f}")
        report("serve/lockstep", lock["wall_s"] * 1e6,
               f"steps={lock['decode_steps']} occ={lock['occupancy']:.2f} "
               f"p50={lock['latency_p50_steps']:.0f} "
               f"p99={lock['latency_p99_steps']:.0f}")
        report("serve/paged", paged["wall_s"] * 1e6,
               f"slots={SLOTS_PAGED} peak_live={paged['peak_live_slots']} "
               f"steps={paged['decode_steps']} "
               f"(contiguous {base['decode_steps']}) "
               f"pages_peak={paged['pages']['pages_peak']}/{NUM_PAGES}")
        report("serve/sustained_paged", sust_paged["wall_s"] * 1e6,
               f"n={len(wl_sust)} tok/step="
               f"{sust_paged['tokens_per_decode_step']:.2f} "
               f"p50={sust_paged['latency_p50_steps']:.0f} "
               f"p99={sust_paged['latency_p99_steps']:.0f} "
               f"stalls={sust_paged['pages']['reservation_failures']}")
        report("serve/sustained_contiguous", sust_cont["wall_s"] * 1e6,
               f"n={len(wl_sust)} tok/step="
               f"{sust_cont['tokens_per_decode_step']:.2f} "
               f"p50={sust_cont['latency_p50_steps']:.0f} "
               f"p99={sust_cont['latency_p99_steps']:.0f}")
        report("serve/gates", 0.0,
               f"parity={parity} fewer_steps={fewer_steps} "
               f"speedup={out['step_speedup']:.2f}x no_bv={no_bv} "
               f"paged_parity={paged_parity} 4x_slots={slots_4x} "
               f"equal_bytes={equal_bytes} no_strip={no_strip}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_serve.json", "w") as f:
        json.dump(result, f, indent=2)
    if not result["gates_pass"]:
        raise AssertionError(f"serve gates failed: {result}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small workload (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} (speedup {result['step_speedup']:.2f}x, "
          f"parity={result['greedy_token_parity']}, "
          f"gates_pass={result['gates_pass']})")
    return 0 if result["gates_pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
