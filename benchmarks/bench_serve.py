"""Serving scheduler benchmark: continuous (slot) vs lockstep batching.

Drives the ``ServingEngine`` over a Zipf-ragged workload (prompt and
output lengths each varying ≥ 8×) with both schedulers and gates the
redesign's two claims:

  * **strictly fewer decode steps** — the slot scheduler frees a slot
    the moment a request finishes and admits the next queued request
    into it, so on ragged workloads it completes the same requests in
    strictly fewer pooled decode steps than the lockstep baseline
    (which holds every slot until the whole chunk drains);
  * **exact greedy token parity** — scheduling must not change tokens:
    per-request prefill (no padding) + per-slot cache writes mean each
    request's continuation is bit-identical under both schedulers.

Also records tokens/s (wall), slot occupancy, and p50/p99 request
latency in scheduler ticks, and re-checks the acceptance jaxpr
property: the unified serve step (greedy *and* sampled rows, through
the fused streaming top-k kernel path) never materializes a
(batch, V) score tensor.

Writes ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""

from __future__ import annotations

BENCH_FILE = "BENCH_serve.json"        # regression-gated by benchmarks/run.py

import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import intermediate_avals
from repro.core.mach import MACHConfig
from repro.kernels import ops
from repro.models import LanguageModel, ModelConfig
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.engine import make_serve_step_fn

VOCAB = 4096                   # distinctive V for the jaxpr scan
SLOTS = 4
MAX_LEN = 64
# ladders keep the jit cache small while spanning the ragged regime
PROMPT_LADDER = (2, 3, 4, 6, 8, 16)      # 8× spread
OUTPUT_LADDER = (2, 3, 4, 6, 8, 16, 32)  # 16× spread


def build_model():
    cfg = ModelConfig(name="bench-serve", num_layers=2, d_model=48,
                      num_heads=4, num_kv_heads=2, d_ff=96,
                      vocab_size=VOCAB, dtype=jnp.float32,
                      mach=MACHConfig(VOCAB, 32, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return model, params


def build_workload(n_requests: int, seed: int = 0) -> list:
    """[(prompt, max_new), ...] with Zipf-weighted ragged lengths.

    Both ladders' extremes are forced in so the ≥8× spread the gate
    talks about is a property of the workload, not luck."""
    rng = np.random.default_rng(seed)

    def zipf_pick(ladder, n):
        idx = np.minimum(rng.zipf(1.5, n) - 1, len(ladder) - 1)
        return [ladder[i] for i in idx]

    plens = zipf_pick(PROMPT_LADDER, n_requests)
    outs = zipf_pick(OUTPUT_LADDER, n_requests)
    plens[0], plens[1] = min(PROMPT_LADDER), max(PROMPT_LADDER)
    outs[0], outs[1] = max(OUTPUT_LADDER), min(OUTPUT_LADDER)
    assert max(plens) / min(plens) >= 8 and max(outs) / min(outs) >= 8
    work = []
    for pl, mn in zip(plens, outs):
        work.append((list(rng.integers(1, VOCAB, pl)), int(mn)))
    return work


def run_engine(model, params, workload, scheduler: str) -> dict:
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=MAX_LEN, num_slots=SLOTS,
                                    max_new_tokens=max(OUTPUT_LADDER),
                                    seed=0, scheduler=scheduler))
    for prompt, max_new in workload:
        eng.submit(Request(prompt=prompt, max_new_tokens=max_new))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    lat = [r.latency_steps for r in results]
    m = eng.metrics
    return {
        "tokens": {r.request_id: list(r.tokens) for r in results},
        "decode_steps": m.decode_steps,
        "tokens_generated": m.tokens_generated,
        "occupancy": m.occupancy,
        "tokens_per_s_wall": m.tokens_generated / dt,
        "latency_p50_steps": float(np.percentile(lat, 50)),
        "latency_p99_steps": float(np.percentile(lat, 99)),
        "wall_s": dt,
    }


def check_no_bv_tensor(model) -> dict:
    """Trace the unified serve step on the *kernel* path (interpret
    Pallas, any backend) and assert no intermediate carries both the
    slot-batch dim and the V dim — the (batch, V) score matrix must not
    exist for greedy or sampled rows."""
    serve_step = make_serve_step_fn(model, top_k=8)
    pool = model.init_caches(SLOTS, MAX_LEN)
    toks = jnp.zeros((SLOTS, 1), jnp.int32)
    z = jnp.zeros((SLOTS,), jnp.int32)
    temps = jnp.full((SLOTS,), 0.9, jnp.float32)
    row_k = jnp.full((SLOTS,), 4, jnp.int32)
    key = jax.random.key(0)

    def trace(estimators):
        fn = functools.partial(serve_step, estimators=estimators,
                               max_len=MAX_LEN)
        return jax.make_jaxpr(fn)(model.init(jax.random.key(0))[0], pool,
                                  None, {"tokens": toks}, z, key, z, z,
                                  temps, row_k, z)

    orig = ops.mach_topk
    ops.mach_topk = functools.partial(orig, use_pallas=True, interpret=True)
    try:
        out = {}
        for name, ests in (("greedy_or_sampled", ("unbiased",)),
                           ("mixed_estimators", ("median", "unbiased"))):
            jaxpr = trace(ests).jaxpr
            bad = [tuple(a.shape) for a in intermediate_avals(jaxpr)
                   if hasattr(a, "shape") and VOCAB in a.shape
                   and SLOTS in a.shape]
            out[name] = {"ok": not bad, "offending_shapes": bad[:4]}
    finally:
        ops.mach_topk = orig
    return out


def bench(quick: bool = False, report=None) -> dict:
    model, params = build_model()
    workload = build_workload(8 if quick else 20)
    runs = {s: run_engine(model, params, workload, s)
            for s in ("continuous", "lockstep")}
    cont, lock = runs["continuous"], runs["lockstep"]

    parity = cont["tokens"] == lock["tokens"]
    fewer_steps = cont["decode_steps"] < lock["decode_steps"]
    jaxpr_gates = check_no_bv_tensor(model)
    no_bv = all(v["ok"] for v in jaxpr_gates.values())

    out = {
        "backend": jax.default_backend(),
        "workload": {"requests": len(workload),
                     "prompt_lens": [len(p) for p, _ in workload],
                     "max_new": [n for _, n in workload],
                     "slots": SLOTS},
        "continuous": {k: v for k, v in cont.items() if k != "tokens"},
        "lockstep": {k: v for k, v in lock.items() if k != "tokens"},
        "step_speedup": lock["decode_steps"] / cont["decode_steps"],
        "greedy_token_parity": bool(parity),
        "strictly_fewer_steps": bool(fewer_steps),
        "jaxpr_no_bv_tensor": jaxpr_gates,
        "gates_pass": bool(parity and fewer_steps and no_bv),
    }
    if report:
        report("serve/continuous", cont["wall_s"] * 1e6,
               f"steps={cont['decode_steps']} occ={cont['occupancy']:.2f} "
               f"p50={cont['latency_p50_steps']:.0f} "
               f"p99={cont['latency_p99_steps']:.0f}")
        report("serve/lockstep", lock["wall_s"] * 1e6,
               f"steps={lock['decode_steps']} occ={lock['occupancy']:.2f} "
               f"p50={lock['latency_p50_steps']:.0f} "
               f"p99={lock['latency_p99_steps']:.0f}")
        report("serve/gates", 0.0,
               f"parity={parity} fewer_steps={fewer_steps} "
               f"speedup={out['step_speedup']:.2f}x no_bv={no_bv}")
    return out


def run(report) -> None:
    """benchmarks/run.py hook."""
    result = bench(quick=True, report=report)
    with open("BENCH_serve.json", "w") as f:
        json.dump(result, f, indent=2)
    if not result["gates_pass"]:
        raise AssertionError(f"serve gates failed: {result}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small workload (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    result = bench(quick=args.quick,
                   report=lambda n, us, d="": print(f"{n},{us:.2f},{d}",
                                                    flush=True))
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out} (speedup {result['step_speedup']:.2f}x, "
          f"parity={result['greedy_token_parity']}, "
          f"gates_pass={result['gates_pass']})")
    return 0 if result["gates_pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
