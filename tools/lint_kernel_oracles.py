"""Lint: the kernels/ops.py dispatch surface and the kernels/ref.py
oracle set must not drift.

Every public op defined in ``repro.kernels.ops`` must name its pure-jnp
reference in ``ops.ORACLES``, and every named oracle must exist (and be
callable) in ``repro.kernels.ref``.  An op added without an oracle — or
an oracle renamed out from under its op — is a build failure, not a
review nit.  Run by CI and by tests/test_kernels.py:

    PYTHONPATH=src python tools/lint_kernel_oracles.py
"""

from __future__ import annotations

import inspect
import sys


def check() -> list[str]:
    from repro.kernels import ops, ref

    errors = []
    public = sorted(
        name for name, fn in vars(ops).items()
        if inspect.isfunction(fn) and not name.startswith("_")
        and fn.__module__ == ops.__name__)
    for name in public:
        if name not in ops.ORACLES:
            errors.append(
                f"ops.{name} has no entry in ops.ORACLES — every public "
                f"op must name its ref.py oracle")
    for op_name, ref_name in ops.ORACLES.items():
        if op_name not in public:
            errors.append(
                f"ops.ORACLES names {op_name!r}, which is not a public "
                f"function defined in kernels/ops.py")
        oracle = getattr(ref, ref_name, None)
        if not callable(oracle):
            errors.append(
                f"oracle ref.{ref_name} (for ops.{op_name}) does not "
                f"exist in kernels/ref.py or is not callable")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        from repro.kernels import ops
        print(f"ok: {len(ops.ORACLES)} ops, each naming a live ref.py "
              f"oracle")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
