"""Lint: the kernels/ops.py dispatch surface and the kernels/ref.py
oracle set must not drift.

Every public op defined in ``repro.kernels.ops`` must name its pure-jnp
reference in ``ops.ORACLES``, and every named oracle must exist (and be
callable) in ``repro.kernels.ref``.  An op added without an oracle — or
an oracle renamed out from under its op — is a build failure, not a
review nit.  Additionally every public ``*_pallas`` entry point in the
kernels package must be referenced by ops.py — a kernel nobody
dispatches (e.g. a gather/densify variant orphaned by a refactor) is
dead weight that silently escapes the parity tests routed through ops.
Run by CI and by tests/test_kernels.py:

    PYTHONPATH=src python tools/lint_kernel_oracles.py
"""

from __future__ import annotations

import inspect
import sys


def check() -> list[str]:
    from repro.kernels import ops, ref

    errors = []
    public = sorted(
        name for name, fn in vars(ops).items()
        if inspect.isfunction(fn) and not name.startswith("_")
        and fn.__module__ == ops.__name__)
    for name in public:
        if name not in ops.ORACLES:
            errors.append(
                f"ops.{name} has no entry in ops.ORACLES — every public "
                f"op must name its ref.py oracle")
    for op_name, ref_name in ops.ORACLES.items():
        if op_name not in public:
            errors.append(
                f"ops.ORACLES names {op_name!r}, which is not a public "
                f"function defined in kernels/ops.py")
        oracle = getattr(ref, ref_name, None)
        if not callable(oracle):
            errors.append(
                f"oracle ref.{ref_name} (for ops.{op_name}) does not "
                f"exist in kernels/ref.py or is not callable")
    errors += _check_orphan_kernels(ops)
    return errors


def _check_orphan_kernels(ops) -> list[str]:
    """Every public ``*_pallas`` callable in the kernels package must be
    reachable from the dispatch surface: referenced by ops.py, or called
    as a stage of another kernel in its own module.  Anything else is
    dead dispatch surface the ops-routed parity tests can never reach."""
    import importlib
    import pkgutil
    import re

    import repro.kernels as pkg

    errors = []
    ops_src = inspect.getsource(ops)
    for info in pkgutil.iter_modules(pkg.__path__):
        if info.name in ("ops", "ref"):
            continue
        mod = importlib.import_module(f"repro.kernels.{info.name}")
        mod_src = inspect.getsource(mod)
        for name, fn in vars(mod).items():
            if not (callable(fn) and not name.startswith("_")
                    and name.endswith("_pallas")
                    and getattr(fn, "__module__", None) == mod.__name__):
                continue
            called_locally = re.search(
                rf"(?<!def ){re.escape(name)}\(", mod_src)
            if name not in ops_src and not called_locally:
                errors.append(
                    f"{mod.__name__}.{name} is a public Pallas entry "
                    f"point that neither ops.py nor its own module "
                    f"calls — dispatch it from an op (or make it "
                    f"private)")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if not errors:
        from repro.kernels import ops
        print(f"ok: {len(ops.ORACLES)} ops, each naming a live ref.py "
              f"oracle")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
