"""End-to-end driver: train a ~100M-parameter LM with a MACH head.

A scaled tinyllama-family config (~100M params) trains for a few hundred
steps on the synthetic token stream, with checkpointing + restart safety
— the full production path (trainer, optimizer, data pipeline, fault
tolerance) at laptop scale.  The MACH head replaces the full-softmax
unembedding: with V=32,000 and B=512, R=8 the head is 7.8x smaller and
the loss is the paper's R-head hashed cross-entropy.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core.mach import MACHConfig
from repro.data import LMDataConfig, SyntheticLMStream
from repro.models import LanguageModel, ModelConfig
from repro.train.fault_tolerance import StragglerMonitor
from repro.train.trainer import TrainConfig, Trainer


def model_config(vocab: int, mach: bool) -> ModelConfig:
    return ModelConfig(
        name="lm100m", family="dense",
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab_size=vocab,
        activation="swiglu", norm="rmsnorm",
        mach=MACHConfig(vocab, 512, 8) if mach else None,
        dtype=jnp.float32, scan_layers=True, remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=32000)
    ap.add_argument("--oaa", action="store_true",
                    help="full-softmax baseline head instead of MACH")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = model_config(args.vocab, mach=not args.oaa)
    model = LanguageModel(cfg)
    n_params = cfg.param_count_estimate()
    head = "MACH(B=512,R=8)" if cfg.mach else "full softmax"
    print(f"model: {n_params/1e6:.0f}M params, head: {head}")
    if cfg.mach:
        full = cfg.d_model * cfg.vocab_size
        machp = cfg.d_model * 512 * 8
        print(f"head params: {machp/1e6:.1f}M vs {full/1e6:.1f}M "
              f"({full/machp:.1f}x smaller)")

    tcfg = TrainConfig(total_steps=args.steps, warmup_steps=20,
                       peak_lr=3e-4, checkpoint_every=100, log_every=20)
    trainer = Trainer(model, tcfg)
    stream = SyntheticLMStream(LMDataConfig(
        vocab_size=args.vocab, seq_len=args.seq_len,
        global_batch=args.batch))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()

    # restart-safe: resume from the latest checkpoint if one exists
    template = trainer.init_state(jax.random.key(0))
    try:
        state, step0 = mgr.restore(template)
        print(f"resumed from checkpoint at step {step0}")
    except FileNotFoundError:
        state = template

    t0 = time.perf_counter()
    state = trainer.fit(state, stream, args.steps - int(state.step),
                        manager=mgr, monitor=mon)
    dt = time.perf_counter() - t0
    tok_s = args.batch * args.seq_len * args.steps / max(dt, 1e-9)
    print(f"\ndone: {dt:.0f}s  ({tok_s:,.0f} tok/s on CPU)  "
          f"stragglers flagged: {len(mon.flagged)}")


if __name__ == "__main__":
    main()
