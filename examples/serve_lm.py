"""Serve a small LM with continuous batching and fused MACH decode.

Builds a reduced recurrentgemma-family model (extreme 256-class-per-
bucket vocab head would be silly at toy scale, so V=4096, B=256, R=6),
submits typed ``Request``s of very different lengths, and serves them
with the slot engine: per-request prefill scattered into a fixed
4-slot decode pool, every step advancing all live slots through the
paper's never-materialize top-k kernel.  Short requests free their slot
the moment they finish and queued requests are admitted into it — watch
``metrics.occupancy`` stay high even though the workload is ragged.

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --page-size 16  # paged KV pool
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.mach import MACHConfig
from repro.models import LanguageModel, ModelConfig
from repro.serving import Request, SamplingParams, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: tokens per page (0: contiguous "
                         "per-slot strips)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared KV page-pool size (0: derive "
                         "slots * ceil(max_len / page_size))")
    args = ap.parse_args()
    # paged demo swaps the local-attention block (its O(window) ring
    # cache never pages) for full attention so the page pool carries KV
    attn_kind = "attn" if args.page_size else "attn_local"
    cfg = ModelConfig(
        name="serve-demo", family="hybrid",
        num_layers=6, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=4096,
        block_pattern=("rglru", "rglru", attn_kind), local_window=64,
        rnn_width=256, activation="geglu",
        mach=MACHConfig(4096, 256, 6),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    print(f"model: {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M "
          f"params, MACH head B=256 R=6 over V=4096 "
          f"(decode never materializes the (batch, V) logits)")

    engine = ServingEngine(model, params,
                           ServeConfig(max_len=128, num_slots=4,
                                       max_new_tokens=16,
                                       page_size=args.page_size,
                                       num_pages=args.num_pages))
    prompts = [
        [12, 99, 1034, 7],
        [5, 6],
        [2048, 77, 300, 41, 18, 9],
        [1, 2, 3],
        [400, 500],
    ]
    # ragged per-request budgets: short ones free their slot early and
    # the 5th prompt is admitted mid-decode (continuous batching)
    budgets = [16, 4, 16, 6, 16]
    for p, n in zip(prompts, budgets):
        engine.submit(Request(prompt=p, max_new_tokens=n))

    t0 = time.perf_counter()
    outs = engine.run()
    dt = time.perf_counter() - t0
    for p, r in zip(prompts, outs):
        print(f"prompt {p} -> {list(r.tokens)} ({r.finish_reason}, "
              f"{r.latency_steps} ticks)")
    m = engine.metrics
    print(f"\n{len(prompts)} requests, {m.tokens_generated} tokens in "
          f"{dt:.1f}s ({m.tokens_generated/dt:.1f} tok/s on CPU, greedy, "
          f"{m.decode_steps} decode steps over 4 slots, "
          f"occupancy {m.occupancy:.2f})")
    if args.page_size:
        print(f"page pool: {m.num_pages} pages x {args.page_size} tokens, "
              f"peak {m.pages_peak} reserved, "
              f"{m.reservation_failures} reservation stalls")

    # sampled decoding: per-request temperature/top-k/seed, still on the
    # fused streaming top-k path (no (batch, V) tensor anywhere) — an
    # explicit seed makes a request's continuation independent of its
    # batch neighbours and slot placement
    sampler = ServingEngine(model, params,
                            ServeConfig(max_len=128, num_slots=4,
                                        max_new_tokens=16, top_k=16,
                                        seed=0, page_size=args.page_size,
                                        num_pages=args.num_pages))
    for i, p in enumerate(prompts[:4]):
        sampler.submit(Request(
            prompt=p,
            sampling=SamplingParams(temperature=0.7 + 0.1 * i, top_k=8,
                                    seed=100 + i)))
    for p, r in zip(prompts, sampler.run()):
        print(f"sampled {p} -> {list(r.tokens)}")


if __name__ == "__main__":
    main()
