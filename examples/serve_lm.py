"""Serve a small LM with batched requests and fused MACH decode.

Builds a reduced recurrentgemma-family model (extreme 256-class-per-
bucket vocab head would be silly at toy scale, so V=4096, B=256, R=6),
queues a handful of prompts of different lengths, and serves them with
the batching engine: left-padded lockstep prefill + per-token decode
through the paper's summed-score rule.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core.mach import MACHConfig
from repro.models import LanguageModel, ModelConfig
from repro.serving import ServeConfig, ServingEngine


def main():
    cfg = ModelConfig(
        name="serve-demo", family="hybrid",
        num_layers=6, d_model=256, num_heads=4, num_kv_heads=1,
        d_ff=512, vocab_size=4096,
        block_pattern=("rglru", "rglru", "attn_local"), local_window=64,
        rnn_width=256, activation="geglu",
        mach=MACHConfig(4096, 256, 6),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    print(f"model: {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M "
          f"params, MACH head B=256 R=6 over V=4096 "
          f"(decode never materializes the (batch, V) logits)")

    engine = ServingEngine(model, params,
                           ServeConfig(max_len=128, batch_size=4,
                                       max_new_tokens=16))
    prompts = [
        [12, 99, 1034, 7],
        [5, 6],
        [2048, 77, 300, 41, 18, 9],
        [1, 2, 3],
        [400, 500],
    ]
    for p in prompts:
        engine.add_request(p)

    t0 = time.perf_counter()
    outs = engine.run()
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o}")
    print(f"\n{len(prompts)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s on CPU, greedy, batch=4)")

    # sampled decoding: per-request temperature/top-k, still on the
    # fused streaming top-k path (no (batch, V) tensor anywhere)
    sampler = ServingEngine(model, params,
                            ServeConfig(max_len=128, batch_size=4,
                                        max_new_tokens=16, top_k=16,
                                        seed=0))
    for i, p in enumerate(prompts[:4]):
        sampler.add_request(p, {"temperature": 0.7 + 0.1 * i, "top_k": 8})
    for p, o in zip(prompts, sampler.run()):
        print(f"sampled {p} -> {o}")


if __name__ == "__main__":
    main()
