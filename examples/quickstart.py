"""Quickstart: MACH in 60 seconds.

Trains the paper's model — R independent B-way logistic regressions over
hashed labels — on a synthetic extreme-classification task with a known
Bayes optimum, then decodes with the unbiased estimator (Eq. 2) and
compares against the one-vs-all baseline at several memory budgets.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import MACHConfig, MACHLinear, OAAClassifier
from repro.data import ExtremeDataConfig, ExtremeDataset
from repro.optim import adamw, apply_updates

K, D, STEPS, BS = 1024, 256, 150, 512


def train(ds, model, params, lr=0.05):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    t0 = time.perf_counter()
    for s in range(STEPS):
        x, y = ds.batch_at(s, BS)
        params, state, loss = step(params, state, x, y)
    jax.block_until_ready(params)
    return params, time.perf_counter() - t0


def accuracy(ds, predict):
    accs = []
    for s in range(4):
        x, y = ds.batch_at(5000 + s, BS, "test")
        accs.append(float(jnp.mean(predict(x) == y)))
    return sum(accs) / len(accs)


def main():
    ds = ExtremeDataset(ExtremeDataConfig(num_classes=K, dim=D, noise=0.1,
                                          zipf_a=0.0))
    print(f"task: K={K} classes, d={D}, Bayes accuracy ≈ "
          f"{ds.bayes_accuracy(steps=2):.3f}\n")

    oaa = OAAClassifier(K, D)
    po, t = train(ds, oaa, oaa.init(jax.random.key(1)))
    acc_o = accuracy(ds, lambda x: oaa.predict(po, x))
    print(f"OAA baseline     params={oaa.param_count():>8,}  "
          f"acc={acc_o:.3f}  ({t:.1f}s)")

    for b, r in [(32, 4), (64, 4), (64, 8)]:
        cfg = MACHConfig(K, b, r)
        m = MACHLinear(cfg, D)
        pm, t = train(ds, m, m.init(jax.random.key(0)))
        acc = accuracy(ds, lambda x: m.predict(pm, x))
        print(f"MACH B={b:3d} R={r}  params={m.param_count():>8,}  "
              f"acc={acc:.3f}  ({t:.1f}s)  "
              f"size_reduction={oaa.param_count()/m.param_count():.1f}x  "
              f"P(indistinguishable pair)<= {cfg.indistinguishable_bound():.1e}")

    print("\nAt full ODP scale (K=105,033, d=422,713) the same B=32, R=25 "
          "configuration is a 131x model-size reduction (160 GB -> 1.2 GB).")


if __name__ == "__main__":
    main()
