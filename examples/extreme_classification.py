"""Paper reproduction driver: the ODP / ImageNet-21k experiments.

Runs the reduced-scale stand-ins of the paper's two benchmarks (the
datasets themselves are not offline-redistributable; the synthetic task
has a *known Bayes optimum*, which the paper's datasets lack) and prints
the paper-style report: accuracy at each (B, R), model-size reduction,
all three estimators, plus the full-scale arithmetic of Table 2.

    PYTHONPATH=src python examples/extreme_classification.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.odp_mach import IMAGENET, ODP
from repro.core import MACHConfig, MACHLinear
from repro.data import ExtremeDataConfig, ExtremeDataset
from repro.optim import adamw, apply_updates


def train(ds, model, params, steps=150, bs=512, lr=0.05):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    for s in range(steps):
        x, y = ds.batch_at(s, bs)
        params, state, _ = step(params, state, x, y)
    return params


def accuracy(ds, predict, bs=512):
    accs = []
    for s in range(4):
        x, y = ds.batch_at(9000 + s, bs, "test")
        accs.append(float(jnp.mean(predict(x) == y)))
    return sum(accs) / len(accs)


def main():
    for task in (ODP, IMAGENET):
        print(f"=== {task.name}: full scale K={task.num_classes:,} "
              f"d={task.dim:,} B={task.mach_b} R={task.mach_r}")
        oaa_gb = task.num_classes * task.dim * 4 / 1e9
        mach_gb = task.mach_b * task.mach_r * task.dim * 4 / 1e9
        print(f"    model size: OAA {oaa_gb:.0f} GB -> MACH {mach_gb:.2f} GB "
              f"({oaa_gb/mach_gb:.0f}x reduction; paper reports "
              f"{'125x/0.3GB-480x' if task.name == 'odp' else '2x'})")

        ds = ExtremeDataset(ExtremeDataConfig(
            num_classes=task.small_classes, dim=task.small_dim, noise=0.1,
            zipf_a=1.0))
        cfg = task.mach(small=True)
        m = MACHLinear(cfg, task.small_dim)
        t0 = time.perf_counter()
        params = train(ds, m, m.init(jax.random.key(0)))
        t = time.perf_counter() - t0
        bayes = ds.bayes_accuracy(steps=2)
        print(f"    reduced-scale stand-in (K={task.small_classes}, "
              f"d={task.small_dim}, B={cfg.num_buckets}, "
              f"R={cfg.num_repetitions}; Zipf classes): "
              f"train {t:.0f}s, Bayes={bayes:.3f}")
        for est in ("unbiased", "min", "median"):
            acc = accuracy(ds, lambda x, e=est: m.predict(params, x,
                                                          estimator=e))
            marker = "   <- paper Eq. 2" if est == "unbiased" else ""
            print(f"      {est:9s} estimator: acc={acc:.3f}{marker}")
        print()


if __name__ == "__main__":
    main()
