"""Paper reproduction driver: the ODP / ImageNet-21k experiments.

Runs the reduced-scale stand-ins of the paper's two benchmarks (the
datasets themselves are not offline-redistributable; the synthetic task
has a *known Bayes optimum*, which the paper's datasets lack) and prints
the paper-style report: accuracy at each (B, R), model-size reduction,
all three estimators, plus the full-scale arithmetic of Table 2.

For sparse-feature tasks (ODP — bag-of-words, d=422k at full scale) the
driver additionally trains the SAME MACHLinear model twice on identical
Zipf-sparse data: once through the materializing dense path and once
through the fused CSR path (``MACHLinear(fused=True)`` on CSR batches,
no (n, R·B) logits and no dense (n, d) activation on TPU), reporting
both accuracies — the two must agree to within a couple of points at
equal steps, since the fused path computes identical gradients.

    PYTHONPATH=src python examples/extreme_classification.py
    PYTHONPATH=src python examples/extreme_classification.py --task odp --small
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.odp_mach import IMAGENET, ODP
from repro.core import MACHLinear
from repro.data import (ExtremeDataConfig, ExtremeDataset,
                        SparseExtremeDataset)
from repro.optim import adamw, apply_updates


def train(ds, model, params, steps=150, bs=512, lr=0.05, format=None):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    for s in range(steps):
        if format is None:
            x, y = ds.batch_at(s, bs)
        else:
            x, y = ds.batch_at(s, bs, format=format)
        params, state, _ = step(params, state, x, y)
    return params


def accuracy(ds, predict, bs=512, format=None):
    accs = []
    for s in range(4):
        if format is None:
            x, y = ds.batch_at(9000 + s, bs, "test")
        else:
            x, y = ds.batch_at(9000 + s, bs, "test", format=format)
        accs.append(float(jnp.mean(predict(x) == y)))
    return sum(accs) / len(accs)


def run_dense(task, steps):
    """The original paper-style report on the dense centroid stand-in."""
    ds = ExtremeDataset(ExtremeDataConfig(
        num_classes=task.small_classes, dim=task.small_dim, noise=0.1,
        zipf_a=1.0))
    cfg = task.mach(small=True)
    m = MACHLinear(cfg, task.small_dim)
    t0 = time.perf_counter()
    params = train(ds, m, m.init(jax.random.key(0)), steps=steps)
    t = time.perf_counter() - t0
    bayes = ds.bayes_accuracy(steps=2)
    print(f"    reduced-scale stand-in (K={task.small_classes}, "
          f"d={task.small_dim}, B={cfg.num_buckets}, "
          f"R={cfg.num_repetitions}; Zipf classes): "
          f"train {t:.0f}s, Bayes={bayes:.3f}")
    for est in ("unbiased", "min", "median"):
        acc = accuracy(ds, lambda x, e=est: m.predict(params, x,
                                                      estimator=e))
        marker = "   <- paper Eq. 2" if est == "unbiased" else ""
        print(f"      {est:9s} estimator: acc={acc:.3f}{marker}")


def run_sparse(task, steps):
    """Fused-CSR vs materializing-dense training on identical sparse
    data — the ODP §4 sparse-feature regime."""
    ds = SparseExtremeDataset(task.sparse_data(small=True))
    cfg = task.mach(small=True)
    nnz = ds.cfg.nnz
    print(f"    sparse stand-in (K={ds.cfg.num_classes}, "
          f"d={ds.cfg.num_features}, nnz={nnz}, B={cfg.num_buckets}, "
          f"R={cfg.num_repetitions}; Zipf features):")

    m_dense = MACHLinear(cfg, ds.cfg.num_features)
    m_fused = MACHLinear(cfg, ds.cfg.num_features, fused=True)
    init = m_dense.init(jax.random.key(0))

    t0 = time.perf_counter()
    p_dense = train(ds, m_dense, init, steps=steps, format="dense")
    t_dense = time.perf_counter() - t0
    t0 = time.perf_counter()
    p_fused = train(ds, m_fused, init, steps=steps, format="csr")
    t_fused = time.perf_counter() - t0

    acc_dense = accuracy(ds, lambda x: m_dense.predict(p_dense, x),
                         format="dense")
    acc_fused = accuracy(ds, lambda x: m_fused.predict(p_fused, x),
                         format="dense")
    delta = abs(acc_dense - acc_fused)
    print(f"      dense materializing path: acc={acc_dense:.3f} "
          f"({t_dense:.0f}s / {steps} steps)")
    print(f"      fused CSR path:           acc={acc_fused:.3f} "
          f"({t_fused:.0f}s / {steps} steps)")
    print(f"      |Δ| = {delta:.3f}  "
          f"{'OK (<= 0.02)' if delta <= 0.02 else 'DIVERGED'}")
    return delta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="all",
                    choices=["all", "odp", "imagenet21k"])
    ap.add_argument("--small", action="store_true",
                    help="reduced-scale stand-in (the only offline mode; "
                         "kept explicit for scripts)")
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    tasks = [t for t in (ODP, IMAGENET)
             if args.task in ("all", t.name)]
    ok = True
    for task in tasks:
        print(f"=== {task.name}: full scale K={task.num_classes:,} "
              f"d={task.dim:,} B={task.mach_b} R={task.mach_r}"
              f"{f' nnz~{task.nnz}' if task.sparse_features else ''}")
        oaa_gb = task.num_classes * task.dim * 4 / 1e9
        mach_gb = task.mach_b * task.mach_r * task.dim * 4 / 1e9
        print(f"    model size: OAA {oaa_gb:.0f} GB -> MACH {mach_gb:.2f} GB "
              f"({oaa_gb/mach_gb:.0f}x reduction; paper reports "
              f"{'125x/0.3GB-480x' if task.name == 'odp' else '2x'})")
        run_dense(task, args.steps)
        if task.sparse_features:
            ok = run_sparse(task, args.steps) <= 0.02 and ok
        print()
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
