"""Attention-path properties (hypothesis): the three implementations
(dense, jnp-flash, Pallas flash) agree across shapes/windows, and the
masking semantics hold."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.models.attention import (KVCache, _attend_dense, attend,
                                    cache_update_decode,
                                    cache_update_prefill, init_cache)


def _qkv(b, t, h, kv, hd, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(ks[0], (b, t, h, hd)),
            jax.random.normal(ks[1], (b, t, kv, hd)),
            jax.random.normal(ks[2], (b, t, kv, hd)))


@given(st.sampled_from([64, 128]), st.sampled_from([(4, 2), (4, 4), (8, 1)]),
       st.sampled_from([None, 16, 48]))
@settings(max_examples=10, deadline=None)
def test_flash_equals_dense(t, heads, window):
    h, kv = heads
    q, k, v = _qkv(2, t, h, kv, 32, seed=t + h)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (2, t))
    dense = attend(q, k, v, pos, pos, causal=True, window=window,
                   flash_threshold=1 << 62)
    flash = attend(q, k, v, pos, pos, causal=True, window=window,
                   flash_threshold=1, chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               rtol=2e-4, atol=2e-5)


def test_causality_property():
    """Changing future tokens never changes past outputs."""
    q, k, v = _qkv(1, 32, 4, 2, 16, seed=3)
    pos = jnp.arange(32)[None]
    base = attend(q, k, v, pos, pos, causal=True)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-7.0)
    pert = attend(q, k2, v2, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(base[:, :20]),
                               np.asarray(pert[:, :20]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(base[:, 20:]), np.asarray(pert[:, 20:]))


def test_window_property():
    """With window w, tokens more than w in the past have no influence."""
    w = 8
    q, k, v = _qkv(1, 32, 2, 2, 16, seed=4)
    pos = jnp.arange(32)[None]
    base = attend(q, k, v, pos, pos, causal=True, window=w)
    k2 = k.at[:, :16].set(5.0)       # outside the window of position >= 24
    v2 = v.at[:, :16].set(5.0)
    pert = attend(q, k2, v2, pos, pos, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(base[:, 24:]),
                               np.asarray(pert[:, 24:]), rtol=1e-5, atol=1e-6)


def test_empty_cache_slots_are_masked():
    """Decode against a cache with unwritten (-1 position) slots ignores
    them completely."""
    cache = init_cache(batch=2, capacity=16, num_kv=2, head_dim=8,
                       dtype=jnp.float32)
    k = jax.random.normal(jax.random.key(0), (2, 4, 2, 8))
    v = jax.random.normal(jax.random.key(1), (2, 4, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(4)[None], (2, 4))
    cache = cache_update_prefill(cache, k, v, pos)
    # poison unwritten slots: must not affect output
    poisoned = cache._replace(k=cache.k.at[:, 4:].set(1e4),
                              v=cache.v.at[:, 4:].set(1e4))
    from repro.models.attention import decode_attend
    q1 = jax.random.normal(jax.random.key(2), (2, 1, 4, 8))
    np.testing.assert_allclose(
        np.asarray(decode_attend(q1, cache)),
        np.asarray(decode_attend(q1, poisoned)), rtol=1e-6)


def test_ring_cache_invariant():
    """Ring-buffer invariant (position p at slot p mod cap) holds through
    a long prefill followed by decode writes."""
    cap = 8
    cache = init_cache(batch=1, capacity=cap, num_kv=1, head_dim=4,
                       dtype=jnp.float32)
    t = 19                                # > cap: trailing window kept
    k = jnp.arange(t, dtype=jnp.float32).reshape(1, t, 1, 1) \
        * jnp.ones((1, t, 1, 4))
    pos = jnp.arange(t)[None]
    cache = cache_update_prefill(cache, k, k, pos)
    for step in range(3):
        p = t + step
        k1 = jnp.full((1, 1, 1, 4), float(p))
        cache = cache_update_decode(cache, k1, k1, ring=True)
        np_pos = np.asarray(cache.positions[0])
        for slot in range(cap):
            if np_pos[slot] >= 0:
                assert np_pos[slot] % cap == slot
                assert float(cache.k[0, slot, 0, 0]) == float(np_pos[slot])
