"""Serving engine: batched requests end-to-end, MACH greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, mach_meta_probs
from repro.core.estimators import predict_classes
from repro.models import LanguageModel, ModelConfig
from repro.serving import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def served():
    cfg = ModelConfig(name="srv", num_layers=2, d_model=48, num_heads=4,
                      num_kv_heads=2, d_ff=96, vocab_size=200,
                      dtype=jnp.float32, mach=MACHConfig(200, 16, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_batched_requests(served):
    cfg, model, params = served
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=32, batch_size=4,
                                    max_new_tokens=6))
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    for p in prompts:
        eng.add_request(p)
    outs = eng.run()
    assert len(outs) == len(prompts)
    for seq in outs:
        assert len(seq) == 6
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_greedy_decode_matches_reference(served):
    """Engine's next_token (fused kernel path on TPU; ref on CPU) equals
    the paper's Algorithm-2 argmax on the same hidden states."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(3), (5, cfg.d_model))
    ids, _ = model.next_token(params, h)
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    want = predict_classes(meta, cfg.mach.table(), "unbiased")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_oaa_serving_parity():
    """Same engine logic with the OAA head (argmax over full logits)."""
    cfg = ModelConfig(name="srv2", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=50,
                      dtype=jnp.float32)
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(1))
    h = jax.random.normal(jax.random.key(2), (3, 32))
    ids, vals = model.next_token(params, h)
    logits = model.oaa_logits(params, h)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_lockstep_decode_positions(served):
    """Engine left-pads prompts so the batch decodes in lockstep —
    decode output at each step is finite and cache positions advance."""
    cfg, model, params = served
    toks = jnp.asarray([[0, 0, 1, 2], [3, 4, 5, 6]], jnp.int32)
    caches, enc_kvs, h = model.prefill(params, {"tokens": toks}, max_len=16)
    ids, _ = model.next_token(params, h)
    for i in range(3):
        pos = jnp.full((2,), 4 + i, jnp.int32)
        caches, h = model.decode_step(params, caches, enc_kvs, ids, pos)
        ids, _ = model.next_token(params, h)
        assert bool(jnp.all(jnp.isfinite(h)))
    # first stack's cache index advanced by prefill + 3 decodes
    kv = caches[0][0]
    assert int(kv.index[0, 0]) == 4 + 3


def test_sample_token_topk(served):
    """Sampling stays within the top-k support and is temperature-
    sensitive; MACH and OAA paths both work."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(9), (4, cfg.d_model))
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    from repro.kernels import ops
    scores = ops.mach_scores(jnp.moveaxis(meta, 0, 1), cfg.mach.table())
    topk_sets = [set(np.asarray(jax.lax.top_k(scores[i], 5)[1]).tolist())
                 for i in range(4)]
    for seed in range(6):
        s = model.sample_token(params, h, jax.random.key(seed),
                               temperature=0.8, top_k=5)
        for i in range(4):
            assert int(s[i]) in topk_sets[i]
    # near-zero temperature == greedy
    greedy, _ = model.next_token(params, h)
    s0 = model.sample_token(params, h, jax.random.key(0),
                            temperature=1e-6, top_k=5)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(greedy))
