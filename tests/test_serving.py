"""Serving engine: batched requests end-to-end, MACH greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, mach_meta_probs
from repro.core.estimators import predict_classes
from repro.models import LanguageModel, ModelConfig
from repro.serving import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def served():
    cfg = ModelConfig(name="srv", num_layers=2, d_model=48, num_heads=4,
                      num_kv_heads=2, d_ff=96, vocab_size=200,
                      dtype=jnp.float32, mach=MACHConfig(200, 16, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_batched_requests(served):
    cfg, model, params = served
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=32, batch_size=4,
                                    max_new_tokens=6))
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    for p in prompts:
        eng.add_request(p)
    outs = eng.run()
    assert len(outs) == len(prompts)
    for seq in outs:
        assert len(seq) == 6
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_greedy_decode_matches_reference(served):
    """Engine's next_token (fused kernel path on TPU; ref on CPU) equals
    the paper's Algorithm-2 argmax on the same hidden states."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(3), (5, cfg.d_model))
    ids, _ = model.next_token(params, h)
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    want = predict_classes(meta, cfg.mach.table(), "unbiased")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_oaa_serving_parity():
    """Same engine logic with the OAA head (argmax over full logits)."""
    cfg = ModelConfig(name="srv2", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=50,
                      dtype=jnp.float32)
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(1))
    h = jax.random.normal(jax.random.key(2), (3, 32))
    ids, vals = model.next_token(params, h)
    logits = model.oaa_logits(params, h)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_lockstep_decode_positions(served):
    """Engine left-pads prompts so the batch decodes in lockstep —
    decode output at each step is finite and cache positions advance."""
    cfg, model, params = served
    toks = jnp.asarray([[0, 0, 1, 2], [3, 4, 5, 6]], jnp.int32)
    caches, enc_kvs, h = model.prefill(params, {"tokens": toks}, max_len=16)
    ids, _ = model.next_token(params, h)
    for i in range(3):
        pos = jnp.full((2,), 4 + i, jnp.int32)
        caches, h = model.decode_step(params, caches, enc_kvs, ids, pos)
        ids, _ = model.next_token(params, h)
        assert bool(jnp.all(jnp.isfinite(h)))
    # first stack's cache index advanced by prefill + 3 decodes
    kv = caches[0][0]
    assert int(kv.index[0, 0]) == 4 + 3


def test_greedy_decode_honors_estimator():
    """With a min/median MACHConfig, next_token must follow the
    configured prediction rule (k=1 streaming kernel), not the
    summed-score rule — and greedy rows inside a mixed sampled batch
    must produce the same tokens as a pure-greedy batch."""
    cfg = ModelConfig(name="srv3", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=120,
                      dtype=jnp.float32,
                      mach=MACHConfig(120, 16, 5, estimator="median"))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(4))
    h = jax.random.normal(jax.random.key(5), (4, 32))
    ids, _ = model.next_token(params, h)
    meta = mach_meta_probs(model.mach_logits(params, h).astype(jnp.float32))
    want = predict_classes(meta, cfg.mach.table(), "median")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))

    pure = ServingEngine(model, params,
                         ServeConfig(max_len=16, batch_size=2,
                                     max_new_tokens=3))
    pure.add_request([3, 7])
    pure.add_request([9])
    want_seq = pure.run()[0]
    mixed = ServingEngine(model, params,
                          ServeConfig(max_len=16, batch_size=2,
                                      max_new_tokens=3, seed=2))
    mixed.add_request([3, 7])                          # greedy row
    mixed.add_request([9], {"temperature": 1.1, "top_k": 6})
    assert mixed.run()[0] == want_seq


def test_sampling_knobs_row_semantics(served):
    """A top_k-only request samples (temp 1.0, its k); only rows with
    no sampling knobs at all degrade to greedy in a mixed batch."""
    cfg, model, params = served
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=16, batch_size=3,
                                    max_new_tokens=2, top_k=8))
    chunk = [([1], {"top_k": 4}),            # sampling, default temp 1.0
             ([2], {}),                      # greedy row
             ([3], {"temperature": 0.3})]    # sampling, default k cap
    temps, row_k = eng._sampling_knobs(chunk)
    np.testing.assert_allclose(np.asarray(temps), [1.0, 1e-6, 0.3])
    np.testing.assert_array_equal(np.asarray(row_k), [4, 1, 8])
    # all-greedy chunk -> no sampling path at all
    assert eng._sampling_knobs([([1], {}), ([2], {})]) is None


def test_engine_sampling_mode(served):
    """Engine-level sampling (fused streaming top-k path): per-request
    temperature/top-k, deterministic under a fixed seed."""
    cfg, model, params = served
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10]]

    def run_once():
        eng = ServingEngine(model, params,
                            ServeConfig(max_len=32, batch_size=4,
                                        max_new_tokens=5, temperature=0.9,
                                        top_k=8, seed=42))
        for i, p in enumerate(prompts):
            eng.add_request(p, {"temperature": 0.5 + 0.2 * i,
                                "top_k": 2 + i})
        return eng.run()

    outs1, outs2 = run_once(), run_once()
    assert outs1 == outs2                      # same seed -> same samples
    assert len(outs1) == len(prompts)
    for seq in outs1:
        assert len(seq) == 5
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_engine_fresh_keys_across_runs(served):
    """Successive run() calls on one engine must draw fresh PRNG keys:
    resubmitting the same sampled prompt should not replay the identical
    'random' continuation every call."""
    cfg, model, params = served
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=32, batch_size=1,
                                    max_new_tokens=6, temperature=1.5,
                                    top_k=8, seed=0))
    outs = []
    for _ in range(3):
        eng.add_request([1, 2, 3])
        outs.append(tuple(eng.run()[0]))
    assert len(set(outs)) > 1, outs


def test_engine_mixed_greedy_and_sampled_chunk(served):
    """A greedy request batched with sampled ones must still produce its
    greedy continuation (temperature ~0 over the top-1 candidate)."""
    cfg, model, params = served
    greedy_eng = ServingEngine(model, params,
                               ServeConfig(max_len=32, batch_size=2,
                                           max_new_tokens=4))
    greedy_eng.add_request([3, 1, 4])
    greedy_eng.add_request([2, 7])
    want = greedy_eng.run()[0]

    mixed = ServingEngine(model, params,
                          ServeConfig(max_len=32, batch_size=2,
                                      max_new_tokens=4, seed=7))
    mixed.add_request([3, 1, 4])                       # greedy row
    mixed.add_request([2, 7], {"temperature": 1.2, "top_k": 6})
    outs = mixed.run()
    assert outs[0] == want


def test_sample_token_topk(served):
    """Sampling stays within the top-k support and is temperature-
    sensitive; MACH and OAA paths both work."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(9), (4, cfg.d_model))
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    from repro.kernels import ops
    scores = ops.mach_scores(jnp.moveaxis(meta, 0, 1), cfg.mach.table())
    topk_sets = [set(np.asarray(jax.lax.top_k(scores[i], 5)[1]).tolist())
                 for i in range(4)]
    for seed in range(6):
        s = model.sample_token(params, h, jax.random.key(seed),
                               temperature=0.8, top_k=5)
        for i in range(4):
            assert int(s[i]) in topk_sets[i]
    # near-zero temperature == greedy
    greedy, _ = model.next_token(params, h)
    s0 = model.sample_token(params, h, jax.random.key(0),
                            temperature=1e-6, top_k=5)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(greedy))


def test_sample_token_row_top_k_zero_clamped(served):
    """row_top_k=0 used to mask every candidate to -inf, making
    jax.random.categorical return an undefined index; it now clamps to
    1, i.e. the row degrades to its top-1 candidate (greedy)."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(21), (3, cfg.d_model))
    greedy, _ = model.next_token(params, h)
    for seed in range(4):
        s = model.sample_token(params, h, jax.random.key(seed),
                               temperature=1.0, top_k=5,
                               row_top_k=jnp.zeros((3,), jnp.int32))
        assert bool(jnp.all((s >= 0) & (s < cfg.vocab_size)))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(greedy))
    # mixed row_top_k: the 0 row is clamped, others unaffected
    s = model.sample_token(params, h, jax.random.key(0), temperature=1e-6,
                           top_k=5, row_top_k=jnp.asarray([0, 3, 1]))
    np.testing.assert_array_equal(np.asarray(s[0]), np.asarray(greedy[0]))
    np.testing.assert_array_equal(np.asarray(s[2]), np.asarray(greedy[2]))


def test_engine_rejects_zero_top_k_cap(served):
    cfg, model, params = served
    with pytest.raises(ValueError):
        ServingEngine(model, params, ServeConfig(top_k=0))


def test_sample_token_matches_legacy_summed_score_distribution(served):
    """The fused path must reproduce the historical sampling semantics
    exactly: categorical over softmax(summed scores / T) (Eq. 2's affine
    scale is divided back out, so tuned temperatures keep meaning)."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(13), (4, cfg.d_model))
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    from repro.kernels import ops
    scores = ops.mach_scores(jnp.moveaxis(meta, 0, 1), cfg.mach.table())
    for seed in range(5):
        for temp in (0.5, 0.7, 1.3):
            vals, idxs = jax.lax.top_k(scores, 5)           # legacy path
            gk = jax.random.categorical(jax.random.key(seed), vals / temp)
            legacy = jnp.take_along_axis(idxs, gk[:, None], axis=-1)[:, 0]
            fused = model.sample_token(params, h, jax.random.key(seed),
                                       temperature=temp, top_k=5)
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(legacy),
                                          err_msg=f"seed={seed} T={temp}")
