"""Serving: slot-scheduled continuous batching engine + MACH decode."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, mach_meta_probs
from repro.core.estimators import predict_classes
from repro.kernels import ops
from repro.models import LanguageModel, ModelConfig
from repro.models import attention as attn_lib
from repro.serving import (GenerationResult, Request, SamplingParams,
                           ServeConfig, ServingEngine)
from repro.serving.engine import make_serve_step_fn


@pytest.fixture(scope="module")
def served():
    cfg = ModelConfig(name="srv", num_layers=2, d_model=48, num_heads=4,
                      num_kv_heads=2, d_ff=96, vocab_size=200,
                      dtype=jnp.float32, mach=MACHConfig(200, 16, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def served_enc_dec():
    cfg = ModelConfig(name="srv-ed", num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=4, d_ff=64, vocab_size=120,
                      family="enc_dec", num_encoder_layers=2,
                      frontend="audio", dtype=jnp.float32,
                      mach=MACHConfig(120, 16, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(1))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_new_tokens", 6)
    return ServingEngine(model, params, ServeConfig(**kw))


def _reference_decode(model, params, prompt, n, max_len=32, extras=None):
    """Per-request greedy decode straight off the model API — the
    engine must match it token for token (no padding, no batching
    effects)."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    if extras:
        batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
    caches, enc_kvs, h = model.prefill(params, batch, max_len)
    ids, _ = model.next_token(params, h)
    toks = [int(ids[0])]
    pos = len(prompt)
    for _ in range(n - 1):
        caches, h = model.decode_step(params, caches, enc_kvs,
                                      jnp.asarray([toks[-1]], jnp.int32),
                                      jnp.asarray([pos], jnp.int32))
        ids, _ = model.next_token(params, h)
        toks.append(int(ids[0]))
        pos += 1
    return toks


# ---------------------------------------------------------------------------
# engine basics
# ---------------------------------------------------------------------------

def test_engine_batched_requests(served):
    cfg, model, params = served
    eng = _engine(model, params)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    ids = [eng.submit(Request(prompt=p)) for p in prompts]
    assert ids == list(range(5))
    outs = eng.run()
    assert [r.request_id for r in outs] == ids        # submission order
    for r in outs:
        assert isinstance(r, GenerationResult)
        assert len(r.tokens) == 6
        assert r.finish_reason == "length"
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
    m = eng.metrics
    assert m.prefills == 5 and m.completed == 5
    assert m.tokens_generated == 30
    assert eng.queue_depth == 0


def test_greedy_slot_engine_matches_reference_decode(served):
    """Token-level parity between the slot engine and a per-request
    reference decode: per-request prefill + per-slot cache writes mean
    scheduling cannot change a request's tokens."""
    cfg, model, params = served
    eng = _engine(model, params, num_slots=2)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10], [11, 12]]
    for p in prompts:
        eng.submit(Request(prompt=p))
    outs = eng.run()
    for p, r in zip(prompts, outs):
        assert list(r.tokens) == _reference_decode(model, params, p, 6), p


def test_slot_reuse_ragged_workload(served):
    """Short requests finish, free their slot, and queued requests are
    admitted mid-decode — visible in the metrics and in strictly fewer
    decode steps than the lockstep baseline (identical tokens)."""
    cfg, model, params = served
    reqs = [([1, 2, 3], 6), ([4, 5], 2), ([6, 7, 8, 9], 6),
            ([10], 2), ([11, 12], 4)]
    runs = {}
    for sched in ("continuous", "lockstep"):
        eng = _engine(model, params, num_slots=2, scheduler=sched)
        for p, mn in reqs:
            eng.submit(Request(prompt=p, max_new_tokens=mn))
        runs[sched] = (eng.run(), eng.metrics)
    cont_out, cont_m = runs["continuous"]
    lock_out, lock_m = runs["lockstep"]
    assert [r.tokens for r in cont_out] == [r.tokens for r in lock_out]
    assert cont_m.decode_steps < lock_m.decode_steps
    # 5 requests over 2 slots: slots were reused mid-decode
    assert cont_m.prefills == 5 and cont_m.completed == 5
    assert cont_m.occupancy > lock_m.occupancy
    for (_, mn), r in zip(reqs, cont_out):
        assert len(r.tokens) == mn
    # latency: the 2-token request finished well before the long ones
    lat = {r.request_id: r.latency_steps for r in cont_out}
    assert lat[1] < lat[2]


def test_eos_frees_slot_immediately(served):
    cfg, model, params = served
    base = _engine(model, params, num_slots=1, max_new_tokens=6)
    base.submit(Request(prompt=[3, 1, 4]))
    base.submit(Request(prompt=[2, 7]))
    outs = base.run()
    steps_no_eos = base.metrics.decode_steps
    eos = outs[0].tokens[2]                       # appears mid-stream
    eng = _engine(model, params, num_slots=1, max_new_tokens=6,
                  eos_id=int(eos))
    eng.submit(Request(prompt=[3, 1, 4]))
    eng.submit(Request(prompt=[2, 7]))
    outs2 = eng.run()
    cut = list(outs[0].tokens).index(eos)
    assert outs2[0].finish_reason == "eos"
    assert list(outs2[0].tokens) == list(outs[0].tokens)[:cut + 1]
    assert eng.metrics.decode_steps < steps_no_eos


def test_max_new_tokens_one_finishes_at_prefill(served):
    cfg, model, params = served
    eng = _engine(model, params, num_slots=1)
    eng.submit(Request(prompt=[1, 2], max_new_tokens=1))
    eng.submit(Request(prompt=[3, 4], max_new_tokens=1))
    outs = eng.run()
    assert [len(r.tokens) for r in outs] == [1, 1]
    assert eng.metrics.decode_steps == 0          # never occupied a slot
    for p, r in zip([[1, 2], [3, 4]], outs):
        assert list(r.tokens) == _reference_decode(model, params, p, 1)


def test_on_token_streaming_callback(served):
    cfg, model, params = served
    seen = []
    eng = _engine(model, params)
    eng.submit(Request(prompt=[1, 2, 3], on_token=seen.append))
    out = eng.run()[0]
    assert tuple(seen) == out.tokens


# ---------------------------------------------------------------------------
# sampling: determinism, inertness, per-request streams
# ---------------------------------------------------------------------------

def test_seeded_sampling_determinism_across_engines(served):
    """Same seed + same submission order on fresh engines (and fresh
    run() calls) -> identical samples."""
    cfg, model, params = served
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [10]]

    def run_once():
        eng = _engine(model, params, num_slots=4, max_new_tokens=5,
                      temperature=0.9, top_k=8, seed=42)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=p, sampling=SamplingParams(
                temperature=0.5 + 0.2 * i, top_k=2 + i)))
        return [r.tokens for r in eng.run()]

    outs1, outs2 = run_once(), run_once()
    assert outs1 == outs2
    for seq in outs1:
        assert len(seq) == 5
        assert all(0 <= t < cfg.vocab_size for t in seq)


def test_engine_fresh_streams_across_runs(served):
    """Resubmitting the same sampled prompt to one engine must not
    replay the identical continuation (each submission gets a fresh
    request id and with it a fresh PRNG stream)."""
    cfg, model, params = served
    eng = _engine(model, params, num_slots=1, max_new_tokens=6,
                  temperature=1.5, top_k=8, seed=0)
    outs = []
    for _ in range(3):
        eng.submit(Request(prompt=[1, 2, 3]))
        outs.append(eng.run()[0].tokens)
    assert len(set(outs)) > 1, outs


def test_sampling_seed_is_slot_and_neighbour_independent(served):
    """An explicit SamplingParams.seed pins the request's stream: the
    continuation is identical whatever the queue order, batch
    neighbours, or slot placement — free/greedy rows are inert (their
    ε-temperature top-1 pick consumes no useful randomness)."""
    cfg, model, params = served

    def run_A(order):
        eng = _engine(model, params, seed=7)
        rid = None
        for name in order:
            if name == "A":
                rid = eng.submit(Request(prompt=[3, 7], sampling=SamplingParams(
                    temperature=1.3, top_k=8, seed=99)))
            else:
                eng.submit(Request(prompt=[9, 1, 4]))
        return {r.request_id: r.tokens for r in eng.run()}[rid]

    a1 = run_A(["A", "B", "C"])
    a2 = run_A(["B", "C", "A"])
    a3 = run_A(["A"])
    assert a1 == a2 == a3


def test_explicit_seed_does_not_collide_with_request_id_streams(served):
    """Explicit seeds and engine-assigned request ids draw from
    disjoint salt namespaces: a request with seed=N must not replay the
    stream of the engine's N-th (unseeded) submission."""
    cfg, model, params = served
    knobs = dict(temperature=1.4, top_k=8)

    eng = _engine(model, params, num_slots=1, seed=3)
    for _ in range(2):                                # burn rids 0, 1
        eng.submit(Request(prompt=[5]))
    rid2 = eng.submit(Request(prompt=[3, 7],
                              sampling=SamplingParams(**knobs)))
    unseeded = {r.request_id: r.tokens for r in eng.run()}[rid2]

    eng2 = _engine(model, params, num_slots=1, seed=3)
    seeded_rid = eng2.submit(Request(prompt=[3, 7], sampling=SamplingParams(
        seed=2, **knobs)))
    seeded = {r.request_id: r.tokens for r in eng2.run()}[seeded_rid]
    assert seeded != unseeded


def test_greedy_request_unaffected_by_sampled_neighbours(served):
    """A greedy request batched with sampled ones produces exactly its
    solo greedy continuation (inert ε-temperature top-1 rows)."""
    cfg, model, params = served
    want = _reference_decode(model, params, [3, 1, 4], 4)
    eng = _engine(model, params, max_new_tokens=4, seed=7)
    rid = eng.submit(Request(prompt=[3, 1, 4]))
    eng.submit(Request(prompt=[2, 7], sampling=SamplingParams(
        temperature=1.2, top_k=6)))
    outs = {r.request_id: r.tokens for r in eng.run()}
    assert list(outs[rid]) == want


def test_per_request_estimator_threading(served):
    """Two live requests with different estimators share one pooled
    decode call; each matches its solo-engine run."""
    cfg, model, params = served

    def solo(est):
        eng = _engine(model, params, num_slots=1, max_new_tokens=4)
        rid = eng.submit(Request(prompt=[3, 7], sampling=SamplingParams(
            estimator=est)))
        return {r.request_id: r.tokens for r in eng.run()}[rid]

    eng = _engine(model, params, num_slots=2, max_new_tokens=4)
    ia = eng.submit(Request(prompt=[3, 7],
                            sampling=SamplingParams(estimator="median")))
    ib = eng.submit(Request(prompt=[3, 7]))
    outs = {r.request_id: r.tokens for r in eng.run()}
    assert outs[ia] == solo("median")
    assert outs[ib] == solo(None)
    assert outs[ia] != outs[ib]       # the estimator actually matters


# ---------------------------------------------------------------------------
# admission validation
# ---------------------------------------------------------------------------

def test_submit_validation_errors(served):
    cfg, model, params = served
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit(Request(prompt=[]))
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(Request(prompt=[1], sampling=SamplingParams(
            temperature=0.0)))
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(Request(prompt=[1], sampling=SamplingParams(top_k=0)))
    with pytest.raises(ValueError, match="estimator"):
        eng.submit(Request(prompt=[1], sampling=SamplingParams(
            estimator="mean")))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=[1] * 30, max_new_tokens=10))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(prompt=[1], max_new_tokens=0))   # not the default!
    with pytest.raises(ValueError, match="no encoder"):
        eng.submit(Request(prompt=[1], enc_feats=np.zeros((4, 8))))
    with pytest.raises(ValueError, match="no vision frontend"):
        eng.submit(Request(prompt=[1], prefix_feats=np.zeros((4, 8))))


def test_engine_config_validation(served):
    cfg, model, params = served
    with pytest.raises(ValueError, match="top_k"):
        ServingEngine(model, params, ServeConfig(top_k=0))
    with pytest.raises(ValueError, match="num_slots"):
        ServingEngine(model, params, ServeConfig(num_slots=0))
    with pytest.raises(ValueError, match="scheduler"):
        ServingEngine(model, params, ServeConfig(scheduler="chunked"))
    with pytest.raises(ValueError, match="temperature"):
        ServingEngine(model, params, ServeConfig(temperature=0.0))


def test_enc_feats_consistency_validation(served_enc_dec):
    """The old engine probed requests[0] for features: a batch where
    later requests carried them silently dropped them, one where only
    the first did crashed in jnp.stack.  Admission now validates every
    request: features required by the model, and shape-consistent."""
    cfg, model, params = served_enc_dec
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="needs enc_feats"):
        eng.submit(Request(prompt=[1, 2]))
    with pytest.raises(ValueError, match=r"\(S, 1024\)"):
        eng.submit(Request(prompt=[1, 2], enc_feats=np.zeros((4, 8),
                                                             np.float32)))
    # a rejected request must not pin the engine's enc-feats shape:
    # this one fails later in validation (prefix on a non-vision model)
    with pytest.raises(ValueError, match="no vision frontend"):
        eng.submit(Request(prompt=[1, 2],
                           enc_feats=np.zeros((4, 1024), np.float32),
                           prefix_feats=np.zeros((2, 8), np.float32)))
    eng.submit(Request(prompt=[1, 2],
                       enc_feats=np.zeros((4, 1024), np.float32)))
    with pytest.raises(ValueError, match="pinned"):
        eng.submit(Request(prompt=[1, 2],
                           enc_feats=np.zeros((6, 1024), np.float32)))


def test_enc_dec_slot_engine_end_to_end(served_enc_dec):
    """Cross-attention KV is pooled per slot exactly like the decode
    caches: each request decodes against its *own* encoder output, and
    matches its solo reference decode."""
    cfg, model, params = served_enc_dec
    rng = np.random.default_rng(3)
    feats = [rng.standard_normal((4, 1024)).astype(np.float32)
             for _ in range(3)]
    prompts = [[1, 2, 3], [4, 5], [6, 7]]
    eng = _engine(model, params, num_slots=2, max_new_tokens=4)
    for p, f in zip(prompts, feats):
        eng.submit(Request(prompt=p, enc_feats=f))
    outs = eng.run()
    for p, f, r in zip(prompts, feats, outs):
        want = _reference_decode(model, params, p, 4,
                                 extras={"enc_feats": f})
        assert list(r.tokens) == want


# ---------------------------------------------------------------------------
# per-slot cache machinery
# ---------------------------------------------------------------------------

def test_insert_and_reset_cache_slot(served):
    cfg, model, params = served
    pool = model.init_caches(3, 16)
    caches, _, _ = model.prefill(
        params, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)}, 16)
    pool2 = model.insert_cache_slot(pool, caches, 1)
    kv_pool, kv_one = pool2[0][0], caches[0][0]
    np.testing.assert_array_equal(np.asarray(kv_pool.k[:, 1]),
                                  np.asarray(kv_one.k[:, 0]))
    assert int(kv_pool.index[0, 1]) == 3
    # neighbouring slots untouched (still empty)
    assert int(kv_pool.index[0, 0]) == 0 and int(kv_pool.index[0, 2]) == 0
    assert bool(jnp.all(kv_pool.positions[:, 0] == -1))
    # reset restores the freshly initialized slot
    pool3 = model.reset_cache_slot(pool2, 1, 16)
    kv3 = pool3[0][0]
    assert int(kv3.index[0, 1]) == 0
    assert bool(jnp.all(kv3.positions[:, 1] == -1))
    assert bool(jnp.all(kv3.k[:, 1] == 0))


def test_cache_update_decode_per_row_writes():
    """per_row mode writes each row's KV at its own index (the slot
    engine's pooled decode); lockstep mode writes all rows at index[0]."""
    cache = attn_lib.init_cache(2, 8, 1, 4, jnp.float32)
    cache = cache._replace(index=jnp.asarray([2, 5], jnp.int32))
    k1 = jnp.ones((2, 1, 1, 4), jnp.float32)
    upd = attn_lib.cache_update_decode(cache, k1, 2 * k1, ring=False,
                                       per_row=True)
    np.testing.assert_array_equal(np.asarray(upd.index), [3, 6])
    assert float(upd.k[0, 2, 0, 0]) == 1.0 and float(upd.k[1, 5, 0, 0]) == 1.0
    assert float(upd.k[0, 5, 0, 0]) == 0.0 and float(upd.k[1, 2, 0, 0]) == 0.0
    np.testing.assert_array_equal(
        np.asarray(upd.positions), [[-1, -1, 2, -1, -1, -1, -1, -1],
                                    [-1, -1, -1, -1, -1, 5, -1, -1]])
    # ring mode wraps per row
    ring = attn_lib.init_cache(2, 4, 1, 4, jnp.float32)
    ring = ring._replace(index=jnp.asarray([5, 2], jnp.int32))
    upd = attn_lib.cache_update_decode(ring, k1, k1, ring=True, per_row=True)
    assert float(upd.k[0, 1, 0, 0]) == 1.0     # 5 % 4
    assert float(upd.k[1, 2, 0, 0]) == 1.0


def test_unified_serve_step_no_bv_tensor(served):
    """Acceptance: the unified serve step (kernel path) never
    materializes a (batch, V) score tensor — greedy and sampled rows
    both route through the fused streaming top-k."""
    from benchmarks.common import intermediate_avals
    cfg, model, params = served
    slots, v = 3, cfg.vocab_size
    pool = model.init_caches(slots, 16)
    serve_step = make_serve_step_fn(model, top_k=8)
    z = jnp.zeros((slots,), jnp.int32)
    args = (params, pool, None, {"tokens": jnp.zeros((slots, 1), jnp.int32)},
            z, jax.random.key(0), z, z,
            jnp.asarray([1e-6, 0.8, 1.2], jnp.float32),
            jnp.asarray([1, 4, 8], jnp.int32), z)
    orig = ops.mach_topk
    ops.mach_topk = functools.partial(orig, use_pallas=True, interpret=True)
    try:
        jaxpr = jax.make_jaxpr(functools.partial(
            serve_step, estimators=("unbiased",), max_len=16))(*args).jaxpr
    finally:
        ops.mach_topk = orig
    bad = [tuple(a.shape) for a in intermediate_avals(jaxpr)
           if hasattr(a, "shape") and v in a.shape and slots in a.shape]
    assert not bad, bad


@pytest.mark.parametrize("pattern,extra", [
    (("rglru", "attn_local"), {"local_window": 8, "rnn_width": 32,
                               "family": "hybrid"}),
    (("mlstm", "slstm"), {"family": "xlstm"}),
])
def test_slot_engine_parity_recurrent_and_ring_substrates(pattern, extra):
    """Per-slot decode must be bit-identical to a solo decode on the
    stateful substrates too: ring-buffer KV writes (idx % capacity per
    row) and per-row recurrent/xLSTM states, across slot reuse."""
    cfg = ModelConfig(name=f"srv-{pattern[0]}", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=100,
                      block_pattern=pattern, dtype=jnp.float32,
                      scan_layers=False, remat="none",
                      mach=MACHConfig(100, 16, 4), **extra)
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(6))
    # max_len 16 > local_window 8 engages the ring cache
    eng = _engine(model, params, max_len=16, num_slots=2, max_new_tokens=5)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]      # 3 reqs over 2 slots
    for p in prompts:
        eng.submit(Request(prompt=p))
    outs = eng.run()
    for p, r in zip(prompts, outs):
        want = _reference_decode(model, params, p, 5, max_len=16)
        assert list(r.tokens) == want, (pattern, p)


# ---------------------------------------------------------------------------
# model-level decode surface (unchanged semantics)
# ---------------------------------------------------------------------------

def test_greedy_decode_matches_reference(served):
    """Engine's next_token (fused kernel path on TPU; ref on CPU) equals
    the paper's Algorithm-2 argmax on the same hidden states."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(3), (5, cfg.d_model))
    ids, _ = model.next_token(params, h)
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    want = predict_classes(meta, cfg.mach.table(), "unbiased")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_oaa_serving_parity():
    """Same engine logic with the OAA head (argmax over full logits)."""
    cfg = ModelConfig(name="srv2", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=50,
                      dtype=jnp.float32)
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(1))
    h = jax.random.normal(jax.random.key(2), (3, 32))
    ids, vals = model.next_token(params, h)
    logits = model.oaa_logits(params, h)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(jnp.argmax(logits, -1)))
    # and the slot engine serves the OAA head end to end
    eng = _engine(model, params, num_slots=2, max_new_tokens=3)
    eng.submit(Request(prompt=[1, 2]))
    eng.submit(Request(prompt=[3]))
    for r in eng.run():
        assert len(r.tokens) == 3
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)


def test_lockstep_decode_positions(served):
    """Lockstep decode (per_row=False) stays supported for lockstep
    callers: positions advance uniformly and outputs stay finite."""
    cfg, model, params = served
    toks = jnp.asarray([[0, 0, 1, 2], [3, 4, 5, 6]], jnp.int32)
    caches, enc_kvs, h = model.prefill(params, {"tokens": toks}, max_len=16)
    ids, _ = model.next_token(params, h)
    for i in range(3):
        pos = jnp.full((2,), 4 + i, jnp.int32)
        caches, h = model.decode_step(params, caches, enc_kvs, ids, pos)
        ids, _ = model.next_token(params, h)
        assert bool(jnp.all(jnp.isfinite(h)))
    # first stack's cache index advanced by prefill + 3 decodes
    kv = caches[0][0]
    assert int(kv.index[0, 0]) == 4 + 3


def test_greedy_decode_honors_estimator():
    """With a min/median MACHConfig, next_token must follow the
    configured prediction rule (k=1 streaming kernel), not the
    summed-score rule — and the slot engine's greedy ε-temperature
    top-1 path must agree with it."""
    cfg = ModelConfig(name="srv3", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=120,
                      dtype=jnp.float32,
                      mach=MACHConfig(120, 16, 5, estimator="median"))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(4))
    h = jax.random.normal(jax.random.key(5), (4, 32))
    ids, _ = model.next_token(params, h)
    meta = mach_meta_probs(model.mach_logits(params, h).astype(jnp.float32))
    want = predict_classes(meta, cfg.mach.table(), "median")
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))

    want_seq = _reference_decode(model, params, [3, 7], 3, max_len=16)
    eng = _engine(model, params, max_len=16, num_slots=2, max_new_tokens=3,
                  seed=2)
    rid = eng.submit(Request(prompt=[3, 7]))               # greedy row
    eng.submit(Request(prompt=[9], sampling=SamplingParams(
        temperature=1.1, top_k=6)))
    outs = {r.request_id: r.tokens for r in eng.run()}
    assert list(outs[rid]) == want_seq


def test_sample_token_topk(served):
    """Sampling stays within the top-k support and is temperature-
    sensitive; MACH and OAA paths both work."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(9), (4, cfg.d_model))
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    scores = ops.mach_scores(jnp.moveaxis(meta, 0, 1), cfg.mach.table())
    topk_sets = [set(np.asarray(jax.lax.top_k(scores[i], 5)[1]).tolist())
                 for i in range(4)]
    for seed in range(6):
        s = model.sample_token(params, h, jax.random.key(seed),
                               temperature=0.8, top_k=5)
        for i in range(4):
            assert int(s[i]) in topk_sets[i]
    # near-zero temperature == greedy
    greedy, _ = model.next_token(params, h)
    s0 = model.sample_token(params, h, jax.random.key(0),
                            temperature=1e-6, top_k=5)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(greedy))


def test_sample_token_row_top_k_zero_clamped(served):
    """row_top_k=0 used to mask every candidate to -inf, making
    jax.random.categorical return an undefined index; it now clamps to
    1, i.e. the row degrades to its top-1 candidate (greedy)."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(21), (3, cfg.d_model))
    greedy, _ = model.next_token(params, h)
    for seed in range(4):
        s = model.sample_token(params, h, jax.random.key(seed),
                               temperature=1.0, top_k=5,
                               row_top_k=jnp.zeros((3,), jnp.int32))
        assert bool(jnp.all((s >= 0) & (s < cfg.vocab_size)))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(greedy))
    # mixed row_top_k: the 0 row is clamped, others unaffected
    s = model.sample_token(params, h, jax.random.key(0), temperature=1e-6,
                           top_k=5, row_top_k=jnp.asarray([0, 3, 1]))
    np.testing.assert_array_equal(np.asarray(s[0]), np.asarray(greedy[0]))
    np.testing.assert_array_equal(np.asarray(s[2]), np.asarray(greedy[2]))


def test_sample_token_matches_legacy_summed_score_distribution(served):
    """The fused path must reproduce the historical sampling semantics
    exactly: categorical over softmax(summed scores / T) (Eq. 2's affine
    scale is divided back out, so tuned temperatures keep meaning)."""
    cfg, model, params = served
    h = jax.random.normal(jax.random.key(13), (4, cfg.d_model))
    logits = model.mach_logits(params, h)
    meta = mach_meta_probs(logits.astype(jnp.float32))
    scores = ops.mach_scores(jnp.moveaxis(meta, 0, 1), cfg.mach.table())
    for seed in range(5):
        for temp in (0.5, 0.7, 1.3):
            vals, idxs = jax.lax.top_k(scores, 5)           # legacy path
            gk = jax.random.categorical(jax.random.key(seed), vals / temp)
            legacy = jnp.take_along_axis(idxs, gk[:, None], axis=-1)[:, 0]
            fused = model.sample_token(params, h, jax.random.key(seed),
                                       temperature=temp, top_k=5)
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(legacy),
                                          err_msg=f"seed={seed} T={temp}")
