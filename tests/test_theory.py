"""Quantitative validation of the paper's Theorems 1 and 2."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MACHConfig, estimate_class_probs, mach_meta_probs,
                        unbiased_estimator)
from repro.core.hashing import indistinguishable_pair_bound


def test_theorem1_unbiased_estimator():
    """E[ B/(B-1) (mean_j P_{h_j(i)} - 1/B) ] = p_i.

    Simulate: draw a ground-truth distribution p over K classes; build
    EXACT meta-class probabilities P^j_b = sum_{i: h_j(i)=b} p_i for many
    independently-seeded hash families; average the estimator over
    families and compare to p.
    """
    K, B, R = 64, 8, 4
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(K)).astype(np.float32)

    # NOTE: Carter-Wegman — exactly 2-universal, which Theorem 1 assumes.
    # The paper's fast multiply-shift trick has collision prob <= 2/B
    # (only approximately universal) and shows a small measurable bias
    # here; see test_multshift_bias_documented below.
    n_fam = 400
    est_sum = np.zeros(K, np.float64)
    for seed in range(n_fam):
        cfg = MACHConfig(K, B, R, seed=seed, hash_kind="carter_wegman")
        tab = np.asarray(cfg.table())                     # (R, K)
        meta = np.zeros((R, B), np.float64)
        for j in range(R):
            np.add.at(meta[j], tab[j], p)
        meta_j = jnp.asarray(meta, jnp.float32)[:, None, :]  # (R, 1, B)
        est = unbiased_estimator(meta_j, jnp.asarray(tab))[0]
        est_sum += np.asarray(est, np.float64)
    est_mean = est_sum / n_fam
    # unbiasedness: the average over hash families converges to p
    np.testing.assert_allclose(est_mean, p, atol=0.012)
    # and correlation should be near-perfect
    corr = np.corrcoef(est_mean, p)[0, 1]
    assert corr > 0.99, corr


def test_multshift_bias_documented():
    """The paper's multiply-shift trick (§2.1 'fastest way') is only
    ~2-universal: the unbiased estimator acquires a small positive bias.
    We document (and bound) it rather than hide it: |mean bias| < 2/B."""
    K, B, R = 64, 8, 4
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(K)).astype(np.float32)
    est_sum = np.zeros(K, np.float64)
    n_fam = 150
    for seed in range(n_fam):
        cfg = MACHConfig(K, B, R, seed=seed, hash_kind="mult_shift")
        tab = np.asarray(cfg.table())
        meta = np.zeros((R, B), np.float64)
        for j in range(R):
            np.add.at(meta[j], tab[j], p)
        est = unbiased_estimator(jnp.asarray(meta, jnp.float32)[:, None, :],
                                 jnp.asarray(tab))[0]
        est_sum += np.asarray(est, np.float64)
    bias = (est_sum / n_fam - p).mean()
    assert abs(bias) < 2.0 / B, bias


def test_theorem2_distinguishability_bound():
    """P(∃ indistinguishable pair) <= K² B^-R — check empirically that
    the realized rate respects the bound (for a regime where the bound
    is non-vacuous)."""
    K, B = 24, 8
    for R in (3, 4):
        bound = indistinguishable_pair_bound(K, B, R)
        bad = 0
        trials = 250
        for seed in range(trials):
            cfg = MACHConfig(K, B, R, seed=seed)
            tab = np.asarray(cfg.table())                 # (R, K)
            # classes i, j indistinguishable iff columns identical
            cols = [tuple(tab[:, i]) for i in range(K)]
            bad += int(len(set(cols)) < K)
        rate = bad / trials
        assert rate <= bound + 0.05, (R, rate, bound)


def test_theorem2_rate_shrinks_with_r():
    K, B = 48, 4
    rates = []
    for R in (2, 4, 8):
        bad = 0
        for seed in range(150):
            tab = np.asarray(MACHConfig(K, B, R, seed=seed).table())
            cols = set(tuple(tab[:, i]) for i in range(K))
            bad += int(len(cols) < K)
        rates.append(bad / 150)
    assert rates[0] >= rates[1] >= rates[2]
    assert rates[2] < 0.05          # K² B^-R = 48²/4^8 ≈ 0.035


def test_estimator_argmax_equals_sum_rule():
    """argmax of the unbiased estimator == argmax of the plain summed
    scores (Algorithm 2) — the affine map is order-preserving."""
    K, B, R, N = 100, 16, 6, 32
    cfg = MACHConfig(K, B, R)
    tab = cfg.table()
    logits = jax.random.normal(jax.random.key(1), (N, R, B))
    meta = mach_meta_probs(logits)                        # (R, N, B)
    est = estimate_class_probs(meta, tab, "unbiased")     # (N, K)
    g = jnp.take_along_axis(
        meta, tab[:, None, :].repeat(N, 1), axis=-1)      # not used; clarity
    scores = jnp.sum(jnp.stack(
        [meta[j][:, np.asarray(tab)[j]] for j in range(R)]), axis=0)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(est, -1)),
                                  np.asarray(jnp.argmax(scores, -1)))
