"""Streaming top-k decode kernel vs the reference estimator+top_k path.

The fused kernel must match ``estimate_class_probs`` + ``jax.lax.top_k``
(indices and values, up to tie order) for all three paper estimators,
both hash sources, and non-divisible N/K — in interpret mode on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MACHConfig
from repro.core.estimators import predict_topk
from repro.core.mach import mach_meta_probs
from repro.kernels import ops, ref
from repro.kernels.mach_decode import choose_decode_blocks, mach_decode_pallas
from repro.kernels.mach_topk import mach_topk_pallas

ESTIMATORS = ("unbiased", "min", "median")


def _assert_topk_matches(probs, tab, kv, ki, rv, ri, estimator,
                         rtol=1e-5, atol=1e-6):
    """Values must match; indices must match up to tie order (where they
    differ, the reference score at the kernel's index must equal the
    reference value at that rank)."""
    kv, ki = np.asarray(kv), np.asarray(ki)
    rv, ri = np.asarray(rv), np.asarray(ri)
    np.testing.assert_allclose(kv, rv, rtol=rtol, atol=atol)
    n = kv.shape[0]
    # no duplicate classes within a row
    for i in range(n):
        assert len(set(ki[i].tolist())) == ki.shape[1]
    if np.array_equal(ki, ri):
        return
    scores = np.asarray(ref.mach_estimator_scores_ref(probs, tab, estimator))
    np.testing.assert_allclose(scores[np.arange(n)[:, None], ki], rv,
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("k,b,r,n,topk", [
    (1000, 32, 8, 16, 10),     # paper-ish ODP block
    (5003, 64, 4, 7, 50),      # non-divisible K, odd N
    (257, 16, 3, 1, 5),        # single row
    (300, 4, 2, 3, 128),       # topk == lane width, tiny B
])
@pytest.mark.parametrize("estimator", ESTIMATORS)
def test_topk_table_mode(k, b, r, n, topk, estimator):
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(k + n), (n, r, b)), -1)
    rv, ri = ref.mach_topk_ref(probs, tab, topk, estimator)
    kv, ki = mach_topk_pallas(probs, tab, num_classes=k, k=topk,
                              estimator=estimator, interpret=True)
    _assert_topk_matches(probs, tab, kv, ki, rv, ri, estimator)


@pytest.mark.parametrize("estimator", ESTIMATORS)
@pytest.mark.parametrize("k,b,r", [(1000, 32, 8), (4096, 128, 3)])
def test_topk_inline_mode(k, b, r, estimator):
    cfg = MACHConfig(k, b, r, hash_kind="mult_shift")
    fam = cfg.family
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(1), (9, r, b)), -1)
    rv, ri = ref.mach_topk_ref(probs, cfg.table(), 20, estimator)
    kv, ki = mach_topk_pallas(
        probs, num_classes=k, k=20, estimator=estimator,
        inline_coeffs=jnp.asarray(fam.coeffs()), inline_shift=fam.shift,
        interpret=True)
    _assert_topk_matches(probs, cfg.table(), kv, ki, rv, ri, estimator)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dtypes(dtype):
    k, b, r, n = 1000, 32, 6, 5
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(3), (n, r, b)), -1).astype(dtype)
    rv, ri = ref.mach_topk_ref(probs.astype(jnp.float32), tab, 8)
    kv, ki = mach_topk_pallas(probs, tab, num_classes=k, k=8, interpret=True)
    _assert_topk_matches(probs.astype(jnp.float32), tab, kv, ki, rv, ri,
                         "unbiased")


def test_topk_k1_matches_top1_kernel():
    """k=1 degenerates to the fused top-1 decode (same argmax rule)."""
    k, b, r, n = 511, 16, 5, 6
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(4), (n, r, b)), -1)
    v1, i1 = mach_decode_pallas(probs, tab, num_classes=k, interpret=True)
    vk, ik = mach_topk_pallas(probs, tab, num_classes=k, k=1,
                              estimator="unbiased", interpret=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(ik[:, 0]))
    # top-1 kernel reports raw summed scores; top-k reports Eq. 2 values
    np.testing.assert_allclose(
        np.asarray((b / (b - 1.0)) * (v1 / r - 1.0 / b)),
        np.asarray(vk[:, 0]), rtol=1e-5, atol=1e-6)


def test_topk_ties_across_blocks():
    """Uniform probs -> every class ties; the streaming merge must keep
    the lowest class ids, like lax.top_k on the full matrix."""
    k, b, r, n, topk = 300, 4, 2, 3, 8
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jnp.ones((n, r, b)) / b
    _, ki = mach_topk_pallas(probs, tab, num_classes=k, k=topk,
                             interpret=True, block_k=128)
    np.testing.assert_array_equal(
        np.asarray(ki), np.broadcast_to(np.arange(topk), (n, topk)))


@pytest.mark.parametrize("estimator", ["min", "median"])
def test_topk_paper_scale_blocks(estimator):
    """ODP-like (R=25, B=32) min/median config: the bk chooser must
    shrink for the extra (R, bn, bk) VMEM tensor and stay correct."""
    k, b, r, n = 4000, 32, 25, 32
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(5), (n, r, b)), -1)
    rv, ri = ref.mach_topk_ref(probs, tab, 16, estimator)
    kv, ki = mach_topk_pallas(probs, tab, num_classes=k, k=16,
                              estimator=estimator, interpret=True)
    _assert_topk_matches(probs, tab, kv, ki, rv, ri, estimator)


def test_topk_validation():
    cfg = MACHConfig(100, 16, 2)
    probs = jnp.ones((2, 2, 16)) / 16
    with pytest.raises(ValueError):
        mach_topk_pallas(probs, cfg.table(), num_classes=100, k=0,
                         interpret=True)
    with pytest.raises(ValueError):
        mach_topk_pallas(probs, cfg.table(), num_classes=100, k=101,
                         interpret=True)
    with pytest.raises(ValueError):
        mach_topk_pallas(probs, cfg.table(), num_classes=100, k=5,
                         estimator="mode", interpret=True)


# ---------------------------------------------------------------------------
# blocking / padding paths
# ---------------------------------------------------------------------------

def test_choose_decode_blocks_rounds_bn():
    """bn is clamped to a multiple of 8 whatever the caller passes."""
    for block_n, want in [(1, 8), (5, 8), (8, 8), (13, 16), (100, 104),
                          (None, 8)]:
        bn, bk = choose_decode_blocks(7, 64, block_n, None)
        if block_n is not None:
            assert bn == want
        assert bn % 8 == 0
        assert bk % 128 == 0


def test_choose_decode_blocks_budget_sweep():
    """Explicit tile-byte accounting: over a sweep of VMEM budgets and
    estimator / kcap configurations the chosen tile always fits, bk is
    lane-aligned, min/median never pick a wider bk than unbiased at the
    same budget (their per-repetition score cube is accounted), and the
    floor tile overflowing raises instead of silently clamping."""
    from repro.kernels.mach_decode import decode_tile_bytes
    rb, r, n = 8 * 128, 8, 8
    for budget in (4 * 2**20, 6 * 2**20, 16 * 2**20):
        bks = {}
        for est in ("unbiased", "min", "median"):
            for kcap in (0, 128, 512):
                bn, bk = choose_decode_blocks(
                    n, rb, vmem_budget=budget, r=r, estimator=est,
                    kcap=kcap)
                assert bk % 128 == 0 and bk >= 128
                assert decode_tile_bytes(bn, bk, rb, r=r, estimator=est,
                                         kcap=kcap) <= budget
                assert bk >= kcap       # merge needs a kcap-wide block
                bks[est, kcap] = bk
        for kcap in (0, 128, 512):
            assert bks["min", kcap] <= bks["unbiased", kcap]
            assert bks["median", kcap] == bks["min", kcap]
    # larger budget -> never narrower tiles
    widths = [choose_decode_blocks(n, rb, vmem_budget=bud, r=r,
                                   estimator="min")[1]
              for bud in (4 * 2**20, 6 * 2**20, 32 * 2**20)]
    assert widths == sorted(widths)
    # floor overflow is an error, not a silent VMEM blowout ...
    with pytest.raises(ValueError):
        choose_decode_blocks(n, rb, vmem_budget=2**18, r=r,
                             estimator="min")
    # ... unless the caller takes responsibility with an explicit block_k
    assert choose_decode_blocks(32, rb, None, 256,
                                vmem_budget=2**18) == (32, 256)


@pytest.mark.parametrize("block_n", [5, 13])
def test_decode_padding_path_odd_block_n(block_n):
    """N not divisible by (rounded) bn AND K not divisible by bk stays
    correct for both the top-1 and top-k kernels."""
    k, b, r, n = 300, 8, 3, 13
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(7), (n, r, b)), -1)
    rv, ri = ref.mach_decode_ref(probs, tab)
    kv, ki = mach_decode_pallas(probs, tab, num_classes=k, interpret=True,
                                block_n=block_n, block_k=128)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv), rtol=1e-5)
    tv, ti = ref.mach_topk_ref(probs, tab, 9)
    pv, pi = mach_topk_pallas(probs, tab, num_classes=k, k=9, interpret=True,
                              block_n=block_n, block_k=128)
    _assert_topk_matches(probs, tab, pv, pi, tv, ti, "unbiased")


# ---------------------------------------------------------------------------
# dispatch layers: ops.mach_topk and estimators.predict_topk
# ---------------------------------------------------------------------------

def test_ops_mach_topk_leading_dims_and_fallback_parity():
    cfg = MACHConfig(100, 16, 4)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(0), (2, 3, 4, 16)), -1)
    v1, i1 = ops.mach_topk(probs, tab, num_classes=100, k=7,
                           use_pallas=True, interpret=True)
    v2, i2 = ops.mach_topk(probs, tab, num_classes=100, k=7,
                           use_pallas=False)
    assert v1.shape == (2, 3, 7) and i1.shape == (2, 3, 7)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)


def test_ops_mach_topk_inline_fallback_rebuilds_table():
    cfg = MACHConfig(512, 32, 4, hash_kind="mult_shift")
    fam = cfg.family
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(8), (5, 4, 32)), -1)
    v1, i1 = ops.mach_topk(probs, num_classes=512, k=6,
                           inline_coeffs=jnp.asarray(fam.coeffs()),
                           inline_shift=fam.shift, use_pallas=False)
    v2, i2 = ops.mach_topk(probs, cfg.table(), num_classes=512, k=6,
                           use_pallas=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


@pytest.mark.parametrize("estimator", ESTIMATORS)
def test_predict_topk_matches_reference_rule(estimator):
    """predict_topk (kernel route) == estimate_class_probs + lax.top_k,
    and its top-1 equals predict_classes."""
    from repro.core.estimators import estimate_class_probs, predict_classes
    cfg = MACHConfig(200, 16, 5)
    tab = cfg.table()
    logits = jax.random.normal(jax.random.key(11), (6, 5, 16))
    meta = mach_meta_probs(logits)                   # (R, N, B)
    scores = estimate_class_probs(meta, tab, estimator)
    rv, ri = jax.lax.top_k(scores, 4)
    kv, ki = predict_topk(meta, tab, 4, estimator,
                          use_pallas=True, interpret=True)
    _assert_topk_matches(jnp.moveaxis(meta, 0, 1), tab, kv, ki, rv, ri,
                         estimator)
    np.testing.assert_array_equal(np.asarray(ki[:, 0]),
                                  np.asarray(predict_classes(meta, tab,
                                                             estimator)))
