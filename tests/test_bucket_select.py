"""Dynamic bucket selection (ISSUE 8): the training-time C-axis cut.

The four claims the feature stands on:

  * force-inclusion — every example's label bucket is inside its
    repetition's selection, whatever the proxy scores say, so the
    positive CE term is exact at every step;
  * one-sided, bounded bias — ``full_loss − selected_loss`` is in
    ``[0, mach_selected_bias_bound_ref]`` per example (the selected
    logsumexp runs over a subset that contains the label);
  * zero gradient on unselected W/bias columns — selection is a
    gather, so its VJP scatters dW back only into selected columns;
  * ``bucket_select=None`` (or c_sel = B) is bit-identical to the
    unselected path — the knob is free when off.

Plus the plumbing: cached-proxy == in-graph-proxy, CSR == dense ==
oracle, the kernel path composes with selection, model.loss threads
``ModelConfig.mach_bucket_select``, and ``train.Trainer`` refreshes the
proxy cache on the ``refresh_every`` cadence.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, MACHLinear, MACHOutputHead
from repro.kernels import ops, ref
from repro.models import LanguageModel, ModelConfig


def _sel_case(n=12, d=32, r=4, b=64, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed), 4)
    h = jax.random.normal(k1, (n, d)) / np.sqrt(d)
    w = jax.random.normal(k2, (d, r * b)) / np.sqrt(d)
    bias = jax.random.normal(k3, (r * b,)) * 0.1
    y = jax.random.randint(k4, (n, r), 0, b)
    return h, w, bias, y


def _proxy(h, w, bias, b):
    return ops.mach_bucket_proxy(h, w, num_buckets=b, bias=bias)


# ---------------------------------------------------------------------------
# the four core claims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c_sel", [4, 16, 63])
def test_select_buckets_force_includes_labels(c_sel):
    """Every label bucket of the batch lands in its repetition's
    selection — even when the proxy actively down-ranks it.
    (Force-inclusion needs the batch's distinct label buckets per
    repetition to fit in c_sel, so small c_sel draws labels from a
    c_sel-sized bucket pool — an arbitrary one, per repetition.)"""
    n, d, r, b = 12, 32, 4, 64
    h, w, bias, y = _sel_case(n, d, r, b)
    if c_sel < n:
        pools = jnp.stack([jax.random.permutation(
            jax.random.key(10 + rr), b)[:c_sel] for rr in range(r)])
        y = pools[jnp.arange(r)[None, :], y % c_sel]
    proxy = _proxy(h, w, bias, b)
    # adversarial proxy: label buckets pushed to the bottom
    rows = jnp.broadcast_to(jnp.arange(r), (n, r))
    hostile = proxy.at[rows, y].add(-1e6)
    for p in (proxy, hostile):
        sel = ops.mach_select_buckets(p, y, num_buckets=b, c_sel=c_sel)
        assert sel.shape == (r, c_sel) and sel.dtype == jnp.int32
        sel_np = np.asarray(sel)
        assert all(np.all(np.diff(row) > 0) for row in sel_np)  # sorted,
        #                                                         unique
        for rr in range(r):
            assert set(np.asarray(y)[:, rr]) <= set(sel_np[rr])


def test_selected_bias_one_sided_and_bounded():
    """0 <= full − selected <= mach_selected_bias_bound_ref, per
    example; the bound is finite and the gap nonzero (the test would
    pass vacuously on a degenerate case otherwise)."""
    n, d, r, b, c_sel = 16, 32, 4, 64, 8
    h, w, bias, y = _sel_case(n, d, r, b, seed=2)
    proxy = _proxy(h, w, bias, b)
    sel = ops.mach_select_buckets(proxy, y, num_buckets=b, c_sel=c_sel)
    full = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    part = ops.mach_fused_xent_selected(
        h, w, y, sel, num_buckets=b, bias=bias)
    bound = ref.mach_selected_bias_bound_ref(h, w, y, sel, b, bias=bias)
    gap = np.asarray(full - part)
    assert np.all(gap >= -1e-5)
    assert np.all(gap <= np.asarray(bound) + 1e-5)
    assert np.all(np.isfinite(np.asarray(bound)))
    assert np.max(gap) > 1e-3          # the bias is real at c_sel << B


def test_unselected_columns_get_exactly_zero_grad():
    n, d, r, b, c_sel = 10, 24, 3, 32, 6
    h, w, bias, y = _sel_case(n, d, r, b, seed=3)
    proxy = _proxy(h, w, bias, b)
    sel = ops.mach_select_buckets(proxy, y, num_buckets=b, c_sel=c_sel)

    dw, dbias = jax.grad(lambda w_, b_: jnp.sum(
        ops.mach_fused_xent_selected(h, w_, y, sel, num_buckets=b,
                                     bias=b_)),
        argnums=(0, 1))(w, bias)
    mask = np.zeros((r, b), bool)
    mask[np.arange(r)[:, None], np.asarray(sel)] = True
    dw3 = np.asarray(dw).reshape(d, r, b)
    db2 = np.asarray(dbias).reshape(r, b)
    assert np.all(dw3[:, ~mask] == 0.0)
    assert np.all(db2[~mask] == 0.0)
    # and the selected columns actually learn
    assert np.all(np.any(dw3[:, mask] != 0.0, axis=0))


def test_bucket_select_none_and_full_are_bit_identical():
    """The knob off (None) or vacuous (c_sel = B) takes the exact same
    path as no knob at all — bitwise, values and grads."""
    n, d, r, b = 9, 24, 3, 16
    h, w, bias, y = _sel_case(n, d, r, b, seed=4)

    def vag(**kw):
        return jax.value_and_grad(lambda w_: jnp.sum(ops.mach_fused_xent(
            h, w_, y, num_buckets=b, bias=bias, **kw)))(w)

    l0, g0 = vag()
    for kw in ({"bucket_select": None},
               {"bucket_select": (b, 1)},
               {"bucket_select": (2 * b, 1)}):
        l1, g1 = vag(**kw)
        assert np.asarray(l0).tobytes() == np.asarray(l1).tobytes()
        assert np.asarray(g0).tobytes() == np.asarray(g1).tobytes()


# ---------------------------------------------------------------------------
# plumbing: proxy cache, CSR/dense/oracle parity, kernel composition
# ---------------------------------------------------------------------------

def test_cached_proxy_matches_in_graph_proxy():
    """bucket_proxy=<precomputed> is exactly the in-graph recompute
    (same batch), and the kwarg path equals the explicit selected op."""
    n, d, r, b, c_sel = 11, 24, 3, 32, 8
    h, w, bias, y = _sel_case(n, d, r, b, seed=5)
    proxy = _proxy(h, w, bias, b)
    via_kwarg = ops.mach_fused_xent(h, w, y, num_buckets=b, bias=bias,
                                    bucket_select=(c_sel, 7))
    via_cache = ops.mach_fused_xent(h, w, y, num_buckets=b, bias=bias,
                                    bucket_select=(c_sel, 7),
                                    bucket_proxy=proxy)
    sel = ops.mach_select_buckets(proxy, y, num_buckets=b, c_sel=c_sel)
    explicit = ops.mach_fused_xent_selected(h, w, y, sel, num_buckets=b,
                                            bias=bias)
    np.testing.assert_array_equal(np.asarray(via_kwarg),
                                  np.asarray(via_cache))
    np.testing.assert_array_equal(np.asarray(via_cache),
                                  np.asarray(explicit))


def test_csr_selected_matches_dense_and_oracle():
    from benchmarks.common import make_csr_case
    n, d, r, b, nnz, c_sel = 9, 48, 4, 32, 8, 8
    indptr, indices, values, w, bias, y, _ = make_csr_case(n, d, r, b,
                                                           nnz)
    proxy = ops.mach_bucket_proxy(w=w, num_buckets=b, bias=bias,
                                  csr=(indptr, indices, values))
    sel = ops.mach_select_buckets(proxy, y, num_buckets=b, c_sel=c_sel)
    out = ops.mach_fused_xent_csr_selected(
        indptr, indices, values, w, y, sel, num_buckets=b, nnz_max=nnz,
        bias=bias)
    oracle = ref.mach_fused_xent_csr_selected_ref(
        indptr, indices, values, w, y, sel, b, bias=bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    dense = ops.mach_fused_xent_selected(
        ref.csr_densify_ref(indptr, indices, values, d), w, y, sel,
        num_buckets=b, bias=bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_selected_composes_with_kernel_path():
    """Selection is a pre-transform: the fused Pallas kernel runs at
    B' = c_sel and matches the selected oracle (values + dW)."""
    n, d, r, b, c_sel = 8, 32, 3, 64, 16
    h, w, bias, y = _sel_case(n, d, r, b, seed=6)
    proxy = _proxy(h, w, bias, b)
    sel = ops.mach_select_buckets(proxy, y, num_buckets=b, c_sel=c_sel)

    def loss(w_, use_pallas, interpret):
        return jnp.sum(ops.mach_fused_xent_selected(
            h, w_, y, sel, num_buckets=b, bias=bias,
            use_pallas=use_pallas, interpret=interpret))

    lr, dr = jax.value_and_grad(lambda w_: loss(w_, False, None))(w)
    lk, dk = jax.value_and_grad(lambda w_: loss(w_, True, True))(w)
    np.testing.assert_allclose(float(lr), float(lk), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(dk),
                               rtol=1e-4, atol=1e-6)


def test_head_fused_loss_threads_bucket_select():
    """MACHLinear and MACHOutputHead thread the knob; the selected head
    loss is a lower bound on the full head loss."""
    cfg = MACHConfig(500, 32, 4)
    lin = MACHLinear(cfg, 16, fused=True)
    params = lin.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (10, 16))
    y = jax.random.randint(jax.random.key(2), (10,), 0, 500)
    full = lin.fused_loss(params, x, y)
    proxy = lin.bucket_proxy_scores(params, x)
    assert proxy.shape == (4, 32)
    part = lin.fused_loss(params, x, y, bucket_select=(8, 3),
                          bucket_proxy=proxy)
    assert float(part) <= float(full) + 1e-6

    head = MACHOutputHead(cfg, 16)
    hp = head.init(jax.random.key(3))
    h = jax.random.normal(jax.random.key(4), (6, 3, 16))
    hy = jax.random.randint(jax.random.key(5), (6, 3), 0, 500)
    hfull = head.fused_loss(hp, h, hy)
    hpart = head.fused_loss(hp, h, hy, bucket_select=(8, 3),
                            bucket_proxy=head.bucket_proxy_scores(hp, h))
    assert float(hpart) <= float(hfull) + 1e-6


# ---------------------------------------------------------------------------
# model + trainer threading
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    return ModelConfig(name="tiny", num_layers=1, d_model=32, num_heads=2,
                       num_kv_heads=1, d_ff=64, vocab_size=64,
                       dtype=jnp.float32, mach=MACHConfig(64, 8, 4),
                       mach_fused_loss=True, **kw)


def test_model_loss_threads_bucket_select():
    """ModelConfig.mach_bucket_select reaches the fused loss: selected
    <= full (one-sided), and None keeps bit-parity with the seed path."""
    cfg = _tiny_cfg()
    m0 = LanguageModel(cfg)
    m1 = LanguageModel(dataclasses.replace(cfg, mach_bucket_select=(4, 3)))
    params, _ = m0.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 9), 0,
                                          64)}
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, batch)
    (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params, batch)
    assert float(l1) <= float(l0) + 1e-6
    # the head kernel grad exists and respects the selection (some
    # columns exactly zero at c_sel=4 < B=8)
    gk = np.asarray(g1["mach_head"]["kernel"]).reshape(32, 4, 8)
    assert np.any(np.all(gk == 0.0, axis=0))
    # knob off: bit-parity
    m2 = LanguageModel(dataclasses.replace(cfg, mach_bucket_select=None))
    (l2, _), g2 = jax.value_and_grad(m2.loss, has_aux=True)(params, batch)
    assert float(l0) == float(l2)
    for a, c in zip(jax.tree.leaves(g0), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_trainer_refreshes_proxy_on_cadence():
    """Trainer honors refresh_every from cfg.mach_bucket_select: the
    proxy fn runs on steps 0, k, 2k, ... and its output is injected as
    batch["bucket_proxy"]."""
    from repro.train.trainer import TrainConfig, Trainer

    cfg = _tiny_cfg(mach_bucket_select=(4, 3))
    model = LanguageModel(cfg)
    calls = []

    def proxy_fn(params, batch):
        calls.append(len(calls))
        h, _, _ = model.hidden_states(params, batch["tokens"][:, :-1])
        return ops.mach_bucket_proxy(
            h.reshape(-1, h.shape[-1]), params["mach_head"]["kernel"],
            num_buckets=cfg.mach.num_buckets)

    seen = []
    orig_loss = model.loss

    def spy_loss(params, batch):
        seen.append("bucket_proxy" in batch)
        return orig_loss(params, batch)

    class Stream:
        def batch_at(self, s):
            return {"tokens": jax.random.randint(jax.random.key(s),
                                                 (4, 9), 0, 64)}

    tr = Trainer(model, TrainConfig(total_steps=7, warmup_steps=1,
                                    log_every=100),
                 loss_fn=spy_loss, bucket_proxy_fn=proxy_fn)
    state = tr.init_state(jax.random.key(0))
    state = tr.fit(state, Stream(), 7, log=None)
    assert len(calls) == 3              # steps 0, 3, 6
    assert seen and all(seen)           # proxy injected every step
    assert int(state.step) == 7
