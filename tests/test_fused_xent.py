"""Fused projection+CE kernel vs oracles: values, grads, memory shape.

Parity ladder (all interpret=True on CPU):
  kernel  ==  ref.mach_fused_xent_ref        (values + dh/dW grads)
  ops.mach_fused_xent / head.fused_loss  ==  mach_loss(head.apply(...))
  model.loss(mach_fused_loss=True)  ==  model.loss (materializing path)
plus the structural claim the kernel exists for: no (N, R·B)-sized
tensor appears in the jaxpr of either pass.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, MACHOutputHead, mach_loss
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import (choose_fused_blocks,
                                           mach_fused_xent_pallas)
from repro.models import LanguageModel, ModelConfig


def _case(n, d, r, b, seed=0, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(seed + n + r), 4)
    h = (jax.random.normal(k1, (n, d)) / np.sqrt(d)).astype(dtype)
    w = (jax.random.normal(k2, (d, r * b)) / np.sqrt(d)).astype(dtype)
    y = jax.random.randint(k3, (n, r), 0, b)
    g = jax.random.normal(k4, (n,))
    return h, w, y, g


# ---------------------------------------------------------------------------
# kernel vs reference oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,r,b", [
    (16, 32, 4, 8),        # several whole heads per column block
    (13, 32, 6, 24),       # ragged N (padded to the 8-sublane tile)
    (5, 32, 25, 32),       # paper ODP-ish R=25: padded head count
    (2, 16, 20, 512),      # imagenet-ish B=512, tiny N
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_xent_matches_ref(n, d, r, b, dtype):
    h, w, y, g = _case(n, d, r, b, dtype=dtype)
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, y, b, None, None, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, y, b, None, None, True) * g),
        argnums=(0, 1))(h, w)
    for a, k in zip(dr, dk):
        assert a.dtype == k.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(k, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_fused_xent_head_split_blocks():
    """B larger than the column block: a head's logsumexp streams across
    blocks through the online rescaling path."""
    n, d, r, b = 9, 16, 3, 256
    h, w, y, g = _case(n, d, r, b)
    bn, bc, rp, bp = choose_fused_blocks(n, d, r, b, None, 64)
    assert bc < b and bp % bc == 0          # the path under test
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, y, b, None, 64, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-6)
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, y, b, None, 64, True) * g),
        argnums=(0, 1))(h, w)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_fused_xent_acceptance_case():
    """The PR's acceptance config: (N=256, d=128, R=16, B=512) in
    interpret mode — |Δloss| ≤ 1e-5, grads allclose at rtol 1e-4."""
    n, d, r, b = 256, 128, 16, 512
    h, w, y, g = _case(n, d, r, b, seed=7)
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, y, b, None, None, True)
    assert float(jnp.max(jnp.abs(lr - lk))) <= 1e-5
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, y, b, None, None, True) * g),
        argnums=(0, 1))(h, w)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# integration: head / model parity with the materializing path
# ---------------------------------------------------------------------------

def test_head_fused_loss_matches_loss():
    cfg = MACHConfig(1000, 16, 5)
    head = MACHOutputHead(cfg, 24)
    params = head.init(jax.random.key(0))
    h = jax.random.normal(jax.random.key(1), (7, 3, 24))
    labels = jax.random.randint(jax.random.key(2), (7, 3), 0, 1000)
    weights = (jnp.arange(21).reshape(7, 3) % 4 != 0).astype(jnp.float32)

    def mat(p):
        return head.loss(p, h, labels, weights)

    def fused(p):
        return head.fused_loss(p, h, labels, weights,
                               use_pallas=True, interpret=True)

    l0, g0 = jax.value_and_grad(mat)(params)
    l1, g1 = jax.value_and_grad(fused)(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(g0["kernel"]),
                               np.asarray(g1["kernel"]),
                               rtol=1e-4, atol=1e-6)


def test_model_loss_fused_flag_parity():
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, mach=MACHConfig(64, 8, 4))
    cfgf = dataclasses.replace(cfg, mach_fused_loss=True)
    m0, m1 = LanguageModel(cfg), LanguageModel(cfgf)
    params, _ = m0.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 17), 0, 64)}
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, batch)
    (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_model_loss_fused_flag_routes_to_kernel(monkeypatch):
    """On CPU the flag's default dispatch falls back to the reference,
    so the plain parity test never proves the *kernel* routing.  Fake a
    TPU backend (with the kernel pinned to interpret mode) and check
    model.loss under the flag actually reaches mach_fused_xent_pallas
    and still matches the materialized path."""
    from repro.kernels import ops as ops_mod

    cfg = ModelConfig(name="tiny", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, mach=MACHConfig(64, 8, 4))
    m0 = LanguageModel(cfg)
    params, _ = m0.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 9), 0, 64)}
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, batch)

    calls = {"n": 0}
    orig = ops_mod.mach_fused_xent_pallas

    def spy(h2, w, lbl, nb, bn, bc, interpret):
        calls["n"] += 1
        return orig(h2, w, lbl, nb, bn, bc, True)   # interpret on CPU

    m1 = LanguageModel(dataclasses.replace(cfg, mach_fused_loss=True))
    with monkeypatch.context() as mp:
        mp.setattr(ops_mod, "_on_tpu", lambda: True)
        mp.setattr(ops_mod, "mach_fused_xent_pallas", spy)
        (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params,
                                                                batch)
    assert calls["n"] >= 1                          # kernel path taken
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# the structural claim: no (N, R·B) tensor in either pass
# ---------------------------------------------------------------------------

def test_no_nrb_tensor_in_fused_jaxpr():
    # shared jaxpr walker (tier-1 runs from the repo root, so the
    # benchmarks package is importable alongside src/)
    from benchmarks.common import intermediate_avals

    n, d, r, b = 64, 32, 8, 128
    h, w, y, g = _case(n, d, r, b)

    def fused_vag(h_, w_):
        return jax.value_and_grad(lambda hh, ww: jnp.sum(
            mach_fused_xent_pallas(hh, ww, y, b, None, None, True) * g),
            argnums=(0, 1))(h_, w_)

    def mat_vag(h_, w_):
        return jax.value_and_grad(lambda hh, ww: jnp.sum(
            ref.mach_fused_xent_ref(hh, ww, y, b) * g),
            argnums=(0, 1))(h_, w_)

    nrb = n * r * b
    fused_sizes = [a.size for a in intermediate_avals(
        jax.make_jaxpr(fused_vag)(h, w).jaxpr) if hasattr(a, "size")]
    mat_sizes = [a.size for a in intermediate_avals(
        jax.make_jaxpr(mat_vag)(h, w).jaxpr) if hasattr(a, "size")]
    # the materializing path forms (N, R·B) twice (fwd + bwd)...
    assert any(s >= nrb for s in mat_sizes)
    # ...the fused path never does, in either pass
    assert all(s < nrb for s in fused_sizes), \
        sorted(fused_sizes, reverse=True)[:5]
