"""Fused projection+CE kernel vs oracles: values, grads, memory shape.

Parity ladder (all interpret=True on CPU):
  kernel  ==  ref.mach_fused_xent_ref        (values + dh/dW/dbias grads)
  ops.mach_fused_xent / head.fused_loss  ==  mach_loss(head.apply(...))
  model.loss(mach_fused_loss=True)  ==  model.loss (materializing path)
plus the structural claims the kernel exists for: no (N, R·B)-sized
tensor in the jaxpr of either pass, no (d+1, R·B) bias-concat on the
dense head path, and the block choosers provably respecting their VMEM
budget (the d=12288 LM-scale case included).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig, MACHOutputHead, mach_loss
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import (DEFAULT_VMEM_BUDGET,
                                           GATHER_NNZ_THRESHOLD,
                                           choose_fused_blocks,
                                           choose_gather_blocks,
                                           choose_sparse_blocks,
                                           dense_tile_bytes,
                                           gather_tile_bytes,
                                           mach_fused_xent_gather_pallas,
                                           mach_fused_xent_pallas,
                                           sparse_tile_bytes)
from repro.models import LanguageModel, ModelConfig


def _case(n, d, r, b, seed=0, dtype=jnp.float32, with_bias=False):
    """Shared dense fixture (benchmarks/common.py) — the benchmark's
    parity gate and these tests see the same inputs."""
    from benchmarks.common import make_dense_case
    h, w, bias, y, g = make_dense_case(n, d, r, b, seed=seed, dtype=dtype)
    if not with_bias:
        return h, w, y, g
    return h, w, bias, y, g


# ---------------------------------------------------------------------------
# kernel vs reference oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,r,b", [
    (16, 32, 4, 8),        # several whole heads per column block
    (13, 32, 6, 24),       # ragged N (padded to the 8-sublane tile)
    (5, 32, 25, 32),       # paper ODP-ish R=25: padded head count
    (2, 16, 20, 512),      # imagenet-ish B=512, tiny N
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_xent_matches_ref(n, d, r, b, dtype):
    h, w, y, g = _case(n, d, r, b, dtype=dtype)
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, None, y, b, None, None, None, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, None, y, b, None, None, None,
                               True) * g),
        argnums=(0, 1))(h, w)
    for a, k in zip(dr, dk):
        assert a.dtype == k.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(k, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,d,r,b", [
    (16, 32, 4, 8),
    (13, 32, 6, 24),       # ragged N
    (2, 16, 20, 512),      # B=512, tiny N
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_xent_bias_matches_ref(n, d, r, b, dtype):
    """The in-kernel bias operand: values and (dh, dW, dbias) against
    the materializing reference."""
    h, w, bias, y, g = _case(n, d, r, b, dtype=dtype, with_bias=True)
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, None, None, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, None, None,
                               True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    # bf16 grads agree to 1 ulp (the final f32->bf16 cast may round a
    # near-midpoint value differently between the two paths)
    rtol, atol = ((1e-2, 1e-4) if dtype == jnp.bfloat16
                  else (1e-4, 1e-5))
    for a, k in zip(dr, dk):
        assert a.dtype == k.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(k, np.float32),
                                   rtol=rtol, atol=atol)


def test_fused_xent_head_split_blocks():
    """B larger than the column block: a head's logsumexp streams across
    blocks through the online rescaling path (bias included)."""
    n, d, r, b = 9, 16, 3, 256
    h, w, bias, y, g = _case(n, d, r, b, with_bias=True)
    bn, bc, bd, rp, bp = choose_fused_blocks(n, d, r, b, None, 64)
    assert bc < b and bp % bc == 0          # the path under test
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, 64, None, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-6)
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, 64, None,
                               True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_fused_xent_d_blocked():
    """d larger than the d block: logits accumulate across d blocks in
    scratch; dh/dW ride the revisited d-blocked output windows."""
    n, d, r, b = 12, 200, 4, 32
    h, w, bias, y, g = _case(n, d, r, b, with_bias=True)
    bn, bc, bd, rp, bp = choose_fused_blocks(n, d, r, b, None, None, 64)
    assert bd < d                            # the path under test
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, None, 64, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-6)
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, None, 64,
                               True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_fused_xent_d_blocked_and_head_split():
    """Both streaming paths at once: d blocked AND a head's logsumexp
    spanning column blocks."""
    n, d, r, b = 9, 200, 3, 256
    h, w, bias, y, g = _case(n, d, r, b, with_bias=True)
    bn, bc, bd, rp, bp = choose_fused_blocks(n, d, r, b, None, 64, 64)
    assert bc < b and bd < d
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    lk = mach_fused_xent_pallas(h, w, bias, y, b, None, 64, 64, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-6)
    dr = jax.grad(lambda h_, w_, b_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b, bias=b_) * g),
        argnums=(0, 1, 2))(h, w, bias)
    dk = jax.grad(lambda h_, w_, b_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, b_, y, b, None, 64, 64, True) * g),
        argnums=(0, 1, 2))(h, w, bias)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_fused_xent_acceptance_case():
    """The PR-2 acceptance config: (N=256, d=128, R=16, B=512) in
    interpret mode — |Δloss| ≤ 1e-5, grads allclose at rtol 1e-4."""
    n, d, r, b = 256, 128, 16, 512
    h, w, y, g = _case(n, d, r, b, seed=7)
    lr = ref.mach_fused_xent_ref(h, w, y, b)
    lk = mach_fused_xent_pallas(h, w, None, y, b, None, None, None, True)
    assert float(jnp.max(jnp.abs(lr - lk))) <= 1e-5
    dr = jax.grad(lambda h_, w_: jnp.sum(
        ref.mach_fused_xent_ref(h_, w_, y, b) * g), argnums=(0, 1))(h, w)
    dk = jax.grad(lambda h_, w_: jnp.sum(
        mach_fused_xent_pallas(h_, w_, None, y, b, None, None, None,
                               True) * g),
        argnums=(0, 1))(h, w)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_fused_xent_lm_scale_d_acceptance():
    """This PR's acceptance config: d=12288 (mistral-large d_model) at
    (R=32, B=512) — the shape whose old tiling silently blew the VMEM
    budget ~2x.  Two claims: (1) choose_fused_blocks at the confirmed
    N=256 shape yields a tiling whose accounted tile bytes fit the
    default 6 MB budget; (2) values + (dh, dW, dbias) match the
    materializing reference through the d-blocked kernels in interpret
    mode at that d/R/B.  Parity runs at N=16 — the (C/bc, D/bd) grid
    axes under test are N-independent, and interpret-mode cost is per
    grid step — with the chooser's own (budget-checked) tiling, which
    streams both axes exactly like the N=256 one."""
    n, d, r, b = 16, 12288, 32, 512
    bn, bc, bd, rp, bp = choose_fused_blocks(256, d, r, b)
    assert dense_tile_bytes(bn, bc, bd, rp) <= DEFAULT_VMEM_BUDGET
    assert bd < d and bc < r * b            # both axes actually stream
    bn2, bc2, bd2, rp2, _ = choose_fused_blocks(n, d, r, b)
    assert dense_tile_bytes(bn2, bc2, bd2, rp2) <= DEFAULT_VMEM_BUDGET
    assert bd2 < d and bc2 < r * b
    h, w, bias, y, g = _case(n, d, r, b, seed=3, with_bias=True)

    @jax.jit
    def kernel_vag(h_, w_, b_):
        return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
            mach_fused_xent_pallas(hh, ww, bb, y, b, None, None, None,
                                   True) * g),
            argnums=(0, 1, 2))(h_, w_, b_)

    @jax.jit
    def ref_vag(h_, w_, b_):
        return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
            ref.mach_fused_xent_ref(hh, ww, y, b, bias=bb) * g),
            argnums=(0, 1, 2))(h_, w_, b_)

    lr, dr = ref_vag(h, w, bias)
    lk, dk = kernel_vag(h, w, bias)
    np.testing.assert_allclose(float(lr), float(lk), rtol=1e-6, atol=1e-4)
    for name, a, k in zip(("dh", "dw", "dbias"), dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# block choosers: provably within the VMEM budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,r,b", [
    (12288, 32, 512),      # the confirmed blowout case (ISSUE 4)
    (8192, 16, 2048),      # R·B = 32k at LM-scale d
    (4096, 20, 512),       # imagenet-21k head on a 4k trunk
    (1024, 25, 32),        # ODP head
    (128, 16, 512),        # the PR-2 acceptance shape
    (32, 4, 8),            # tiny test shape
])
def test_choose_fused_blocks_respects_budget(d, r, b):
    bn, bc, bd, rp, bp = choose_fused_blocks(256, d, r, b)
    assert dense_tile_bytes(bn, bc, bd, rp) <= DEFAULT_VMEM_BUDGET
    # structural invariants the kernels rely on
    assert bn % 8 == 0 and bd % 8 == 0
    assert (rp * bp) % bc == 0 and rp >= r and bp >= b


@pytest.mark.parametrize("d,r,b,j", [
    (422_713, 25, 32, 128),    # paper ODP: d=422k bag-of-words
    (8192, 8, 64, 1024),       # high-nnz regime (gather-path parity in
    #                            test_gather_high_nnz_acceptance_case)
    (4096, 20, 512, 64),
    (96, 4, 16, 8),
])
def test_choose_sparse_blocks_respects_budget(d, r, b, j):
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(256, d, r, b, j)
    assert sparse_tile_bytes(bn, bc, bd, rp, jp) <= DEFAULT_VMEM_BUDGET
    assert bn % 8 == 0 and bd % 8 == 0 and jp % 128 == 0
    assert (rp * bp) % bc == 0 and rp >= r and bp >= b


def test_choosers_raise_when_budget_impossible():
    """No silent over-budget clamp: an unaffordable budget raises
    instead of returning a tiling that overflows (the old _LANE-clamp
    bug returned bn=128, bc=128 at ~12.7 MB against 6 MB)."""
    with pytest.raises(ValueError, match="vmem_budget"):
        choose_fused_blocks(256, 12288, 32, 512, vmem_budget=100_000)
    with pytest.raises(ValueError, match="vmem_budget"):
        choose_sparse_blocks(256, 422_713, 25, 32, 1024,
                             vmem_budget=100_000)


def test_ops_threads_block_overrides(monkeypatch):
    """Benchmarks/tests can pin blocks through the public dispatch:
    ops.mach_fused_xent forwards block_n/block_c/block_d to the kernel
    (which hands them to the chooser), and parity holds under pinned
    blocks."""
    from repro.kernels import mach_fused_xent as kmod

    seen = []
    orig = kmod.choose_fused_blocks

    def spy(n, d, r, b, block_n=None, block_c=None, block_d=None, **kw):
        seen.append((block_n, block_c, block_d))
        return orig(n, d, r, b, block_n, block_c, block_d, **kw)

    monkeypatch.setattr(kmod, "choose_fused_blocks", spy)
    n, d, r, b = 10, 96, 4, 64
    h, w, bias, y, g = _case(n, d, r, b, with_bias=True)
    out = ops.mach_fused_xent(h, w, y, num_buckets=b, bias=bias,
                              block_n=8, block_c=64, block_d=32,
                              use_pallas=True, interpret=True)
    assert seen and all(blk == (8, 64, 32) for blk in seen)
    lr = ref.mach_fused_xent_ref(h, w, y, b, bias=bias)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_ops_csr_threads_block_overrides(monkeypatch):
    from repro.kernels import mach_fused_xent as kmod

    seen = []
    orig = kmod.choose_sparse_blocks

    def spy(n, d, r, b, j, block_n=None, block_c=None, block_d=None,
            **kw):
        seen.append((block_n, block_c, block_d))
        return orig(n, d, r, b, j, block_n, block_c, block_d, **kw)

    monkeypatch.setattr(kmod, "choose_sparse_blocks", spy)
    from benchmarks.common import make_csr_case
    n, d, r, b, nnz = 9, 96, 4, 32, 6
    indptr, indices, values, w, bias, y, g = make_csr_case(n, d, r, b,
                                                           nnz)
    out = ops.mach_fused_xent_csr(
        indptr, indices, values, w, y, num_buckets=b, nnz_max=nnz,
        bias=bias, block_n=8, block_c=64, block_d=32,
        use_pallas=True, interpret=True)
    assert seen and all(blk == (8, 64, 32) for blk in seen)
    lr = ref.mach_fused_xent_csr_ref(indptr, indices, values, w, y, b,
                                     bias=bias)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# scalar-prefetch gather family (the high-nnz sparse path)
# ---------------------------------------------------------------------------

def _ell_case(n, d, r, b, nnz, seed=0):
    from benchmarks.common import make_csr_case
    indptr, indices, values, w, bias, y, g = make_csr_case(n, d, r, b,
                                                           nnz, seed=seed)
    cols, vals = ops.csr_to_ell(indptr, indices, values, nnz, d)
    return indptr, indices, values, cols, vals, w, bias, y, g


def _gather_vs_ref(indptr, indices, values, cols, vals, w, bias, y, g, b,
                   block_c=None, rtol=1e-4, atol=1e-5):
    lr = ref.mach_fused_xent_csr_ref(indptr, indices, values, w, y, b,
                                     bias=bias)
    lk = mach_fused_xent_gather_pallas(cols, vals, w, bias, y, b,
                                       block_c, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    sv = jax.lax.stop_gradient(values)     # kernel path: values are data
    argnums = (0,) if bias is None else (0, 1)

    def ref_loss(w_, b_=None):
        return jnp.sum(ref.mach_fused_xent_csr_ref(
            indptr, indices, sv, w_, y, b, bias=b_) * g)

    def ker_loss(w_, b_=None):
        return jnp.sum(mach_fused_xent_gather_pallas(
            cols, vals, w_, b_, y, b, block_c, True) * g)

    args = (w,) if bias is None else (w, bias)
    dr = jax.grad(ref_loss, argnums=argnums)(*args)
    dk = jax.grad(ker_loss, argnums=argnums)(*args)
    for name, a, k in zip(("dw", "dbias"), dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=rtol, atol=atol, err_msg=name)


@pytest.mark.parametrize("n,d,r,b,nnz", [
    (9, 96, 4, 32, 6),        # ragged rows, several heads per block
    (5, 64, 3, 24, 8),        # padded head count
    (4, 48, 8, 16, 16),       # nnz rows spanning several grid steps
])
def test_gather_matches_densifying_ref(n, d, r, b, nnz):
    """The scalar-prefetch gather kernels against the densifying
    reference oracle: values + dW + dbias on ragged CSR batches."""
    case = _ell_case(n, d, r, b, nnz)
    _gather_vs_ref(*case, b)


def test_gather_no_bias_and_sub_lane_block():
    """No-bias path and a sub-lane column block (bc = 8 < the 128-lane
    tile) through the gather family."""
    n, d, r, b, nnz = 7, 64, 3, 16, 8
    (indptr, indices, values, cols, vals, w, bias, y, g) = _ell_case(
        n, d, r, b, nnz, seed=5)
    _gather_vs_ref(indptr, indices, values, cols, vals, w, None, y, g, b)
    _gather_vs_ref(indptr, indices, values, cols, vals, w, bias, y, g, b,
                   block_c=8)


def test_gather_high_nnz_acceptance_case():
    """ISSUE 8's promoted high-nnz case: (d=8192, R=8, B=64, nnz=1024)
    — the bag-of-words regime where the densify family's one-hot tile
    made the padded-ELL path non-viable — full parity (values + dW +
    dbias) through the gather kernels.  N=2 because interpret mode
    carries the full dW array through every grid step (cost ~ N·d per
    pass); the gather grid axes under test (C/bc, jp) are N-independent.
    """
    n, d, r, b, nnz = 2, 8192, 8, 64, 1024
    (indptr, indices, values, cols, vals, w, bias, y, g) = _ell_case(
        n, d, r, b, nnz, seed=11)
    sv = jax.lax.stop_gradient(values)

    lr, dr = jax.value_and_grad(lambda w_, b_: jnp.sum(
        ref.mach_fused_xent_csr_ref(indptr, indices, sv, w_, y, b,
                                    bias=b_) * g),
        argnums=(0, 1))(w, bias)
    lk, dk = jax.value_and_grad(lambda w_, b_: jnp.sum(
        mach_fused_xent_gather_pallas(cols, vals, w_, b_, y, b, None,
                                      True) * g),
        argnums=(0, 1))(w, bias)
    np.testing.assert_allclose(float(lr), float(lk), rtol=1e-6, atol=1e-4)
    for name, a, k in zip(("dw", "dbias"), dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6, err_msg=name)


def test_choose_gather_blocks_nnz_and_d_independent():
    """The gather accounting's whole point: the budget never depends on
    nnz or d (W streams one gathered row at a time; ELL indices live in
    SMEM) — the paper-ODP d=422k at nnz from 8 to 100k all fit."""
    for j in (8, 1024, 100_000):
        bc, rp, bp, jp = choose_gather_blocks(256, 422_713, 25, 32, j)
        assert gather_tile_bytes(bc, rp) <= DEFAULT_VMEM_BUDGET
        assert jp == max(j, 1)
        assert (rp * bp) % bc == 0 and rp >= 25 and bp >= 32


def test_csr_dispatch_routes_by_nnz(monkeypatch):
    """ops.mach_fused_xent_csr auto-dispatch: nnz_max >=
    GATHER_NNZ_THRESHOLD routes to the gather family, below it to the
    densify family; sparse_impl overrides both ways; parity holds on
    the routed path."""
    calls = []
    orig = ops.mach_fused_xent_gather_pallas
    monkeypatch.setattr(
        ops, "mach_fused_xent_gather_pallas",
        lambda *a, **k: (calls.append("gather"), orig(*a, **k))[1])

    n, d, r, b = 3, 64, 4, 16
    lo = GATHER_NNZ_THRESHOLD // 32
    hi = GATHER_NNZ_THRESHOLD
    for nnz, impl, expect in [(lo, None, []),
                              (lo, "gather", ["gather"]),
                              (hi, None, ["gather"])]:
        calls.clear()
        (indptr, indices, values, _, _, w, bias, y, _) = _ell_case(
            n, d, r, b, nnz)
        out = ops.mach_fused_xent_csr(
            indptr, indices, values, w, y, num_buckets=b, nnz_max=nnz,
            bias=bias, sparse_impl=impl, use_pallas=True, interpret=True)
        assert calls == expect, (nnz, impl, calls)
        lr = ref.mach_fused_xent_csr_ref(indptr, indices, values, w, y,
                                         b, bias=bias)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="sparse_impl"):
        ops.mach_fused_xent_csr(indptr, indices, values, w, y,
                                num_buckets=b, nnz_max=hi,
                                sparse_impl="bogus", use_pallas=True,
                                interpret=True)


def test_no_onehot_tile_in_gather_jaxpr():
    """ISSUE 8 acceptance: scanning INTO the pallas kernel jaxprs
    (skip_primitives=()), the gather path has no (bn, jp, bd)-shaped
    one-hot intermediate — every gather tile is 2D — while the densify
    path provably has one (the detector works)."""
    from benchmarks.common import intermediate_avals

    n, d, r, b, nnz = 4, 96, 4, 32, 16
    (indptr, indices, values, _, _, w, bias, y, g) = _ell_case(
        n, d, r, b, nnz)

    def vag(impl):
        def f(w_, b_):
            return jax.value_and_grad(lambda ww, bb: jnp.sum(
                ops.mach_fused_xent_csr(
                    indptr, indices, values, ww, y, num_buckets=b,
                    nnz_max=nnz, bias=bb, sparse_impl=impl,
                    use_pallas=True, interpret=True) * g),
                argnums=(0, 1))(w_, b_)
        return jax.make_jaxpr(f)(w, bias).jaxpr

    def onehot_tiles(jaxpr):
        # a (bn, jp, bd) one-hot: nnz-sized middle axis crossed with a
        # real feature block (bd >= the 8-sublane tile) — benign 3D
        # reshapes like the (N, jp, 1) ELL widening or the (d, R, B)
        # W view don't match
        return [a.shape for a in intermediate_avals(
            jaxpr, skip_primitives=())
            if getattr(a, "ndim", 0) == 3
            and a.shape[1] >= nnz and a.shape[2] >= 8]

    densify_onehot = onehot_tiles(vag("densify"))
    assert densify_onehot, "detector broken: densify one-hot not seen"
    gather_onehot = onehot_tiles(vag("gather"))
    assert not gather_onehot, gather_onehot


# ---------------------------------------------------------------------------
# integration: head / model parity with the materializing path
# ---------------------------------------------------------------------------

def test_head_fused_loss_matches_loss():
    cfg = MACHConfig(1000, 16, 5)
    head = MACHOutputHead(cfg, 24)
    params = head.init(jax.random.key(0))
    h = jax.random.normal(jax.random.key(1), (7, 3, 24))
    labels = jax.random.randint(jax.random.key(2), (7, 3), 0, 1000)
    weights = (jnp.arange(21).reshape(7, 3) % 4 != 0).astype(jnp.float32)

    def mat(p):
        return head.loss(p, h, labels, weights)

    def fused(p):
        return head.fused_loss(p, h, labels, weights,
                               use_pallas=True, interpret=True)

    l0, g0 = jax.value_and_grad(mat)(params)
    l1, g1 = jax.value_and_grad(fused)(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(g0["kernel"]),
                               np.asarray(g1["kernel"]),
                               rtol=1e-4, atol=1e-6)


def test_model_loss_fused_flag_parity():
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, mach=MACHConfig(64, 8, 4))
    cfgf = dataclasses.replace(cfg, mach_fused_loss=True)
    m0, m1 = LanguageModel(cfg), LanguageModel(cfgf)
    params, _ = m0.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 17), 0, 64)}
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, batch)
    (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_model_loss_fused_flag_routes_to_kernel(monkeypatch):
    """On CPU the flag's default dispatch falls back to the reference,
    so the plain parity test never proves the *kernel* routing.  Fake a
    TPU backend (with the kernel pinned to interpret mode) and check
    model.loss under the flag actually reaches mach_fused_xent_pallas
    and still matches the materialized path."""
    from repro.kernels import ops as ops_mod

    cfg = ModelConfig(name="tiny", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=1, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, mach=MACHConfig(64, 8, 4))
    m0 = LanguageModel(cfg)
    params, _ = m0.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 9), 0, 64)}
    (l0, _), g0 = jax.value_and_grad(m0.loss, has_aux=True)(params, batch)

    calls = {"n": 0}
    orig = ops_mod.mach_fused_xent_pallas

    def spy(h2, w, bias, lbl, nb, bn, bc, bd, interpret):
        calls["n"] += 1
        return orig(h2, w, bias, lbl, nb, bn, bc, bd, True)  # interpret
    m1 = LanguageModel(dataclasses.replace(cfg, mach_fused_loss=True))
    with monkeypatch.context() as mp:
        mp.setattr(ops_mod, "_on_tpu", lambda: True)
        mp.setattr(ops_mod, "mach_fused_xent_pallas", spy)
        (l1, _), g1 = jax.value_and_grad(m1.loss, has_aux=True)(params,
                                                                batch)
    assert calls["n"] >= 1                          # kernel path taken
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# structural claims: no (N, R·B) tensor, no (d+1, R·B) bias concat
# ---------------------------------------------------------------------------

def test_no_nrb_tensor_in_fused_jaxpr():
    # shared jaxpr walker (tier-1 runs from the repo root, so the
    # benchmarks package is importable alongside src/)
    from benchmarks.common import intermediate_avals

    # N > dp (the padded feature dim) so batch-carrying and
    # parameter-shaped intermediates are distinguishable by leading dim
    n, d, r, b = 256, 32, 8, 128
    h, w, bias, y, g = _case(n, d, r, b, with_bias=True)

    def fused_vag(h_, w_, b_):
        return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
            mach_fused_xent_pallas(hh, ww, bb, y, b, None, None, None,
                                   True) * g),
            argnums=(0, 1, 2))(h_, w_, b_)

    def mat_vag(h_, w_, b_):
        return jax.value_and_grad(lambda hh, ww, bb: jnp.sum(
            ref.mach_fused_xent_ref(hh, ww, y, b, bias=bb) * g),
            argnums=(0, 1, 2))(h_, w_, b_)

    nrb = n * r * b

    def batch_sizes(fn):
        return [a.size for a in intermediate_avals(
            jax.make_jaxpr(fn)(h, w, bias).jaxpr)
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]

    fused_sizes = batch_sizes(fused_vag)
    mat_sizes = batch_sizes(mat_vag)
    # the materializing path forms (N, R·B) twice (fwd + bwd)...
    assert any(s >= nrb for s in mat_sizes)
    # ...the fused path never does, in either pass
    assert all(s < nrb for s in fused_sizes), \
        sorted(fused_sizes, reverse=True)[:5]


def test_dense_fused_loss_has_no_bias_concat():
    """MACHLinear.fused_loss on dense inputs no longer folds the bias
    by concatenating a row onto W: no (d+1, R·B)-shaped intermediate
    (nor its concat cotangent) in either pass — the bias is an
    in-kernel operand."""
    from benchmarks.common import intermediate_avals
    from repro.core.mach import MACHLinear

    cfg = MACHConfig(300, 8, 5)
    dim = 24
    m = MACHLinear(cfg, dim, fused=True)
    params = m.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (10, dim))
    y = jax.random.randint(jax.random.key(2), (10,), 0, 300)

    def vag(p):
        return jax.value_and_grad(
            lambda q: m.fused_loss(q, x, y, use_pallas=True,
                                   interpret=True))(p)

    avals = intermediate_avals(jax.make_jaxpr(vag)(params).jaxpr)
    rb = cfg.num_repetitions * cfg.num_buckets
    concat_shapes = [a.shape for a in avals
                     if getattr(a, "ndim", 0) == 2
                     and a.shape[0] == dim + 1 and a.shape[1] >= rb]
    assert not concat_shapes, concat_shapes
