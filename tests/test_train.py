"""Training substrate: loss goes down, checkpoint exactness,
crash-restart, microbatching equivalence, straggler detection."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.mach import MACHConfig
from repro.data import LMDataConfig, SyntheticLMStream
from repro.models import LanguageModel, ModelConfig
from repro.optim import accumulate_grads
from repro.train.fault_tolerance import (StragglerMonitor, reshard_state,
                                         run_with_restarts)
from repro.train.trainer import TrainConfig, Trainer

CFG = ModelConfig(name="tiny", num_layers=2, d_model=32, num_heads=2,
                  num_kv_heads=1, d_ff=64, vocab_size=64, dtype=jnp.float32,
                  mach=MACHConfig(64, 8, 4))
TCFG = TrainConfig(total_steps=30, warmup_steps=5, peak_lr=1e-2,
                   checkpoint_every=10, log_every=1000)


@pytest.fixture(scope="module")
def stream():
    return SyntheticLMStream(LMDataConfig(vocab_size=64, seq_len=16,
                                          global_batch=8))


def test_loss_decreases(stream):
    m = LanguageModel(CFG)
    tr = Trainer(m, TCFG)
    state = tr.init_state(jax.random.key(0))
    l0 = float(m.loss(state.params, stream.batch_at(0))[0])
    state = tr.fit(state, stream, 30, log=None)
    l1 = float(m.loss(state.params, stream.batch_at(0))[0])
    assert l1 < l0 * 0.9, (l0, l1)


def test_checkpoint_roundtrip_exact(stream):
    m = LanguageModel(CFG)
    tr = Trainer(m, TCFG)
    state = tr.init_state(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = tr.fit(state, stream, 25, manager=mgr, log=None)
        restored, step = mgr.restore(tr.init_state(jax.random.key(0)))
        assert step == 25
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # keep=2 garbage collection
        assert len(mgr.all_steps()) <= 2


def test_crash_restart_bit_exact(stream):
    """Kill training mid-run; the restarted run must produce the SAME
    final state as an uninterrupted one (deterministic data cursor +
    durable checkpoints)."""
    m = LanguageModel(CFG)

    def run(crash):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=3)
            tr = Trainer(m, TCFG)
            calls = {"n": 0}

            def train_once(state, remaining):
                calls["n"] += 1
                for s in range(int(state.step), 30):
                    state, _ = tr._jit_step(state, stream.batch_at(s))
                    if (s + 1) % 10 == 0:
                        mgr.save(s + 1, state)
                    if crash and calls["n"] == 1 and s == 17:
                        raise RuntimeError("injected node failure")
                return state

            final = run_with_restarts(
                train_once, lambda: tr.init_state(jax.random.key(0)),
                mgr, 30, log=None)
            return final, calls["n"]

    f_ok, n1 = run(False)
    f_crash, n2 = run(True)
    assert n1 == 1 and n2 == 2
    for a, b in zip(jax.tree.leaves(f_ok), jax.tree.leaves(f_crash)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatch_accumulation_equivalence(stream):
    """grad(batch) == mean over microbatch grads (same loss_fn)."""
    m = LanguageModel(CFG)
    params, _ = m.init(jax.random.key(1))
    batch = stream.batch_at(3)
    loss_fn = lambda p, b: m.loss(p, b)
    (l1, _), g1 = accumulate_grads(loss_fn, params, batch, 1)
    (l4, _), g4 = accumulate_grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold_sigma=3.0, warmup=3)
    for s in range(20):
        assert not mon.record(s, 0.1 + 0.001 * (s % 3))
    assert mon.record(20, 0.5)          # 5x slower step
    assert mon.flagged and mon.flagged[0][0] == 20
    # monitor's mean must not be poisoned by the outlier
    assert mon.mean < 0.12


def test_elastic_reshard_roundtrip():
    """Checkpoint saved anywhere restores onto a (trivially different)
    sharding — the elastic-restart path."""
    m = LanguageModel(CFG)
    tr = Trainer(m, TCFG)
    state = tr.init_state(jax.random.key(0))
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    moved = reshard_state(state, sharding)
    for a, b in zip(jax.tree.leaves(moved), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, state)
        restored, _ = mgr.restore(state, shardings=sharding)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint_save(stream):
    m = LanguageModel(CFG)
    tr = Trainer(m, TCFG)
    state = tr.init_state(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=5)
        mgr.save(1, state, blocking=False)
        mgr.save(2, state, blocking=False)   # waits for save 1 internally
        mgr.wait()
        assert mgr.all_steps() == [1, 2]
        assert mgr.latest_step() == 2


def test_async_save_failure_raises_on_wait():
    """A failed background save must surface on the next wait()/save(),
    never be reported durable, and leave the manager usable."""
    state = {"a": jnp.arange(4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        # plant a regular *file* where the writer wants its .tmp dir:
        # shutil.rmtree on it raises inside the background thread
        blocker = os.path.join(d, "step_000000000007.tmp")
        with open(blocker, "w") as f:
            f.write("in the way")
        mgr.save(7, state, blocking=False)
        with pytest.raises(NotADirectoryError):
            mgr.wait()
        assert mgr.latest_step() is None     # failure not durable
        # the captured failure is cleared once raised; manager recovers
        os.remove(blocker)
        mgr.save(7, state, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7


def test_async_save_failure_raises_on_next_save():
    state = {"a": jnp.arange(4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        blocker = os.path.join(d, "step_000000000003.tmp")
        with open(blocker, "w") as f:
            f.write("x")
        mgr.save(3, state, blocking=False)
        with pytest.raises(NotADirectoryError):
            mgr.save(4, state)               # wait() runs first and raises
        os.remove(blocker)
        mgr.save(4, state)                   # recovered
        assert mgr.latest_step() == 4


def test_stale_tmp_dirs_swept_by_gc():
    """step_*.tmp left by a crashed writer is GC'd by the next durable
    save (and never shows up as a restorable step)."""
    state = {"a": jnp.arange(4)}
    with tempfile.TemporaryDirectory() as d:
        stale = os.path.join(d, "step_000000000001.tmp")
        os.makedirs(stale)
        with open(os.path.join(stale, "junk"), "w") as f:
            f.write("partial write")
        mgr = CheckpointManager(d, keep=2)
        assert mgr.all_steps() == []         # .tmp never restorable
        mgr.save(2, state, blocking=True)
        assert not os.path.exists(stale)
        assert mgr.latest_step() == 2


def test_checkpoint_save_retries_transient_fs_errors(monkeypatch):
    """Bounded retry with backoff: two transient FS failures, third
    attempt lands; the checkpoint is durable and wait() is clean."""
    import numpy as _np

    from repro.checkpoint import manager as manager_mod

    state = {"w": jnp.arange(6.0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_retries=3, retry_backoff=0.0)
        calls = {"n": 0}
        orig = _np.savez

        def flaky(*a, **k):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise OSError("transient fs hiccup")
            return orig(*a, **k)

        monkeypatch.setattr(manager_mod.np, "savez", flaky)
        mgr.save(7, state, blocking=False)
        mgr.wait()                               # must not raise
        assert calls["n"] == 3
        restored, step = mgr.restore({"w": jnp.zeros(6)})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(6.0))


def test_checkpoint_save_reraises_after_final_attempt(monkeypatch):
    """A persistent failure exhausts the retry budget and re-raises —
    async on the next wait(), blocking immediately."""
    from repro.checkpoint import manager as manager_mod

    state = {"w": jnp.arange(3.0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_retries=2, retry_backoff=0.0)
        calls = {"n": 0}

        def always_fails(*a, **k):
            calls["n"] += 1
            raise OSError("disk on fire")

        monkeypatch.setattr(manager_mod.np, "savez", always_fails)
        mgr.save(1, state, blocking=False)
        with pytest.raises(OSError, match="disk on fire"):
            mgr.wait()
        assert calls["n"] == 2                   # bounded, not infinite
        calls["n"] = 0
        with pytest.raises(OSError, match="disk on fire"):
            mgr.save(2, state, blocking=True)
        assert calls["n"] == 2
        # no half-written checkpoint became visible
        assert mgr.all_steps() == []


def test_checkpoint_publish_failure_never_destroys_durable_step(monkeypatch):
    """A post-rename failure (LATEST pointer / GC) must not re-enter the
    step write — the durable step dir survives and restore() recovers
    it via the directory-scan fallback."""
    import os as _os

    from repro.checkpoint import manager as manager_mod

    state = {"w": jnp.arange(5.0)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, save_retries=2, retry_backoff=0.0)
        orig = _os.rename

        def flaky_rename(src, dst):
            if dst.endswith("LATEST"):
                raise OSError("LATEST write failed")
            return orig(src, dst)

        monkeypatch.setattr(manager_mod.os, "rename", flaky_rename)
        mgr.save(3, state, blocking=False)
        with pytest.raises(OSError, match="LATEST write failed"):
            mgr.wait()
        monkeypatch.setattr(manager_mod.os, "rename", orig)
        # the step dir is durable despite the publish failure
        assert mgr.all_steps() == [3]
        restored, step = mgr.restore({"w": jnp.zeros(5)})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(5.0))
