"""2-universal hashing properties (paper §2.1) — hypothesis-driven."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import hashing


FAMILIES = ["carter_wegman", "mult_shift"]


@pytest.mark.parametrize("kind", FAMILIES)
def test_table_range_and_determinism(kind):
    B = 32
    fam = hashing.make_hash_family(B, 5, seed=7, kind=kind)
    t1 = np.asarray(fam.table(1000))
    t2 = np.asarray(fam.table(1000))
    assert t1.shape == (5, 1000)
    assert t1.min() >= 0 and t1.max() < B
    np.testing.assert_array_equal(t1, t2)


@pytest.mark.parametrize("kind", FAMILIES)
def test_hash_labels_matches_table(kind):
    fam = hashing.make_hash_family(16, 4, seed=3, kind=kind)
    tab = np.asarray(fam.table(500))
    y = jnp.asarray([0, 1, 13, 499, 250])
    hl = np.asarray(fam.hash_labels(y, 500))
    np.testing.assert_array_equal(hl, tab[:, np.asarray(y)])


@pytest.mark.parametrize("kind", FAMILIES)
def test_bucket_distribution_roughly_uniform(kind):
    """Each hash function spreads K classes evenly over B buckets."""
    B, K = 16, 20000
    fam = hashing.make_hash_family(B, 3, seed=11, kind=kind)
    tab = np.asarray(fam.table(K))
    for j in range(3):
        counts = np.bincount(tab[j], minlength=B)
        # expected K/B = 1250; allow 15%
        assert counts.min() > K / B * 0.85, counts
        assert counts.max() < K / B * 1.15, counts


def test_independence_across_repetitions():
    """Different repetitions disagree on bucket assignment (no duplicated
    hash functions)."""
    fam = hashing.make_hash_family(32, 8, seed=0)
    tab = np.asarray(fam.table(4096))
    for i in range(8):
        for j in range(i + 1, 8):
            agree = np.mean(tab[i] == tab[j])
            assert agree < 0.2, (i, j, agree)  # ~1/B expected


@given(st.integers(2, 1 << 12), st.integers(10, 100000))
@settings(max_examples=30, deadline=None)
def test_r_required_gives_valid_bound(b_exp, k):
    b = 1 << max(1, b_exp.bit_length() - 1)
    if b < 2:
        b = 2
    r = hashing.r_required(k, b, delta=1e-3)
    assert r >= 1
    # plugging R back into the union bound must satisfy delta
    assert hashing.indistinguishable_pair_bound(k, b, r) <= 1e-3 + 1e-12


def test_r_required_decreases_with_b():
    rs = [hashing.r_required(100000, b) for b in (2, 8, 32, 512, 4096)]
    assert rs == sorted(rs, reverse=True)


def test_memory_reduction_matches_paper_numbers():
    # paper §4.3: ODP with B=32, R=25 -> ~131x vs K=105033 (reported 125x
    # against their slightly different accounting; the ratio K/(BR))
    assert abs(hashing.memory_reduction(105033, 32, 25) - 131.3) < 0.1
    # imagenet: 21841/(512*20) ~ 2.13x (paper: "2x")
    assert abs(hashing.memory_reduction(21841, 512, 20) - 2.13) < 0.01


def test_mult_shift_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hashing.MultShiftFamily(num_buckets=30, num_repetitions=4)


def test_carter_wegman_exact_universality_small():
    """Empirical pair-collision probability ~ 1/B over many seeds."""
    B = 8
    collisions = 0
    trials = 300
    for seed in range(trials):
        fam = hashing.CarterWegmanFamily(B, 1, seed=seed)
        tab = fam.table_np(64)
        collisions += int(tab[0, 3] == tab[0, 41])
    rate = collisions / trials
    assert abs(rate - 1.0 / B) < 0.06, rate
