"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per instructions: shape/dtype sweeps + hypothesis, assert_allclose
against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import MACHConfig
from repro.kernels import ops, ref
from repro.kernels.lru_scan import lru_scan_pallas
from repro.kernels.mach_decode import mach_decode_pallas
from repro.kernels.mach_xent import mach_xent_pallas


# ---------------------------------------------------------------------------
# mach_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,b,r,n", [
    (1000, 32, 8, 16),      # paper-ish ODP block
    (5003, 64, 4, 7),       # non-divisible K, odd N
    (257, 16, 3, 1),        # single row
    (21841 // 8, 512, 5, 4),  # imagenet-ish B
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mach_decode_table_mode(k, b, r, n, dtype):
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(k + n), (n, r, b)), -1).astype(dtype)
    rv, ri = ref.mach_decode_ref(probs, tab)
    kv, ki = mach_decode_pallas(probs, tab, num_classes=k, interpret=True)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))


@pytest.mark.parametrize("k,b,r", [(1000, 32, 8), (4096, 128, 3)])
def test_mach_decode_inline_mode(k, b, r):
    cfg = MACHConfig(k, b, r, hash_kind="mult_shift")
    fam = cfg.family
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(0), (9, r, b)), -1)
    rv, ri = ref.mach_decode_ref(probs, cfg.table())
    kv, ki = mach_decode_pallas(
        probs, num_classes=k, inline_coeffs=jnp.asarray(fam.coeffs()),
        inline_shift=fam.shift, interpret=True)
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))


@given(st.integers(50, 700), st.sampled_from([4, 16, 32]),
       st.integers(1, 6), st.integers(1, 9))
@settings(max_examples=12, deadline=None)
def test_mach_decode_hypothesis(k, b, r, n):
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(k * n + r), (n, r, b)), -1)
    rv, ri = ref.mach_decode_ref(probs, tab)
    kv, ki = mach_decode_pallas(probs, tab, num_classes=k, interpret=True,
                                block_k=128)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(rv), np.asarray(kv), rtol=1e-5)


def test_mach_decode_block_boundary_ties():
    """Argmax ties across K-block boundaries resolve to the first index
    (jnp.argmax semantics)."""
    k, b, r, n = 300, 4, 2, 3
    cfg = MACHConfig(k, b, r)
    tab = cfg.table()
    probs = jnp.ones((n, r, b)) / b       # all scores equal
    _, ri = mach_decode_pallas(probs, tab, num_classes=k, interpret=True,
                               block_k=128)
    _, rr = ref.mach_decode_ref(probs, tab)
    np.testing.assert_array_equal(np.asarray(ri), np.asarray(rr))
    assert int(ri[0]) == 0


# ---------------------------------------------------------------------------
# mach_xent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,r,b", [(16, 8, 32), (5, 3, 17), (64, 25, 32),
                                   (2, 20, 512)])
def test_mach_xent_fwd_bwd(n, r, b):
    key = jax.random.key(n * r)
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (n, r, b))
    labels = jax.random.randint(k2, (n, r), 0, b)
    np.testing.assert_allclose(
        np.asarray(ref.mach_xent_ref(logits, labels)),
        np.asarray(mach_xent_pallas(logits, labels, None, True)),
        rtol=1e-5, atol=1e-6)
    g_ref = jax.grad(lambda lg: jnp.sum(ref.mach_xent_ref(lg, labels)))(logits)
    g_k = jax.grad(lambda lg: jnp.sum(
        mach_xent_pallas(lg, labels, None, True)))(logits)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_k),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,r,b", [(16, 4, 32),   # divisible N
                                   (13, 6, 24)])  # padded N (bn=8 tiles)
def test_mach_xent_vjp_matches_mach_loss_grad(n, r, b):
    """The fused VJP must equal jax.grad of the core mach_loss (the
    semantic definition), including through the N-padding path and the
    weighted batch reduction."""
    from repro.core.mach import mach_loss
    key = jax.random.key(n + r)
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (n, r, b))
    labels = jax.random.randint(k2, (n, r), 0, b)
    weights = (jnp.arange(n) % 3 != 0).astype(jnp.float32)

    def core(lg):
        return mach_loss(lg, jnp.moveaxis(labels, -1, 0), weights)

    def fused(lg):
        per = mach_xent_pallas(lg, labels, 8, True)   # block_n=8: force pad
        return jnp.sum(per * weights) / jnp.maximum(jnp.sum(weights), 1.0)

    np.testing.assert_allclose(float(core(logits)), float(fused(logits)),
                               rtol=1e-6)
    g_core = jax.grad(core)(logits)
    g_fused = jax.grad(fused)(logits)
    np.testing.assert_allclose(np.asarray(g_core), np.asarray(g_fused),
                               rtol=1e-5, atol=1e-6)


def test_mach_xent_matches_mach_loss():
    """kernel == the core mach_loss (modulo batch reduction)."""
    from repro.core.mach import mach_loss
    n, r, b = 12, 6, 24
    logits = jax.random.normal(jax.random.key(5), (n, r, b))
    labels = jax.random.randint(jax.random.key(6), (n, r), 0, b)
    per = ops.mach_xent(logits, labels, use_pallas=True, interpret=True)
    core = mach_loss(logits, jnp.moveaxis(labels, -1, 0))
    np.testing.assert_allclose(float(jnp.mean(per)), float(core), rtol=1e-6)


# ---------------------------------------------------------------------------
# lru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bsz,t,d", [(2, 64, 128), (3, 128, 300),
                                     (1, 256, 64), (5, 32, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan(bsz, t, d, dtype):
    key = jax.random.key(t + d)
    ka, kx, kh = jax.random.split(key, 3)
    a = jax.random.uniform(ka, (bsz, t, d), minval=0.5, maxval=0.99
                           ).astype(dtype)
    x = (jax.random.normal(kx, (bsz, t, d)) * 0.1).astype(dtype)
    h0 = jax.random.normal(kh, (bsz, d)).astype(dtype)
    r = ref.lru_scan_ref(a.astype(jnp.float32), x.astype(jnp.float32),
                         h0.astype(jnp.float32))
    k = lru_scan_pallas(a, x, h0, block_t=min(64, t), interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(r), np.asarray(k, np.float32),
                               rtol=tol, atol=tol)


def test_lru_scan_state_continuity():
    """Scanning two halves with carried state == one full scan."""
    b, t, d = 2, 64, 128
    key = jax.random.key(9)
    a = jax.random.uniform(key, (b, t, d), minval=0.3, maxval=0.95)
    x = jax.random.normal(jax.random.key(10), (b, t, d))
    h0 = jnp.zeros((b, d))
    full = ref.lru_scan_ref(a, x, h0)
    h1 = ref.lru_scan_ref(a[:, :32], x[:, :32], h0)
    h2 = ref.lru_scan_ref(a[:, 32:], x[:, 32:], h1[:, -1])
    np.testing.assert_allclose(np.asarray(full[:, 32:]), np.asarray(h2),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_dispatch_leading_dims():
    cfg = MACHConfig(100, 16, 4)
    tab = cfg.table()
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.key(0), (2, 3, 4, 16)), -1)
    v1, i1 = ops.mach_top1(probs, tab, num_classes=100,
                           use_pallas=True, interpret=True)
    v2, i2 = ops.mach_top1(probs, tab, num_classes=100, use_pallas=False)
    assert v1.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_mach_scores_matches_gather():
    from repro.core.estimators import gather_class_probs
    cfg = MACHConfig(77, 8, 5)
    tab = cfg.table()
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(2), (6, 5, 8)), -1)
    g = ops.mach_scores(probs, tab)                        # (6, 77)
    meta = jnp.moveaxis(probs, 1, 0)                       # (R, N, B)
    gathered = gather_class_probs(meta, tab).sum(0)        # (N, K)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gathered),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,kv,hd,window,bq,bk", [
    (2, 128, 4, 2, 64, None, 64, 64),      # GQA
    (1, 256, 8, 1, 32, None, 128, 64),     # MQA
    (2, 128, 4, 4, 64, 48, 32, 32),        # MHA + sliding window
    (1, 64, 2, 2, 128, None, 64, 64),      # single block
])
def test_flash_attention_vs_reference(b, t, h, kv, hd, window, bq, bk):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import attend
    key = jax.random.key(t + h)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, t, h, hd))
    k = jax.random.normal(kk, (b, t, kv, hd))
    v = jax.random.normal(kv_, (b, t, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    want = attend(q, k, v, pos, pos, causal=True, window=window,
                  flash_threshold=1 << 62)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import attend
    b, t, h, kv, hd = 1, 128, 4, 2, 64
    q = jax.random.normal(jax.random.key(0), (b, t, h, hd)).astype(dtype)
    k = jax.random.normal(jax.random.key(1), (b, t, kv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.key(2), (b, t, kv, hd)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    want = attend(q, k, v, pos, pos, causal=True, flash_threshold=1 << 62)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                 block_k=64, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(got, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# ops/oracle drift lint (same check CI runs)
# ---------------------------------------------------------------------------

def test_every_op_names_a_live_oracle():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "tools"))
    try:
        from lint_kernel_oracles import check
    finally:
        sys.path.pop(0)
    assert check() == []
