"""Candidate-filtered decode: count-min filter + fused filter->gather->
score path vs the streaming oracle.

Covers the inverted-table construction, exactness at (m=B, t=R) and of
the "exact" knob, jnp-vs-Pallas-interpret parity, the count-min
semantics against the brute-force oracle, t-backfill behavior, recall
monotonicity in (m, t), the no-(n, K)-tensor jaxpr gate, dispatch
threading (ops -> estimators -> MACHHead), and the benchmark
regression-delta gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MACHConfig
from repro.core.estimators import predict_topk
from repro.core.hashing import inverted_table, inverted_table_np
from repro.kernels import ops, ref
from repro.kernels.mach_candidates import (bucket_topm, bucket_topm_pallas,
                                           mach_candidate_topk,
                                           mach_candidate_topk_pallas)

ESTIMATORS = ("unbiased", "min", "median")


def _probs(key, n, r, b, dtype=jnp.float32):
    return jax.nn.softmax(
        jax.random.normal(jax.random.key(key), (n, r, b)), -1).astype(dtype)


def _assert_values_match(cand_v, oracle_v, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(np.asarray(cand_v), np.asarray(oracle_v),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# inverted table
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,k,b,r", [("carter_wegman", 1000, 32, 8),
                                        ("mult_shift", 512, 16, 4)])
def test_inverted_table_partition(kind, k, b, r):
    """Each repetition's rows partition [K]: every class appears exactly
    once, in its own bucket's row, ascending, sentinel-padded, with L a
    lane multiple."""
    cfg = MACHConfig(k, b, r, hash_kind=kind)
    tab = cfg.table_np()
    inv = inverted_table_np(tab, b)
    rb, ell = inv.shape
    assert rb == r * b and ell % 128 == 0
    for j in range(r):
        seen = []
        for bb in range(b):
            row = inv[j * b + bb]
            real = row[row < k]
            assert np.all(np.diff(real) > 0)          # ascending class ids
            assert np.all(tab[j][real] == bb)         # right bucket
            assert np.all(row[len(real):] == k)       # sentinel tail
            seen.extend(real.tolist())
        assert sorted(seen) == list(range(k))


def test_inverted_table_config_accessor():
    cfg = MACHConfig(300, 8, 3)
    np.testing.assert_array_equal(
        np.asarray(cfg.inverted_table()),
        inverted_table_np(cfg.table_np(), 8))


def test_inverted_table_validation():
    with pytest.raises(ValueError):
        inverted_table_np(np.zeros((3, 4, 5), np.int32), 8)
    with pytest.raises(ValueError):
        inverted_table_np(np.full((2, 10), 9, np.int32), 8)  # bucket >= B


# ---------------------------------------------------------------------------
# bucket top-m
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 5, 16])
def test_bucket_topm_pallas_matches_jnp(m):
    probs = _probs(3, 7, 6, 16)      # odd/ragged n
    t1, i1 = bucket_topm(probs, m)
    t2, i2 = bucket_topm_pallas(probs, m, interpret=True)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# exactness: full top-m + t = R  ==  streaming
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("estimator", ESTIMATORS)
@pytest.mark.parametrize("mode", ["table", "inline"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_full_topm_tR_matches_streaming(estimator, mode, dtype):
    k_cls, b, r, n, k = 1000, 32, 8, 7, 10     # ragged n
    kind = "mult_shift" if mode == "inline" else "carter_wegman"
    cfg = MACHConfig(k_cls, b, r, hash_kind=kind)
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    probs = _probs(k_cls + n, n, r, b, dtype)
    p32 = probs.astype(jnp.float32)
    rv, ri = ref.mach_topk_ref(p32, tab, k, estimator)
    if mode == "inline":
        fam = cfg.family
        cv, ci = mach_candidate_topk(
            p32, inv, num_classes=k_cls, k=k, m=b, t=r, estimator=estimator,
            inline_coeffs=jnp.asarray(fam.coeffs()), inline_shift=fam.shift)
    else:
        cv, ci = mach_candidate_topk(p32, inv, tab, num_classes=k_cls, k=k,
                                     m=b, t=r, estimator=estimator)
    _assert_values_match(cv, rv)
    # indices match up to tie order: scores at candidate ids == values
    if not np.array_equal(np.asarray(ci), np.asarray(ri)):
        sc = np.asarray(ref.mach_estimator_scores_ref(p32, tab, estimator))
        np.testing.assert_allclose(
            sc[np.arange(n)[:, None], np.asarray(ci)], np.asarray(rv),
            rtol=1e-5, atol=1e-6)
    # no duplicate classes in any row
    for i in range(n):
        assert len(set(np.asarray(ci)[i].tolist())) == k


def test_exact_knob_is_bit_identical_to_streaming():
    cfg = MACHConfig(500, 16, 4)
    tab = cfg.table()
    probs = _probs(0, 5, 4, 16)
    sv, si = ops.mach_topk(probs, tab, num_classes=500, k=6,
                           use_pallas=False)
    ev, ei = ops.mach_topk(probs, tab, num_classes=500, k=6,
                           candidate_mode="exact", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(ev))


# ---------------------------------------------------------------------------
# jnp vs Pallas-interpret parity, and both vs the brute-force oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("estimator", ESTIMATORS)
@pytest.mark.parametrize("m,t", [(4, 1), (6, 2), (32, 8)])
def test_kernel_vs_jnp_vs_oracle(estimator, m, t):
    k_cls, b, r, n, k = 1000, 32, 8, 5, 9
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    fam = cfg.family
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    co, sh = jnp.asarray(fam.coeffs()), fam.shift
    probs = _probs(2, n, r, b)
    ov, oi = ref.mach_candidate_topk_ref(probs, tab, k, m, t, estimator)
    jv, ji = mach_candidate_topk(probs, inv, num_classes=k_cls, k=k, m=m,
                                 t=t, estimator=estimator, inline_coeffs=co,
                                 inline_shift=sh)
    pv, pi = mach_candidate_topk_pallas(probs, inv, num_classes=k_cls, k=k,
                                        m=m, t=t, estimator=estimator,
                                        inline_coeffs=co, inline_shift=sh,
                                        interpret=True)
    _assert_values_match(jv, ov)
    _assert_values_match(pv, ov)
    # filtered slots agree exactly (value -inf, id -1)
    dead = np.asarray(jv) == -np.inf
    np.testing.assert_array_equal(np.asarray(ji)[dead],
                                  np.full(int(dead.sum()), -1))
    np.testing.assert_array_equal(dead, np.asarray(pv) == -np.inf)


def test_backfill_row_with_no_t_survivor():
    """With t=R and tiny m, rows whose oracle top class doesn't land in
    every repetition's top-m still return their best count>=1 candidate
    in slot 0 (the serving never-empty guarantee)."""
    k_cls, b, r, n, k = 2000, 16, 6, 8, 5
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    fam = cfg.family
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    probs = _probs(11, n, r, b)      # flat-random: t=R survivors are rare
    ov, oi = ref.mach_candidate_topk_ref(probs, tab, k, 1, r)
    cv, ci = mach_candidate_topk(probs, inv, num_classes=k_cls, k=k, m=1,
                                 t=r, inline_coeffs=jnp.asarray(fam.coeffs()),
                                 inline_shift=fam.shift)
    _assert_values_match(cv, ov)
    cv, ci = np.asarray(cv), np.asarray(ci)
    assert np.all(cv[:, 0] > -np.inf)          # slot 0 never empty
    assert np.all(ci[:, 0] >= 0)
    assert np.any(cv == -np.inf)               # the filter did filter
    np.testing.assert_array_equal(ci[cv == -np.inf], -1)


# ---------------------------------------------------------------------------
# recall monotonicity in (m, t)
# ---------------------------------------------------------------------------

def _recall_at(probs, tab, inv, cfg, m, t, k=10):
    fam = cfg.family
    _, si = ref.mach_topk_ref(probs, tab, k)
    _, ci = mach_candidate_topk(probs, inv, num_classes=cfg.num_classes,
                                k=k, m=m, t=t,
                                inline_coeffs=jnp.asarray(fam.coeffs()),
                                inline_shift=fam.shift)
    si, ci = np.asarray(si), np.asarray(ci)
    return np.mean([len(set(ci[i]) & set(si[i])) / k
                    for i in range(si.shape[0])])


def test_recall_monotone_in_m_and_t():
    """The candidate set grows with m and shrinks with t, and any oracle
    top-k class inside the set survives to the filtered top-k — so
    recall@k is non-decreasing in m and non-increasing in t, exactly."""
    k_cls, b, r, n = 3000, 32, 6, 12
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    probs = _probs(23, n, r, b)
    rec_m = [_recall_at(probs, tab, inv, cfg, m, 1) for m in (1, 2, 4, 8, 32)]
    assert all(a <= b_ + 1e-12 for a, b_ in zip(rec_m, rec_m[1:])), rec_m
    assert rec_m[-1] == 1.0                   # m=B, t=1 covers everything
    rec_t = [_recall_at(probs, tab, inv, cfg, 4, t) for t in (1, 2, 4, 6)]
    assert all(a >= b_ - 1e-12 for a, b_ in zip(rec_t, rec_t[1:])), rec_t


# ---------------------------------------------------------------------------
# jaxpr gate: no (n, K) tensor on the filtered path
# ---------------------------------------------------------------------------

def test_no_nK_tensor_on_filtered_path():
    from benchmarks.common import intermediate_avals
    # B large enough that the candidate pool (R*m*L, with L ~ K/B times
    # hash skew) stays well under K — the pool is the intended working
    # set; what must never appear is a K-sized axis.
    k_cls, b, r, n, k = 200_000, 512, 4, 8, 10
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    fam = cfg.family
    inv = inverted_table(cfg.table_np(), b)
    probs = _probs(1, n, r, b)

    def filtered(p, iv):
        return ops.mach_topk_candidates(
            p, inverted=iv, num_classes=k_cls, k=k, m=4, t=1,
            inline_coeffs=jnp.asarray(fam.coeffs()), inline_shift=fam.shift,
            use_pallas=False)

    jaxpr = jax.make_jaxpr(filtered)(probs, inv).jaxpr
    bad = [tuple(a.shape) for a in intermediate_avals(jaxpr)
           if a.shape and max(a.shape) >= k_cls]
    assert not bad, f"(n, K)-scale tensors on the filtered path: {bad}"


# ---------------------------------------------------------------------------
# dispatch threading: ops -> estimators -> MACHHead
# ---------------------------------------------------------------------------

def test_ops_mach_topk_candidate_mode_dispatch():
    k_cls, b, r = 1000, 32, 8
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    probs = _probs(4, 6, r, b).reshape(2, 3, r, b)    # leading dims
    ov, oi = ref.mach_candidate_topk_ref(probs.reshape(6, r, b), tab, 5,
                                         6, 2)
    cv, ci = ops.mach_topk(probs, tab, num_classes=k_cls, k=5,
                           candidate_mode=(6, 2), inverted=inv,
                           use_pallas=False)
    assert cv.shape == (2, 3, 5) and ci.shape == (2, 3, 5)
    _assert_values_match(cv.reshape(6, 5), ov)


def test_predict_topk_and_head_candidate_mode():
    from repro.core import MACHLinear
    from repro.core.mach import mach_meta_probs
    k_cls, b, r = 600, 16, 5
    cfg = MACHConfig(k_cls, b, r, hash_kind="mult_shift")
    tab = cfg.table()
    inv = inverted_table(cfg.table_np(), b)
    logits = jax.random.normal(jax.random.key(6), (9, r, b))
    meta = mach_meta_probs(logits)                    # (R, N, B)
    sv, si = predict_topk(meta, tab, 4, "unbiased", use_pallas=False)
    cv, ci = predict_topk(meta, tab, 4, "unbiased",
                          candidate_mode=(b, r), inverted=inv,
                          use_pallas=False)
    _assert_values_match(cv, sv)

    head = MACHLinear(cfg, dim=12)
    params = head.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (7, 12))
    full = head.predict(params, x)
    cand = head.predict(params, x, candidate_mode=(b, r), inverted=inv)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(cand))


def test_candidate_validation():
    cfg = MACHConfig(100, 16, 2, hash_kind="mult_shift")
    inv = inverted_table(cfg.table_np(), 16)
    probs = _probs(0, 2, 2, 16)
    fam = cfg.family
    kw = dict(inline_coeffs=jnp.asarray(fam.coeffs()),
              inline_shift=fam.shift)
    for bad in [dict(k=0, m=4, t=1), dict(k=5, m=0, t=1),
                dict(k=5, m=17, t=1), dict(k=5, m=4, t=3),
                dict(k=5, m=4, t=1, estimator="mode")]:
        with pytest.raises(ValueError):
            mach_candidate_topk(probs, inv, num_classes=100,
                                **{**kw, **bad})
    with pytest.raises(ValueError):
        mach_candidate_topk(probs, inv, num_classes=100, k=5, m=4, t=1)


# ---------------------------------------------------------------------------
# benchmark regression gate
# ---------------------------------------------------------------------------

def test_bench_regression_delta():
    from benchmarks.common import bench_regression, flatten_bench_times
    old = {"configs": [{"K": 1, "us_ref": 100.0, "us_fused": 50.0},
                       {"K": 2, "us_ref": 200.0, "us_fused": 80.0}],
           "gate": {"rows": [{"us_stream": 1000.0, "us_filtered": 100.0}]},
           "verified": True, "us_zero": 0.0}
    flat = flatten_bench_times(old)
    assert set(flat) == {"configs.0.us_ref", "configs.0.us_fused",
                         "configs.1.us_ref", "configs.1.us_fused",
                         "gate.rows.0.us_stream", "gate.rows.0.us_filtered"}
    med, ratios, ok = bench_regression(old, old)
    assert med == 1.0 and ok and len(ratios) == 6
    # one noisy outlier doesn't fail the median-of-window gate
    new = {**old, "configs": [{"K": 1, "us_ref": 300.0, "us_fused": 50.0},
                              old["configs"][1]]}
    med, _, ok = bench_regression(old, new)
    assert ok and med == 1.0
    # a broad slowdown does
    slow = {"configs": [{"K": 1, "us_ref": 150.0, "us_fused": 75.0},
                        {"K": 2, "us_ref": 300.0, "us_fused": 120.0}],
            "gate": {"rows": [{"us_stream": 1500.0, "us_filtered": 150.0}]}}
    med, _, ok = bench_regression(old, slow)
    assert not ok and med == pytest.approx(1.5)
    # no baseline -> pass
    assert bench_regression(None, old) == (None, {}, True)
