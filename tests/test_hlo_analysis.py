"""Trip-count-aware HLO cost analyzer vs XLA's cost_analysis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def _xla_cost(compiled) -> dict:
    """cost_analysis() returns a dict in jax>=0.4.31, a 1-list before."""
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, (list, tuple)) else cost


def test_matches_xla_on_scan_free_program():
    def f(x, w1, w2):
        return jnp.sum(jnp.tanh(x @ w1) @ w2)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for s in [(128, 256), (256, 512), (512, 64)]]
    c = _compile(f, *specs)
    mine = ha.analyze(c.as_text())
    xla = _xla_cost(c)
    assert abs(mine["flops"] / xla["flops"] - 1) < 0.05


def test_scan_flops_scale_with_trip_count():
    def f(x, ws):
        def body(c2, w):
            return jnp.tanh(c2 @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    flops = {}
    for n in (4, 16):
        specs = [jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((n, 256, 256), jnp.float32)]
        c = _compile(f, *specs)
        mine = ha.analyze(c.as_text())
        xla = _xla_cost(c)
        expected = n * 2 * 128 * 256 * 256
        assert abs(mine["flops"] / expected - 1) < 0.05, (n, mine["flops"])
        # and XLA's raw number does NOT scale (the bug we correct)
        flops[n] = (mine["flops"], xla["flops"])
    assert flops[16][1] == flops[4][1]
    assert flops[16][0] > 3.5 * flops[4][0]


def test_nested_scans_multiply():
    def f(x, ws):
        def outer(c2, w):
            def inner(c3, _):
                return jnp.tanh(c3 @ w), None
            c2, _ = jax.lax.scan(inner, c2, jnp.arange(3))
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return jnp.sum(y)

    specs = [jax.ShapeDtypeStruct((64, 128), jnp.float32),
             jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)]
    mine = ha.analyze(_compile(f, *specs).as_text())
    expected = 5 * 3 * 2 * 64 * 128 * 128
    assert abs(mine["flops"] / expected - 1) < 0.1


def test_dus_accumulation_not_overcharged():
    """Scan ys accumulation must be charged per-slice, not per-buffer:
    bytes must scale ~linearly in trip count, not quadratically."""
    def f(x, ws):
        def body(c2, w):
            h = jnp.tanh(c2 @ w)
            return h, h
        _, ys = jax.lax.scan(body, x, ws)
        return ys

    per = {}
    for n in (8, 32):
        specs = [jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)]
        mine = ha.analyze(_compile(f, *specs).as_text())
        per[n] = mine["bytes"] / n
    assert per[32] < per[8] * 1.8, per  # superlinear growth = overcharge


def test_collectives_counted_with_trip_multipliers():
    """A psum inside a scan must be charged trip-count times."""
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # shard_map-free proxy: verify the parser on a synthetic module
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[128,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[128,128]{1,0}) tuple(%i2, %ar)
}

%cond (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]{1,0}) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main () -> f32[] {
  %c = f32[128,128]{1,0} constant(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[128,128]{1,0}) tuple(%z, %c)
  %w = (s32[], f32[128,128]{1,0}) while(%init), condition=%cond, body=%body
  %r = f32[128,128]{1,0} get-tuple-element(%w), index=1
  ROOT %out = f32[] constant(0)
}
"""
    res = ha.analyze(hlo)
    assert res["collectives"]["all-reduce"]["count"] == 12
    assert res["collective_wire_bytes"] == 12 * 128 * 128 * 4
