"""Model-substrate correctness: prefill/decode consistency for every
cache type, and the chunkwise mLSTM equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mach import MACHConfig
from repro.models import LanguageModel, ModelConfig
from repro.models.xlstm import (MLSTMState, _mlstm_chunkwise,
                                _mlstm_parallel, _mlstm_step,
                                init_mlstm_state)

BASE = dict(d_model=64, num_heads=4, d_ff=128, vocab_size=100,
            dtype=jnp.float32, scan_layers=True)


def _decode_consistency(cfg, batch_extra=None, atol=2e-3):
    """Full forward == prefill + per-token decode, for every block/cache
    type (linear KV, ring/SWA KV, RG-LRU state, xLSTM states, cross-attn)."""
    m = LanguageModel(cfg)
    params, _ = m.init(jax.random.key(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.key(5), (B, T), 0, cfg.vocab_size)
    batch_extra = batch_extra or {}
    enc_kvs = None
    if cfg.num_encoder_layers:
        enc_out = m.encode(params, batch_extra["enc_feats"])
        enc_kvs = m.enc_kvs(params, enc_out)
    h_full, _, _ = m.hidden_states(params, toks, enc_kvs=enc_kvs)
    P = T - 3
    caches, enc_kvs2, h_last = m.prefill(
        params, {"tokens": toks[:, :P], **batch_extra}, max_len=T + 4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_full[:, P - 1]),
                               atol=atol, rtol=1e-2)
    for i in range(3):
        pos = jnp.full((B,), P + i, jnp.int32)
        caches, h = m.decode_step(params, caches, enc_kvs2, toks[:, P + i], pos)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_full[:, P + i]),
                                   atol=atol, rtol=1e-2)


def test_decode_consistency_dense_gqa():
    _decode_consistency(ModelConfig(name="d", num_layers=3, num_kv_heads=2,
                                    **BASE))


def test_decode_consistency_swa_ring_cache():
    _decode_consistency(ModelConfig(name="swa", num_layers=3, num_kv_heads=2,
                                    attention_kind="sliding_window", window=5,
                                    **BASE))


def test_decode_consistency_rglru_hybrid():
    _decode_consistency(ModelConfig(
        name="rg", num_layers=5, num_kv_heads=1, family="hybrid",
        block_pattern=("rglru", "rglru", "attn_local"), local_window=6,
        **BASE))


def test_decode_consistency_xlstm():
    _decode_consistency(ModelConfig(name="xl", num_layers=4, num_kv_heads=4,
                                    family="xlstm",
                                    block_pattern=("mlstm", "slstm"), **BASE))


def test_decode_consistency_enc_dec():
    _decode_consistency(
        ModelConfig(name="ed", num_layers=2, num_kv_heads=4,
                    family="enc_dec", num_encoder_layers=2, frontend="audio",
                    **BASE),
        {"enc_feats": jax.random.normal(jax.random.key(7), (2, 9, 1024))})


def test_decode_consistency_mach_head():
    _decode_consistency(ModelConfig(name="mh", num_layers=2, num_kv_heads=2,
                                    mach=MACHConfig(100, 16, 4), **BASE))


# ---------------------------------------------------------------------------
# chunkwise mLSTM equivalences (the long-context substrate)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mlstm_inputs():
    B, T, H, hd = 2, 128, 4, 32
    ks = jax.random.split(jax.random.key(0), 5)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    li = jax.random.normal(ks[3], (B, T, H)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)) + 2.0)
    return q, k, v, li, lf


def test_mlstm_chunkwise_equals_parallel(mlstm_inputs):
    q, k, v, li, lf = mlstm_inputs
    B, T, H, hd = q.shape
    h_par = _mlstm_parallel(q, k, v, li, lf)
    for chunk in (T, 32):
        h_ck, _ = _mlstm_chunkwise(q, k, v, li, lf,
                                   init_mlstm_state(B, H, hd), chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_ck),
                                   rtol=2e-4, atol=2e-5)


def test_mlstm_chunkwise_state_equals_recurrence(mlstm_inputs):
    q, k, v, li, lf = mlstm_inputs
    B, T, H, hd = q.shape
    _, st_ck = _mlstm_chunkwise(q, k, v, li, lf,
                                init_mlstm_state(B, H, hd), chunk=32)
    st = init_mlstm_state(B, H, hd)
    for t in range(T):
        st, _ = _mlstm_step(st, q[:, t], k[:, t], v[:, t], li[:, t], lf[:, t])
    for a, b in zip(st_ck, st):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-4)


def test_mlstm_chunkwise_memory_is_subquadratic():
    """The chunkwise form never materializes a (T, T) tensor: jaxpr-level
    check that no intermediate has T² elements."""
    B, T, H, hd = 1, 512, 2, 16
    q = k = v = jnp.zeros((B, T, H, hd))
    li = lf = jnp.zeros((B, T, H))
    jaxpr = jax.make_jaxpr(
        lambda *a: _mlstm_chunkwise(*a, init_mlstm_state(B, H, hd), 64))(
            q, k, v, li, lf)
    biggest = max(
        (int(np.prod(v2.aval.shape)) for eqn in jaxpr.eqns
         for v2 in eqn.outvars if hasattr(v2.aval, "shape")), default=0)
    assert biggest < T * T, biggest
