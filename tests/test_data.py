"""Data pipelines: determinism, restart-safety, host sharding."""

import jax.numpy as jnp
import numpy as np

from repro.data import (ExtremeDataConfig, ExtremeDataset, LMDataConfig,
                        SyntheticLMStream)


def test_lm_stream_deterministic_and_restart_safe():
    cfg = LMDataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=3)
    s1 = SyntheticLMStream(cfg)
    s2 = SyntheticLMStream(cfg)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(np.asarray(s1.batch_at(step)["tokens"]),
                                      np.asarray(s2.batch_at(step)["tokens"]))
    # different steps differ
    assert not np.array_equal(np.asarray(s1.batch_at(0)["tokens"]),
                              np.asarray(s1.batch_at(1)["tokens"]))


def test_lm_stream_host_sharding_disjoint():
    cfg = LMDataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=0)
    h0 = SyntheticLMStream(cfg, host_index=0, host_count=2)
    h1 = SyntheticLMStream(cfg, host_index=1, host_count=2)
    b0 = np.asarray(h0.batch_at(7)["tokens"])
    b1 = np.asarray(h1.batch_at(7)["tokens"])
    assert b0.shape == (4, 17) and b1.shape == (4, 17)
    assert not np.array_equal(b0, b1)


def test_lm_stream_has_learnable_structure():
    """Planted bigrams: successor correlation is present (otherwise the
    example training loop would have nothing to learn)."""
    cfg = LMDataConfig(vocab_size=64, seq_len=256, global_batch=4,
                       bigram_p=0.5)
    s = SyntheticLMStream(cfg)
    toks = np.asarray(s.batch_at(0)["tokens"])
    pred = (toks[:, :-1] * 31 + 7) % 64
    rate = float(np.mean(pred == toks[:, 1:]))
    # substitution applies to the *base* chain, so the observable rate is
    # ~bigram_p² + noise ≈ 0.27 — still ~17x above the 1/64 chance level
    assert rate > 0.2, rate


def test_lm_stream_modalities():
    cfg = LMDataConfig(vocab_size=10, seq_len=4, global_batch=2,
                       enc_feats_dim=8, enc_len=5,
                       prefix_feats_dim=6, prefix_len=3)
    b = SyntheticLMStream(cfg).batch_at(0)
    assert b["enc_feats"].shape == (2, 5, 8)
    assert b["prefix_feats"].shape == (2, 3, 6)


def test_extreme_dataset_splits_and_bayes():
    ds = ExtremeDataset(ExtremeDataConfig(num_classes=64, dim=32, noise=0.2))
    xtr, ytr = ds.batch_at(0, 128, "train")
    xte, yte = ds.batch_at(0, 128, "test")
    assert not np.array_equal(np.asarray(xtr), np.asarray(xte))
    acc = ds.bayes_accuracy(steps=2, batch_size=256)
    assert acc > 0.7
    # zipf tail: frequent classes dominate
    _, y = ds.batch_at(1, 4096)
    counts = np.bincount(np.asarray(y), minlength=64)
    assert counts[:8].sum() > counts[-32:].sum()


def test_sparse_dataset_deterministic_and_dense_fallback():
    from repro.data import SparseExtremeDataConfig, SparseExtremeDataset

    cfg = SparseExtremeDataConfig(num_classes=64, num_features=96, nnz=8,
                                  sig_features=4, seed=5)
    ds1, ds2 = SparseExtremeDataset(cfg), SparseExtremeDataset(cfg)
    sb1, y1 = ds1.batch_at(3, 16)
    sb2, y2 = ds2.batch_at(3, 16)
    np.testing.assert_array_equal(np.asarray(sb1.indices),
                                  np.asarray(sb2.indices))
    np.testing.assert_array_equal(np.asarray(sb1.values),
                                  np.asarray(sb2.values))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # CSR invariants: fixed-nnz indptr, in-range ids, normalized rows
    assert sb1.nnz_max == 8 and sb1.num_features == 96
    assert sb1.num_rows == 16
    np.testing.assert_array_equal(np.asarray(sb1.indptr),
                                  np.arange(17) * 8)
    assert int(jnp.max(sb1.indices)) < 96
    # dense fallback is the exact densification of the same batch
    xd, yd = ds1.batch_at(3, 16, format="dense")
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(y1))
    np.testing.assert_allclose(np.asarray(xd), np.asarray(sb1.to_dense()),
                               rtol=0, atol=0)
    # different steps / splits differ
    sb3, _ = ds1.batch_at(4, 16)
    assert not np.array_equal(np.asarray(sb1.indices),
                              np.asarray(sb3.indices))
    sbt, _ = ds1.batch_at(3, 16, "test")
    assert not np.array_equal(np.asarray(sb1.indices),
                              np.asarray(sbt.indices))


def test_sparse_dataset_zipf_doc_lengths():
    """length_zipf_a > 0: ragged CSR rows — lengths in
    [sig_features, nnz], Zipf-skewed toward short docs, deterministic
    in (seed, step), and the dense fallback still densifies exactly."""
    from repro.data import SparseExtremeDataConfig, SparseExtremeDataset

    cfg = SparseExtremeDataConfig(num_classes=64, num_features=96, nnz=12,
                                  sig_features=4, seed=5,
                                  length_zipf_a=1.0)
    ds1, ds2 = SparseExtremeDataset(cfg), SparseExtremeDataset(cfg)
    sb1, y1 = ds1.batch_at(3, 64)
    sb2, y2 = ds2.batch_at(3, 64)
    np.testing.assert_array_equal(np.asarray(sb1.indptr),
                                  np.asarray(sb2.indptr))
    np.testing.assert_array_equal(np.asarray(sb1.indices),
                                  np.asarray(sb2.indices))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    lens = np.diff(np.asarray(sb1.indptr))
    assert lens.min() >= 4 and lens.max() <= 12
    assert len(np.unique(lens)) > 1              # actually ragged
    # Zipf skew: short docs outnumber long ones
    assert (lens <= 7).sum() > (lens > 7).sum()
    # rows stay L2-normalized over their kept entries
    vals = np.asarray(sb1.values)
    indptr = np.asarray(sb1.indptr)
    for i in range(sb1.num_rows):
        np.testing.assert_allclose(
            np.linalg.norm(vals[indptr[i]:indptr[i + 1]]), 1.0,
            rtol=1e-5)
    # dense fallback is the exact densification of the ragged batch
    xd, yd = ds1.batch_at(3, 64, format="dense")
    np.testing.assert_array_equal(np.asarray(yd), np.asarray(y1))
    np.testing.assert_allclose(np.asarray(xd),
                               np.asarray(sb1.to_dense()),
                               rtol=0, atol=0)


def test_sparse_batch_is_jit_transparent():
    import jax

    from repro.data import SparseBatch

    sb = SparseBatch(indptr=jnp.asarray([0, 2, 3], jnp.int32),
                     indices=jnp.asarray([1, 3, 0], jnp.int32),
                     values=jnp.asarray([1.0, 2.0, 3.0]),
                     num_features=5, nnz_max=2)

    @jax.jit
    def dense_sum(batch):
        return jnp.sum(batch.to_dense(), axis=1)

    np.testing.assert_allclose(np.asarray(dense_sum(sb)),
                               np.array([3.0, 3.0]), rtol=0, atol=0)
    leaves, treedef = jax.tree.flatten(sb)
    assert len(leaves) == 3
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.num_features == 5 and rebuilt.nnz_max == 2
