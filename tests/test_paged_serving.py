"""Paged KV cache: page pool math, allocator, and engine invariants.

The contiguous slot engine's theorems (exact greedy parity with solo
decode, free-slot inertness, per-request PRNG independence of slot /
page / admission order) must all survive the paged refactor, plus the
paged-only properties: deterministic alloc/free/reuse, no page aliasing
across live requests, reservation backpressure (queue, never crash),
and stale-contents masking on recycled pages.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import intermediate_avals
from repro.core.mach import MACHConfig
from repro.kernels import ops
from repro.models import LanguageModel, ModelConfig
from repro.models import attention as attn_lib
from repro.serving import Request, SamplingParams, ServeConfig, ServingEngine
from repro.serving.engine import make_serve_step_fn


@pytest.fixture(scope="module")
def served():
    cfg = ModelConfig(name="srv-paged", num_layers=2, d_model=48,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=200,
                      dtype=jnp.float32, mach=MACHConfig(200, 16, 4))
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("num_slots", 3)
    kw.setdefault("max_new_tokens", 6)
    kw.setdefault("page_size", 4)
    return ServingEngine(model, params, ServeConfig(**kw))


def _run(model, params, reqs, **kw):
    eng = _engine(model, params, **kw)
    for r in reqs:
        eng.submit(r)
    return [list(r.tokens) for r in eng.run()], eng


RAGGED = [([1, 2, 3], 6), ([4, 5], 2), ([6, 7, 8, 9], 6), ([10], 2),
          ([11, 12, 13, 14, 15, 16, 17], 8), ([18, 19], 4)]


# ---------------------------------------------------------------------------
# pool math units (no engine)
# ---------------------------------------------------------------------------

def _toy_contiguous(cap=8, prompt_len=6, seed=0):
    """Batch-1 contiguous cache as the engine's prefill would build it."""
    kv, hd = 2, 8
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((1, cap, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, cap, kv, hd)), jnp.float32)
    pos = jnp.where(jnp.arange(cap) < prompt_len, jnp.arange(cap), -1)[None]
    return attn_lib.KVCache(k=k, v=v, positions=pos.astype(jnp.int32),
                            index=jnp.asarray([prompt_len], jnp.int32))


def test_paged_insert_then_attend_matches_contiguous():
    """Insert a batch-1 strip into non-contiguous pool pages; paged
    attention over the page table must match dense attention over the
    strip (same mask, online-softmax numerics)."""
    one = _toy_contiguous()
    pool = attn_lib.init_paged_cache(num_slots=3, num_pages=5, page_size=4,
                                     max_pages=4, num_kv=2, head_dim=8,
                                     dtype=jnp.float32)
    pages = jnp.asarray([3, 1], jnp.int32)         # deliberately unordered
    pool = attn_lib.paged_insert_prefill(pool, one, 1, pages)
    assert pool.index[1] == 6 and pool.index[0] == 0
    assert list(pool.page_table[1]) == [3, 1, -1, -1]

    rng = np.random.default_rng(9)
    q1 = jnp.asarray(rng.standard_normal((1, 1, 4, 8)), jnp.float32)
    want = attn_lib.decode_attend(q1, one)
    q_all = jnp.zeros((3, 1, 4, 8), jnp.float32).at[1].set(q1[0])
    got = attn_lib.paged_decode_attend(q_all, pool)
    np.testing.assert_allclose(got[1], want[0], atol=1e-5)
    # slots with an empty page table attend to nothing -> exactly zero
    assert not np.any(np.asarray(got[0])) and not np.any(np.asarray(got[2]))


def test_paged_decode_write_matches_contiguous():
    one = _toy_contiguous()
    pool = attn_lib.init_paged_cache(3, 5, 4, 4, 2, 8, jnp.float32)
    pool = attn_lib.paged_insert_prefill(pool, one,
                                         jnp.asarray(1),
                                         jnp.asarray([0, 2], jnp.int32))
    rng = np.random.default_rng(3)
    k1 = jnp.asarray(rng.standard_normal((1, 1, 2, 8)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((1, 1, 2, 8)), jnp.float32)
    one2 = attn_lib.cache_update_decode(one, k1, v1, ring=False,
                                        per_row=True)
    k_all = jnp.zeros((3, 1, 2, 8), jnp.float32).at[1].set(k1[0])
    pool2 = attn_lib.paged_cache_update_decode(pool, k_all,
                                               k_all.at[1].set(v1[0]))
    assert pool2.index[1] == 7
    q1 = jnp.asarray(rng.standard_normal((1, 1, 4, 8)), jnp.float32)
    want = attn_lib.decode_attend(q1, one2)
    q_all = jnp.zeros((3, 1, 4, 8), jnp.float32).at[1].set(q1[0])
    got = attn_lib.paged_decode_attend(q_all, pool2)
    np.testing.assert_allclose(got[1], want[0], atol=1e-5)
    # free slots (table -1) dropped their write: pool bytes untouched
    assert pool2.index[0] == 1                     # index advances...
    np.testing.assert_array_equal(pool2.page_table[0], -1)  # ...inert


def test_recycled_page_stale_positions_masked():
    """A freed page keeps its contents; the next decode write at page
    offset 0 must rewrite the whole position row so none of the stale
    positions survive into the attention mask."""
    one = _toy_contiguous(cap=4, prompt_len=4)     # one full page
    pool = attn_lib.init_paged_cache(2, 3, 4, 2, 2, 8, jnp.float32)
    pool = attn_lib.paged_insert_prefill(pool, one,
                                         jnp.asarray(0),
                                         jnp.asarray([1], jnp.int32))
    assert list(pool.positions[1]) == [0, 1, 2, 3]
    pool = attn_lib.paged_reset_slot(pool, jnp.asarray(0))
    np.testing.assert_array_equal(pool.page_table[0], -1)
    assert list(pool.positions[1]) == [0, 1, 2, 3]  # stale, by design

    # slot 1 (fresh request, index 0) is handed recycled page 1
    pool = pool._replace(index=pool.index.at[1].set(0))
    pool = attn_lib.paged_append_page(pool, jnp.asarray(1), jnp.asarray(0),
                                      jnp.asarray(1))
    k1 = jnp.ones((2, 1, 2, 8), jnp.float32)
    pool = attn_lib.paged_cache_update_decode(pool, k1, k1)
    assert list(pool.positions[1]) == [0, -1, -1, -1]


# ---------------------------------------------------------------------------
# engine: parity + invariants re-proved paged
# ---------------------------------------------------------------------------

def test_paged_greedy_parity_with_contiguous_ragged(served):
    """Bit-identical greedy tokens, contiguous vs paged, on a ragged
    workload that recycles slots and pages mid-decode."""
    cfg, model, params = served
    reqs = [Request(prompt=p, max_new_tokens=mn) for p, mn in RAGGED]
    cont, _ = _run(model, params, reqs, page_size=0, num_slots=2)
    paged, eng = _run(model, params, reqs, num_slots=2, num_pages=8)
    assert cont == paged
    assert eng.metrics.prefills == len(RAGGED)


def test_paged_seeded_sampling_parity_with_contiguous(served):
    """Sampled continuations are keyed per request, never per page:
    explicit seeds give bit-identical tokens on both layouts."""
    cfg, model, params = served
    reqs = [Request(prompt=p, max_new_tokens=mn,
                    sampling=SamplingParams(temperature=0.9, top_k=8,
                                            seed=50 + i))
            for i, (p, mn) in enumerate(RAGGED)]
    cont, _ = _run(model, params, reqs, page_size=0, num_slots=2)
    paged, _ = _run(model, params, reqs, num_slots=2, num_pages=8)
    assert cont == paged


def test_paged_free_slot_inertness(served):
    """Free slots in a paged pool cannot touch the pool (their table
    rows are -1 and writes drop): a lone request in a wide engine
    matches its solo run exactly."""
    cfg, model, params = served
    solo, _ = _run(model, params, [Request(prompt=[3, 1, 4])], num_slots=1)
    wide, _ = _run(model, params, [Request(prompt=[3, 1, 4])], num_slots=3)
    assert solo == wide


def test_paged_queue_order_independence(served):
    """An explicitly seeded request's continuation is independent of
    queue order — and therefore of which pages it lands in."""
    cfg, model, params = served

    def run_A(order):
        eng = _engine(model, params, seed=7)
        rid = None
        for name in order:
            if name == "A":
                rid = eng.submit(Request(prompt=[3, 7],
                                         sampling=SamplingParams(
                                             temperature=1.3, top_k=8,
                                             seed=99)))
            else:
                eng.submit(Request(prompt=[9, 1, 4]))
        return {r.request_id: r.tokens for r in eng.run()}[rid]

    assert run_A(["A", "B", "C"]) == run_A(["B", "C", "A"]) == run_A(["A"])


def test_freed_pages_recycled_without_leakage(served):
    """num_slots=1 with a pool exactly one request wide: every request
    after the first decodes entirely in recycled pages and must still
    match its solo reference."""
    cfg, model, params = served
    prompts = [[1, 2, 3], [4, 5, 6, 7], [8, 9]]
    # each request worst-case needs ceil((4+4-1)/4) = 2 pages
    got, eng = _run(model, params,
                    [Request(prompt=p, max_new_tokens=4) for p in prompts],
                    num_slots=1, num_pages=2, max_new_tokens=4)
    for p, toks in zip(prompts, got):
        solo, _ = _run(model, params, [Request(prompt=p, max_new_tokens=4)],
                       num_slots=1, num_pages=2, max_new_tokens=4)
        assert [toks] == solo
    assert sorted(eng._free_pages) == [0, 1]


# ---------------------------------------------------------------------------
# allocator: determinism, aliasing, backpressure
# ---------------------------------------------------------------------------

def _step_trace(model, params, **kw):
    """Drive an engine tick by tick; record the page assignment of every
    live slot after each tick and check the aliasing invariants."""
    eng = _engine(model, params, **kw)
    for p, mn in RAGGED:
        eng.submit(Request(prompt=p, max_new_tokens=mn))
    trace = []
    while eng.queue_depth or any(s is not None for s in eng._slots):
        eng.step()
        live = {s.req_id: tuple(s.pages) for s in eng._slots
                if s is not None}
        trace.append(live)
        # no page aliasing: every allocated page belongs to exactly one
        # live request, and never to the free list
        allocated = [p for pages in live.values() for p in pages]
        assert len(allocated) == len(set(allocated)), live
        assert not set(allocated) & set(eng._free_pages)
        assert len(allocated) + len(eng._free_pages) == eng._num_pages
    return trace, eng


def test_page_allocator_deterministic_and_alias_free(served):
    cfg, model, params = served
    t1, e1 = _step_trace(model, params, num_slots=2, num_pages=8)
    t2, e2 = _step_trace(model, params, num_slots=2, num_pages=8)
    assert t1 == t2                      # alloc/free/reuse fully replayed
    assert list(e1._free_pages) == list(e2._free_pages)
    # pages were actually recycled across requests somewhere in the run
    owners = {}
    for live in t1:
        for rid, pages in live.items():
            for p in pages:
                owners.setdefault(p, set()).add(rid)
    assert any(len(v) > 1 for v in owners.values())


def test_reservation_exhaustion_queues_instead_of_crashing(served):
    cfg, model, params = served
    # 3 pages: one 2-page reservation at a time + 1 spare; 4 slots idle
    got, eng = _run(model, params,
                    [Request(prompt=[1 + i, 2, 3], max_new_tokens=4)
                     for i in range(4)],
                    num_slots=4, num_pages=3, max_new_tokens=4)
    assert len(got) == 4 and all(len(t) == 4 for t in got)
    m = eng.metrics
    assert m.reservation_failures > 0
    assert m.pages_peak <= 3
    assert m.pages_in_use == 0 and m.pages_reserved == 0
    assert m.fragmentation == 0
    assert m.peak_live_slots < 4         # page-bound, not slot-bound


def test_submit_rejects_request_larger_than_pool(served):
    cfg, model, params = served
    eng = _engine(model, params, num_pages=4)        # 16-token pool
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(prompt=list(range(1, 15)), max_new_tokens=6))
    # an impossible request must not poison the engine
    eng.submit(Request(prompt=[1, 2], max_new_tokens=2))
    assert len(eng.run()) == 1


def test_lockstep_requires_contiguous_layout(served):
    cfg, model, params = served
    with pytest.raises(ValueError, match="lockstep"):
        _engine(model, params, scheduler="lockstep")
    # the ablation baseline still runs on the contiguous path
    outs, _ = _run(model, params, [Request(prompt=[1, 2, 3])],
                   page_size=0, scheduler="lockstep")
    assert len(outs) == 1


def test_paged_metrics_gauges_and_repr(served):
    cfg, model, params = served
    eng = _engine(model, params, num_slots=2, num_pages=8)
    for p, mn in RAGGED[:3]:
        eng.submit(Request(prompt=p, max_new_tokens=mn))
    eng.run()
    m = eng.metrics
    assert m.num_pages == 8 and m.pages_peak > 0
    assert m.pages_in_use == 0 and m.pages_reserved == 0
    assert m.peak_live_slots == 2
    r = repr(eng)
    assert "pages=0/8" in r and "peak=" in r


# ---------------------------------------------------------------------------
# jaxpr: the decode step never materializes a per-slot max_len strip
# ---------------------------------------------------------------------------

def test_paged_decode_never_materializes_max_len_strip(served):
    """No intermediate of the paged decode step may carry both the slot
    dim and the logical max_len dim — the (num_slots, max_len) strip is
    exactly what the page pool exists to kill.  Dims are chosen to
    collide with nothing else in the model (d_model=48, heads=4)."""
    cfg, model, params = served
    slots, max_len, page_size = 5, 40, 5
    serve_step = make_serve_step_fn(model, top_k=8)
    pool = model.init_paged_caches(slots, max_len, page_size, 10)
    z = jnp.zeros((slots,), jnp.int32)
    fn = functools.partial(serve_step, estimators=("unbiased",),
                           max_len=max_len)
    orig = ops.mach_topk
    ops.mach_topk = functools.partial(orig, use_pallas=True, interpret=True)
    try:
        jaxpr = jax.make_jaxpr(fn)(
            params, pool, None, {"tokens": jnp.zeros((slots, 1), jnp.int32)},
            z, jax.random.key(0), z, z,
            jnp.full((slots,), 0.9, jnp.float32),
            jnp.full((slots,), 4, jnp.int32), z).jaxpr
    finally:
        ops.mach_topk = orig
    bad = [tuple(a.shape) for a in intermediate_avals(jaxpr)
           if hasattr(a, "shape") and slots in a.shape
           and max_len in a.shape]
    assert not bad, bad
