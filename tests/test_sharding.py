"""Partitioning rules: divisibility fallbacks, axis-conflict handling."""

import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.partitioning import ShardingRules, resolve_spec


class FakeMesh:
    """resolve_spec only touches .shape and .axis_names."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})
RULES = ShardingRules(fsdp=True, sp=False)


def _spec(mesh, axes, shape, rules=RULES):
    return resolve_spec(mesh, rules.table(mesh), axes, shape)


def test_tp_sharding_divisible():
    # mistral attention kernel (d, H, hd): d->data (fsdp), H->model
    assert _spec(MESH1, ("embed", "heads", "qkv"), (12288, 96, 128)) == \
        P("data", "model")


def test_heads_fallback_when_not_divisible():
    # paligemma: 8 heads % 16 -> replicated heads, fsdp on d_model
    assert _spec(MESH1, ("embed", "heads", "qkv"), (2048, 8, 256)) == \
        P("data")
    # recurrentgemma: 10 heads
    assert _spec(MESH1, ("embed", "heads", "qkv"), (2560, 10, 256)) == \
        P("data")


def test_mqa_kv_replicated():
    assert _spec(MESH1, ("embed", "kv_heads", "qkv"), (6144, 1, 128)) == \
        P("data")


def test_vocab_and_mach_rb():
    assert _spec(MESH1, ("vocab", "embed"), (256000, 2560)) == \
        P("model", "data")
    assert _spec(MESH1, ("embed", "mach_rb"), (2048, 16384)) == \
        P("data", "model")


def test_axis_conflict_first_wins():
    # experts grabs 'model' when divisible; mlp then falls back
    rules = RULES.table(MESH1)
    spec = resolve_spec(MESH1, rules, ("experts", "embed", "mlp"),
                        (16, 4096, 1408))
    assert spec == P("model", "data")
    # 60 experts don't divide 16 -> mlp gets model instead
    spec2 = resolve_spec(MESH1, rules, ("experts", "embed", "mlp"),
                         (60, 2048, 1408))
    assert spec2 == P(None, "data", "model")


def test_batch_uses_pod_axis_when_present():
    assert _spec(MESH2, ("batch", None), (512, 100)) == P(("pod", "data"))
    # batch=1 (long_500k) cannot shard -> replicated
    assert _spec(MESH2, ("batch", None), (1, 100)) == P()


def test_no_fsdp_disables_embed_sharding():
    rules = ShardingRules(fsdp=False)
    assert resolve_spec(MESH1, rules.table(MESH1),
                        ("embed", "heads", "qkv"), (4096, 32, 128)) == \
        P(None, "model")


def test_sp_shards_seq():
    rules = ShardingRules(fsdp=True, sp=True)
    assert resolve_spec(MESH1, rules.table(MESH1),
                        ("batch", "seq", None), (256, 4096, 8192)) == \
        P("data", "model")
    # seq=1 decode falls back
    assert resolve_spec(MESH1, rules.table(MESH1),
                        ("batch", "seq", None), (256, 1, 8192)) == P("data")


def test_state_shardings_keyed_by_path_not_shape():
    """Two params with the same shape but different shardings: optimizer
    moments must inherit their *own* param's sharding (the old
    shape-keyed map silently gave both the first one's)."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.optim import make_optimizer
    from repro.sharding import partitioning as part

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))

    class TwoParamModel:
        def init(self, key):
            p = {"emb": jnp.zeros((64, 128)), "head": jnp.zeros((64, 128))}
            a = {"emb": ("embed", "mach_rb"), "head": ("vocab", "embed")}
            return p, a

    opt = make_optimizer("adamw", 1e-3)
    _, shard, _ = part.state_shardings(mesh, ShardingRules(fsdp=True),
                                       TwoParamModel(), opt)
    p = shard.params
    assert p["emb"].spec == P("data", "model")
    assert p["head"].spec == P("model", "data")     # same shape, different
    for tree in (shard.opt_state.mu, shard.opt_state.nu):
        assert tree["emb"].spec == p["emb"].spec
        assert tree["head"].spec == p["head"].spec
    assert shard.opt_state.count.spec == P()        # scalar replicates

    # adafactor's factored moments don't match any param shape -> replicate
    _, shard_af, _ = part.state_shardings(
        mesh, ShardingRules(fsdp=True), TwoParamModel(),
        make_optimizer("adafactor", 1e-3))
    assert shard_af.opt_state.vr["head"].spec == P()
    assert shard_af.opt_state.vc["head"].spec == P()


def test_mach_pod_parallel_rule():
    """MACH R-heads shard over (pod, model) — the paper's embarrassing
    parallelism as a mesh axis (DESIGN.md §4)."""
    rules = ShardingRules(fsdp=False, mach_pod_parallel=True)
    spec = resolve_spec(MESH2, rules.table(MESH2),
                        ("embed", "mach_rb"), (2048, 16384))
    assert spec == P(None, ("pod", "model"))


def test_state_shardings_suffix_index_large_tree_with_collisions():
    """The O(params) suffix-tuple index: a deep tree where every layer's
    leaves share terminal path components ('w', 'b') — and a nested
    'block.w' whose suffix collides with a top-level 'w' of the SAME
    shape but a different sharding.  Each moment must still inherit its
    own param's sharding (longest exact suffix wins)."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.optim import make_optimizer
    from repro.sharding import partitioning as part

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    n_layers = 24

    class DeepModel:
        def init(self, key):
            p = {"w": jnp.zeros((64, 128)),
                 "block": {"w": jnp.zeros((64, 128))}}
            a = {"w": ("embed", "mach_rb"),
                 "block": {"w": ("vocab", "embed")}}
            for i in range(n_layers):
                # alternate axes so neighbouring layers shard differently
                ax = ("embed", "mlp") if i % 2 else ("heads", "embed")
                p[f"layer_{i}"] = {"w": jnp.zeros((32, 16)),
                                   "b": jnp.zeros((16,))}
                a[f"layer_{i}"] = {"w": ax, "b": (None,)}
            return p, a

    opt = make_optimizer("adamw", 1e-3)
    _, shard, _ = part.state_shardings(mesh, ShardingRules(fsdp=True),
                                       DeepModel(), opt)
    p = shard.params
    # the collision: same shape, same terminal component, different spec
    assert p["w"].spec == P("data", "model")
    assert p["block"]["w"].spec == P("model", "data")
    for tree in (shard.opt_state.mu, shard.opt_state.nu):
        assert tree["w"].spec == p["w"].spec
        assert tree["block"]["w"].spec == p["block"]["w"].spec
        for i in range(n_layers):
            assert tree[f"layer_{i}"]["w"].spec == \
                p[f"layer_{i}"]["w"].spec
            assert tree[f"layer_{i}"]["b"].spec == \
                p[f"layer_{i}"]["b"].spec
    assert shard.opt_state.count.spec == P()
