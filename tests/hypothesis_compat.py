"""Optional-dependency shim for ``hypothesis``.

The tier-1 suite must run green without the optional property-testing
dependency.  Importing ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` keeps every non-property test in a module
collectable; when hypothesis is missing, each ``@given`` test is
replaced by an explicitly *skipped* placeholder (visible in the report)
rather than an ImportError that kills collection of the whole module.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
