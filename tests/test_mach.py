"""MACH classifier behaviour: learning, parallelism, estimators, heads.

Trained models are built once (module-scoped fixture) on the synthetic
extreme-classification task with a known Bayes optimum; thresholds are
fractions of the measured OAA/Bayes accuracy, not absolute numbers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MACHConfig, MACHLinear, MACHOutputHead, OAAClassifier
from repro.data import ExtremeDataConfig, ExtremeDataset
from repro.optim import adamw, apply_updates

K, D = 1024, 256


def _train(ds, model, params, steps=150, lr=0.05, bs=512):
    opt = adamw(lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, g = jax.value_and_grad(model.loss)(params, x, y)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    for s in range(steps):
        x, y = ds.batch_at(s, bs)
        params, state, _ = step(params, state, x, y)
    return params


def _accuracy(ds, predict_fn, steps=3, bs=512):
    accs = []
    for s in range(steps):
        x, y = ds.batch_at(1000 + s, bs, "test")
        accs.append(float(jnp.mean(predict_fn(x) == y)))
    return float(np.mean(accs))


@pytest.fixture(scope="module")
def setup():
    ds = ExtremeDataset(ExtremeDataConfig(num_classes=K, dim=D, noise=0.1,
                                          zipf_a=0.0))
    mach_cfg = MACHConfig(K, 64, 4)                     # B·R = 256 = K/4
    mach = MACHLinear(mach_cfg, D)
    pm = _train(ds, mach, mach.init(jax.random.key(0)))
    oaa = OAAClassifier(K, D)
    po = _train(ds, oaa, oaa.init(jax.random.key(2)))
    return dict(ds=ds, mach=mach, pm=pm, oaa=oaa, po=po,
                bayes=ds.bayes_accuracy(steps=2, batch_size=512))


def test_mach_linear_learns(setup):
    """Hashed training retains discriminability (the paper's core claim):
    MACH at 4x fewer parameters reaches a large fraction of Bayes."""
    acc = _accuracy(setup["ds"], lambda x: setup["mach"].predict(setup["pm"], x))
    assert acc > 0.45 * setup["bayes"], (acc, setup["bayes"])
    assert acc > 100.0 / K                  # ~500x above random


def test_mach_vs_oaa_memory_accuracy_tradeoff(setup):
    acc_m = _accuracy(setup["ds"], lambda x: setup["mach"].predict(setup["pm"], x))
    acc_o = _accuracy(setup["ds"], lambda x: setup["oaa"].predict(setup["po"], x))
    assert setup["mach"].param_count() * 3.5 < setup["oaa"].param_count()
    assert acc_m > 0.45 * acc_o, (acc_m, acc_o)


def test_estimator_ranking_on_trained_model(setup):
    """Paper Table 3: unbiased is overall best; min is worst."""
    accs = {e: _accuracy(setup["ds"],
                         lambda x, e=e: setup["mach"].predict(setup["pm"], x,
                                                              estimator=e))
            for e in ("unbiased", "min", "median")}
    assert accs["unbiased"] >= accs["min"] - 0.02, accs
    assert accs["unbiased"] >= accs["median"] - 0.05, accs


def test_embarrassing_parallelism_gradient_decoupling(setup):
    """Paper §6.1: the R repetitions are fully independent — the joint
    loss's gradient w.r.t. repetition j's weights equals the gradient of
    repetition j trained alone.  (This is what makes the 25-GPU / 17-min
    claim trivially true, and what slice/merge_repetitions relies on.)"""
    from repro.core.mach import mach_loss

    cfg = MACHConfig(128, 16, 4)
    m = MACHLinear(cfg, dim=64)
    params = m.init(jax.random.key(3))
    ds = ExtremeDataset(ExtremeDataConfig(num_classes=128, dim=64, noise=0.2))
    x, y = ds.batch_at(0, 128)

    g_joint = jax.grad(m.loss)(params, x, y)
    tab = cfg.table()
    for j in range(4):
        pj = MACHLinear.slice_repetition(params, j)

        def loss_j(p):
            logits = (jnp.einsum("nd,db->nb", x, p["w"]) + p["b"])[:, None]
            return mach_loss(logits, jnp.take(tab[j], y)[None])

        gj = jax.grad(loss_j)(pj)
        np.testing.assert_allclose(np.asarray(g_joint["w"][:, j]),
                                   np.asarray(gj["w"]), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(g_joint["b"][j]),
                                   np.asarray(gj["b"]), rtol=1e-4, atol=1e-6)

    merged = MACHLinear.merge_repetitions(
        [MACHLinear.slice_repetition(params, j) for j in range(4)])
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_output_head_shapes_and_reduction():
    cfg = MACHConfig(50304, 2048, 8)
    head = MACHOutputHead(cfg, dim=1024)
    p = head.init(jax.random.key(0))
    h = jax.random.normal(jax.random.key(1), (3, 5, 1024))
    out = head.apply(p, h)
    assert out.shape == (3, 5, 8, 2048)
    assert head.param_count() * 3 < head.full_softmax_param_count()
    loss = head.loss(p, h, jnp.zeros((3, 5), jnp.int32))
    assert jnp.isfinite(loss)


def test_from_delta_constructor():
    cfg = MACHConfig.from_delta(105033, 32, delta=1e-3)
    assert cfg.indistinguishable_bound() <= 1e-3
    assert cfg.num_repetitions >= 2


def test_config_validates_hash_kind_at_construction():
    """hash_kind typos used to construct fine and only blow up later
    inside make_hash_family (e.g. "multshift" for "mult_shift") —
    __post_init__ must reject them like it rejects bad estimators."""
    for kind in ("auto", "carter_wegman", "mult_shift"):
        MACHConfig(100, 8, 4, hash_kind=kind)
    with pytest.raises(ValueError, match="hash_kind"):
        MACHConfig(100, 8, 4, hash_kind="multshift")
    with pytest.raises(ValueError, match="hash_kind"):
        MACHConfig(100, 8, 4, hash_kind="")


def test_oaa_loss_all_zero_weights_no_nan():
    """The maximum(sum, 1.0) guard: an all-padding batch must yield a
    finite zero loss and finite (zero) grads, not NaN."""
    oaa = OAAClassifier(16, 8)
    params = oaa.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 8))
    y = jax.random.randint(jax.random.key(2), (4,), 0, 16)
    zeros = jnp.zeros((4,))
    loss, g = jax.value_and_grad(oaa.loss)(params, x, y, zeros)
    assert float(loss) == 0.0
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
    # partial weights still average over the unmasked examples only
    w2 = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    lw = float(oaa.loss(params, x, y, w2))
    per = -jnp.take_along_axis(
        jax.nn.log_softmax(oaa.logits(params, x), axis=-1),
        y[:, None], axis=-1)[:, 0]
    np.testing.assert_allclose(lw, float((per[0] + per[2]) / 2), rtol=1e-6)
