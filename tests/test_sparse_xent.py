"""Sparse (CSR) fused projection+CE: kernel vs densified oracle, the
MACHHead abstraction, and the structural memory claims.

Parity ladder (all interpret=True on CPU):
  sparse kernel  ==  ref.mach_fused_xent_csr_ref   (values + dW/dbias)
  ops.mach_fused_xent_csr / MACHLinear.fused_loss  ==  materializing
  MACHLinear(fused=True).loss on CSR  ==  MACHLinear().loss on dense
plus the structural claims the kernel exists for: no (N, R·B) logits
tensor AND no dense (N, d) activation in the jaxpr of either pass, and
the slice/merge per-repetition API surviving a fused training step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MACHConfig, MACHHead, MACHLinear, MACHOutputHead
from repro.core.mach import is_sparse_batch
from repro.data import SparseBatch, SparseExtremeDataConfig, \
    SparseExtremeDataset
from repro.kernels import ops, ref
from repro.kernels.mach_fused_xent import (choose_sparse_blocks,
                                           mach_fused_xent_sparse_pallas)
from repro.optim import adamw, apply_updates


def _csr_case(n, d, r, b, nnz_max, seed=0, dtype=jnp.float32):
    """Shared ragged-CSR fixture (benchmarks/common.py) minus the bias —
    the benchmark's parity gate and these tests see the same inputs."""
    from benchmarks.common import make_csr_case
    indptr, indices, values, w, _, y, g = make_csr_case(
        n, d, r, b, nnz_max, seed=seed, dtype=dtype)
    return indptr, indices, values, w, y, g


# ---------------------------------------------------------------------------
# kernel vs densified reference oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,r,b,nnz", [
    (16, 96, 4, 16, 8),      # several whole heads per column block
    (13, 100, 6, 24, 5),     # ragged N and d (both padded)
    (5, 64, 25, 32, 7),      # paper ODP-ish R=25: padded head count
    (2, 48, 8, 512, 4),      # imagenet-ish B=512, tiny N
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_xent_matches_ref(n, d, r, b, nnz, dtype):
    indptr, indices, values, w, y, g = _csr_case(n, d, r, b, nnz,
                                                 dtype=dtype)
    cols, vals = ops.csr_to_ell(indptr, indices, values, nnz, d)
    lr = ref.mach_fused_xent_csr_ref(indptr, indices, values, w, y, b)
    lk = mach_fused_xent_sparse_pallas(cols, vals, w, None, y, b,
                                       None, None, None, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(lambda w_: jnp.sum(
        ref.mach_fused_xent_csr_ref(indptr, indices, values, w_, y, b)
        * g))(w)
    dk = jax.grad(lambda w_: jnp.sum(
        mach_fused_xent_sparse_pallas(cols, vals, w_, None, y, b,
                                      None, None, None, True) * g))(w)
    assert dr.dtype == dk.dtype
    # bf16 grads agree to 1 ulp (the final f32->bf16 cast may round a
    # near-midpoint value differently between the two paths)
    rtol, atol = ((1e-2, 1e-4) if dtype == jnp.bfloat16
                  else (1e-4, 1e-5))
    np.testing.assert_allclose(np.asarray(dr, np.float32),
                               np.asarray(dk, np.float32),
                               rtol=rtol, atol=atol)


def test_sparse_xent_d_blocked_and_head_split():
    """Feature dim larger than the d block AND B larger than the column
    block: the d-accumulation and the online logsumexp streaming paths
    run together."""
    n, d, r, b, nnz = 9, 200, 3, 256, 6
    indptr, indices, values, w, y, g = _csr_case(n, d, r, b, nnz)
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(n, d, r, b, nnz,
                                                  None, 64, 64)
    assert bc < b and bd < d                 # the paths under test
    cols, vals = ops.csr_to_ell(indptr, indices, values, nnz, d)
    lr = ref.mach_fused_xent_csr_ref(indptr, indices, values, w, y, b)
    lk = mach_fused_xent_sparse_pallas(cols, vals, w, None, y, b,
                                       None, 64, 64, True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lk),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(lambda w_: jnp.sum(
        ref.mach_fused_xent_csr_ref(indptr, indices, values, w_, y, b)
        * g))(w)
    dk = jax.grad(lambda w_: jnp.sum(
        mach_fused_xent_sparse_pallas(cols, vals, w_, None, y, b,
                                      None, 64, 64, True) * g))(w)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(dk),
                               rtol=1e-4, atol=1e-6)


def test_csr_op_with_bias_matches_ref():
    """ops-level dispatch: bias as a native in-kernel operand; dW flows
    through the fused scatter-add, dbias through the (1, bc) scratch
    reduction."""
    from benchmarks.common import make_csr_case
    n, d, r, b, nnz = 11, 96, 5, 32, 8
    indptr, indices, values, w, bias, y, g = make_csr_case(n, d, r, b,
                                                           nnz)

    def fr(w_, b_):
        return jnp.sum(ref.mach_fused_xent_csr_ref(
            indptr, indices, values, w_, y, b, bias=b_) * g)

    def fk(w_, b_):
        return jnp.sum(ops.mach_fused_xent_csr(
            indptr, indices, values, w_, y, num_buckets=b, nnz_max=nnz,
            bias=b_, use_pallas=True, interpret=True) * g)

    np.testing.assert_allclose(float(fr(w, bias)), float(fk(w, bias)),
                               rtol=1e-5, atol=1e-5)
    dr = jax.grad(fr, argnums=(0, 1))(w, bias)
    dk = jax.grad(fk, argnums=(0, 1))(w, bias)
    for a, k in zip(dr, dk):
        np.testing.assert_allclose(np.asarray(a), np.asarray(k),
                                   rtol=1e-4, atol=1e-6)


def test_csr_bias_keeps_ell_width_nnz_max():
    """The bias used to ride an always-on unit feature, widening the
    ELL layout to nnz_max+1 (a full extra lane block whenever nnz_max
    was a multiple of 128).  With the in-kernel bias operand the ELL
    width is exactly nnz_max again: the traced fwd+bwd contains
    (N, nnz_max) intermediates and none of width nnz_max+1."""
    from benchmarks.common import intermediate_avals, make_csr_case

    n, d, r, b, nnz = 16, 96, 4, 32, 128    # nnz on a lane multiple
    indptr, indices, values, w, bias, y, g = make_csr_case(n, d, r, b,
                                                           nnz)

    def vag(w_, bias_):
        return jax.value_and_grad(lambda ww, bb: jnp.sum(
            ops.mach_fused_xent_csr(indptr, indices, values, ww, y,
                                    num_buckets=b, nnz_max=nnz, bias=bb,
                                    use_pallas=True, interpret=True)
            * g), argnums=(0, 1))(w_, bias_)

    widths = {a.shape[1] for a in
              intermediate_avals(jax.make_jaxpr(vag)(w, bias).jaxpr)
              if getattr(a, "ndim", 0) == 2 and a.shape[0] == n}
    assert nnz in widths, sorted(widths)
    assert nnz + 1 not in widths, sorted(widths)


def test_csr_to_ell_roundtrip():
    """ELL layout densifies to exactly the CSR densification (duplicate
    ids scatter-add; padding contributes nothing)."""
    n, d, nnz = 7, 40, 5
    indptr, indices, values, _, _, _ = _csr_case(n, d, 4, 8, nnz)
    cols, vals = ops.csr_to_ell(indptr, indices, values, nnz, d)
    assert cols.shape == (n, nnz) and vals.shape == (n, nnz)
    dense_csr = ref.csr_densify_ref(indptr, indices, values, d)
    rows = jnp.arange(n)[:, None] * jnp.ones((1, nnz), jnp.int32)
    dense_ell = jnp.zeros((n, d + 1)).at[
        rows.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))[:, :d]
    np.testing.assert_allclose(np.asarray(dense_csr),
                               np.asarray(dense_ell), rtol=0, atol=1e-7)


# ---------------------------------------------------------------------------
# the MACHHead abstraction: one surface for both heads
# ---------------------------------------------------------------------------

def test_mach_head_protocol_conformance():
    cfg = MACHConfig(500, 16, 4)
    lin = MACHLinear(cfg, 32)
    out = MACHOutputHead(cfg, 32)
    assert isinstance(lin, MACHHead) and isinstance(out, MACHHead)
    key = jax.random.key(0)
    h = jax.random.normal(jax.random.key(1), (6, 32))
    y = jax.random.randint(jax.random.key(2), (6,), 0, 500)
    for head in (lin, out):
        params = head.init(key)
        assert float(head.loss(params, h, y)) > 0
        assert float(head.fused_loss(params, h, y)) == pytest.approx(
            float(head.loss(params, h, y)), rel=1e-5)
        pred = head.predict(params, h)          # Algorithm-2 decode
        assert pred.shape == (6,) and head.param_count() > 0


def test_linear_fused_flag_routes_loss_dense():
    """MACHLinear(fused=True).loss == materializing loss, values and
    grads (bias included via the unit-feature augmentation)."""
    cfg = MACHConfig(300, 8, 5)
    m0, m1 = MACHLinear(cfg, 24), MACHLinear(cfg, 24, fused=True)
    params = m0.init(jax.random.key(0))
    params["b"] = jax.random.normal(jax.random.key(3), params["b"].shape) * 0.1
    x = jax.random.normal(jax.random.key(1), (10, 24))
    y = jax.random.randint(jax.random.key(2), (10,), 0, 300)
    l0, g0 = jax.value_and_grad(m0.loss)(params, x, y)
    l1, g1 = jax.value_and_grad(m1.loss)(params, x, y)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6, atol=1e-6)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=1e-4, atol=1e-6)


def test_linear_fused_csr_matches_dense_path():
    """The full vertical slice: SparseBatch -> fused CSR loss ==
    materializing loss on the densified batch (interpret-mode kernel)."""
    ds = SparseExtremeDataset(SparseExtremeDataConfig(
        num_classes=128, num_features=64, nnz=8, sig_features=4))
    cfg = MACHConfig(128, 8, 4)
    m0, m1 = MACHLinear(cfg, 64), MACHLinear(cfg, 64, fused=True)
    params = m0.init(jax.random.key(0))
    sb, y = ds.batch_at(0, 12)
    xd, _ = ds.batch_at(0, 12, format="dense")
    assert is_sparse_batch(sb) and not is_sparse_batch(xd)
    l0, g0 = jax.value_and_grad(m0.loss)(params, xd, y)
    l1, g1 = jax.value_and_grad(
        lambda p: m1.fused_loss(p, sb, y, use_pallas=True,
                                interpret=True))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-5)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=1e-4, atol=1e-6)
    # the materializing path accepts the sparse batch too (densifies)
    np.testing.assert_allclose(float(m0.loss(params, sb, y)), float(l0),
                               rtol=1e-6, atol=1e-6)


def test_slice_merge_roundtrip_through_fused_step():
    """Paper §6.1 embarrassing parallelism survives fused training: one
    adamw step through the fused CSR loss, then slice_repetition /
    merge_repetitions round-trips the trained params exactly."""
    ds = SparseExtremeDataset(SparseExtremeDataConfig(
        num_classes=64, num_features=48, nnz=6, sig_features=3))
    cfg = MACHConfig(64, 8, 4)
    m = MACHLinear(cfg, 48, fused=True)
    params = m.init(jax.random.key(0))
    sb, y = ds.batch_at(0, 16)
    opt = adamw(0.05)
    state = opt.init(params)
    loss, g = jax.value_and_grad(m.loss)(params, sb, y)
    upd, state = opt.update(g, state, params)
    params = apply_updates(params, upd)
    assert np.isfinite(float(loss))
    merged = MACHLinear.merge_repetitions(
        [MACHLinear.slice_repetition(params, j)
         for j in range(cfg.num_repetitions)])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ragged_zipf_csr_end_to_end_training():
    """Real ragged rows (Zipf doc lengths, not handmade fixtures) flow
    through the fused CSR path end to end: the dataset emits rows of
    varying nnz, the fused interpret-mode loss/grads match the
    materializing dense path on the same batch, and a full adamw step
    goes through."""
    ds = SparseExtremeDataset(SparseExtremeDataConfig(
        num_classes=64, num_features=48, nnz=8, sig_features=3,
        length_zipf_a=1.0))
    cfg = MACHConfig(64, 8, 4)
    m0, m1 = MACHLinear(cfg, 48), MACHLinear(cfg, 48, fused=True)
    params = m0.init(jax.random.key(0))
    sb, y = ds.batch_at(0, 16)
    lens = np.diff(np.asarray(sb.indptr))
    assert lens.min() >= 3 and lens.max() <= 8   # sig_features..nnz
    assert len(set(lens.tolist())) > 1           # actually ragged
    assert sb.nnz_max == 8
    xd, yd = ds.batch_at(0, 16, format="dense")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yd))
    l0, g0 = jax.value_and_grad(m0.loss)(params, xd, y)
    l1, g1 = jax.value_and_grad(
        lambda p: m1.fused_loss(p, sb, y, use_pallas=True,
                                interpret=True))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-5)
    for k in ("w", "b"):
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=1e-4, atol=1e-6)
    opt = adamw(0.05)
    state = opt.init(params)
    upd, state = opt.update(g1, state, params)
    params = apply_updates(params, upd)
    loss2 = m1.fused_loss(params, sb, y, use_pallas=True, interpret=True)
    assert np.isfinite(float(loss2))


# ---------------------------------------------------------------------------
# structural claims: no (N, R·B) logits, no dense (N, d) activation
# ---------------------------------------------------------------------------

def test_no_nrb_or_nd_tensor_in_sparse_jaxpr():
    from benchmarks.common import intermediate_avals

    n, d, r, b, nnz = 32, 1024, 8, 64, 8
    indptr, indices, values, w, y, g = _csr_case(n, d, r, b, nnz)

    def fused_vag(w_):
        return jax.value_and_grad(lambda ww: jnp.sum(
            ops.mach_fused_xent_csr(indptr, indices, values, ww, y,
                                    num_buckets=b, nnz_max=nnz,
                                    use_pallas=True, interpret=True)
            * g))(w_)

    def densified_vag(w_):
        return jax.value_and_grad(lambda ww: jnp.sum(
            ref.mach_fused_xent_csr_ref(indptr, indices, values, ww, y,
                                        b) * g))(w_)

    nrb, nd = n * r * b, n * d

    def batch_sizes(fn):
        return [a.size for a in intermediate_avals(
            jax.make_jaxpr(fn)(w).jaxpr)
            if getattr(a, "ndim", 0) >= 1 and a.size
            and n <= a.shape[0] < n + 128]

    fused_sizes = batch_sizes(fused_vag)
    dens_sizes = batch_sizes(densified_vag)
    # the densifying path forms the (N, d) activation (and d > R·B here)
    assert any(s >= nd for s in dens_sizes)
    # the fused path forms neither the logits nor the dense activation
    assert all(s < min(nrb, nd) for s in fused_sizes), \
        sorted(fused_sizes, reverse=True)[:5]


def test_csr_to_ell_rejects_undersized_nnz_max():
    """Rows longer than nnz_max would be silently truncated on the
    kernel path (the densifying reference uses every entry) — concrete
    batches must be rejected instead."""
    indptr = jnp.asarray([0, 3, 4], jnp.int32)   # row 0 has 3 entries
    indices = jnp.asarray([0, 1, 2, 3], jnp.int32)
    values = jnp.ones((4,))
    with pytest.raises(ValueError, match="nnz_max"):
        ops.csr_to_ell(indptr, indices, values, 2, 8)
    w = jnp.ones((8, 4 * 2)) * 0.1
    y = jnp.zeros((2, 2), jnp.int32)
    with pytest.raises(ValueError, match="nnz_max"):
        ops.mach_fused_xent_csr(indptr, indices, values, w, y,
                                num_buckets=4, nnz_max=2,
                                use_pallas=True, interpret=True)
