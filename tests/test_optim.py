"""Optimizers, schedules, gradient compression — from-scratch substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adam, adamw, apply_updates,
                         clip_by_global_norm, dequantize_8bit, global_norm,
                         init_error_feedback, make_schedule, quantize_8bit,
                         sgd, topk_compress)
from repro.optim.optimizers import with_master_weights


def _quadratic_descent(opt, steps=200, dtype=jnp.float32):
    """min ||x - t||² from 0 — any reasonable optimizer converges."""
    t = jnp.asarray([1.0, -2.0, 3.0], dtype)
    params = {"x": jnp.zeros(3, dtype)}
    state = opt.init(params)
    for _ in range(steps):
        g = {"x": (2 * (params["x"].astype(jnp.float32) - t)).astype(dtype)}
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return np.asarray(params["x"], np.float32), np.asarray(t, np.float32)


@pytest.mark.parametrize("opt", [
    sgd(0.05), sgd(0.02, momentum=0.9), adam(0.05), adamw(0.05),
    adafactor(0.05),
])
def test_optimizers_converge_quadratic(opt):
    x, t = _quadratic_descent(opt)
    np.testing.assert_allclose(x, t, atol=0.05)


def test_adamw_decays_matrices_not_vectors():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    upd, _ = opt.update(zeros, state, params)
    assert float(jnp.abs(upd["w"]).sum()) > 0      # decay applied
    assert float(jnp.abs(upd["b"]).sum()) == 0     # biases not decayed


def test_adafactor_memory_is_factored():
    opt = adafactor(0.1)
    params = {"w": jnp.ones((128, 256))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state < 128 * 256 / 10     # O(n+m), not O(nm)


def test_master_weights_bf16_training():
    """bf16 params + f32 masters track f32 training closely; pure-bf16
    training (no masters) drifts/stalls on tiny updates."""
    opt32 = adam(0.05)
    x32, t = _quadratic_descent(opt32, dtype=jnp.float32)
    opt_m = with_master_weights(adam(0.05))
    xm, _ = _quadratic_descent(opt_m, dtype=jnp.bfloat16)
    np.testing.assert_allclose(xm, t, atol=0.05)
    np.testing.assert_allclose(xm, x32, atol=0.05)


def test_schedules_shapes():
    s = make_schedule("warmup_cosine", peak=1e-3, warmup_steps=10,
                      total_steps=100)
    vals = [float(s(jnp.asarray(i))) for i in (0, 9, 10, 50, 99)]
    assert vals[0] < vals[1] <= vals[2] * 1.01
    assert vals[2] > vals[3] > vals[4]
    r = make_schedule("warmup_rsqrt", peak=1e-3, warmup_steps=10)
    assert float(r(jnp.asarray(1000))) < float(r(jnp.asarray(20)))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_topk_error_feedback_conserves_gradient():
    """kept + residual == gradient (+ previous residual): nothing lost."""
    g = {"w": jax.random.normal(jax.random.key(0), (64, 64))}
    ef = init_error_feedback(g)
    kept, ef2 = topk_compress(g, ef, fraction=0.05)
    total = jax.tree.map(lambda a, b: a + b, kept, ef2.residual)
    np.testing.assert_allclose(np.asarray(total["w"]), np.asarray(g["w"]),
                               rtol=1e-6)
    nz = float(jnp.mean(kept["w"] != 0))
    assert 0.03 <= nz <= 0.08
    # second round: residual feeds back
    kept2, ef3 = topk_compress(g, ef2, fraction=0.05)
    total2 = jax.tree.map(lambda a, b: a + b, kept2, ef3.residual)
    want = jax.tree.map(lambda a, b: a + b, g, ef2.residual)
    np.testing.assert_allclose(np.asarray(total2["w"]),
                               np.asarray(want["w"]), rtol=1e-5)


def test_quantize_8bit_roundtrip_error():
    g = {"w": jax.random.normal(jax.random.key(1), (128,)) * 3}
    q = quantize_8bit(g)
    back = dequantize_8bit(q)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert float(jnp.max(jnp.abs(back["w"] - g["w"]))) <= scale * 0.51
    assert q.q["w"].dtype == jnp.int8
