"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates its REDUCED config (same family:
pattern, GQA ratios, MoE/shared experts, frontends) and runs one forward
+ one train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (launch/dryrun.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import LMDataConfig, SyntheticLMStream
from repro.models import LanguageModel
from repro.models.frontends import AUDIO_FEATURE_DIM, VISION_FEATURE_DIM
from repro.train.trainer import TrainConfig, Trainer

B, L = 2, 16


def _batch_for(cfg):
    key = jax.random.key(42)
    batch = {"tokens": jax.random.randint(key, (B, L + 1), 0,
                                          cfg.vocab_size)}
    if cfg.num_encoder_layers:
        batch["enc_feats"] = jax.random.normal(
            jax.random.key(1), (B, 8, AUDIO_FEATURE_DIM), jnp.float32)
    if cfg.frontend == "vision":
        batch["prefix_feats"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_prefix_tokens,
                                VISION_FEATURE_DIM), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = LanguageModel(cfg)
    params, axes = model.init(jax.random.key(0))
    # axes tree mirrors params tree exactly
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda v: 0, axes,
                              is_leaf=lambda v: isinstance(v, tuple)))
    batch = _batch_for(cfg)
    toks = batch["tokens"][:, :-1]
    enc_kvs = None
    if cfg.num_encoder_layers:
        enc_out = model.encode(params, batch["enc_feats"])
        assert enc_out.shape == (B, 8, cfg.d_model)
        enc_kvs = model.enc_kvs(params, enc_out)
    h, _, _ = model.hidden_states(
        params, toks, enc_kvs=enc_kvs,
        prefix_emb=batch.get("prefix_feats"))
    exp_t = L + (cfg.num_prefix_tokens if cfg.frontend == "vision" else 0)
    assert h.shape == (B, exp_t, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))

    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), arch
    if cfg.mach is not None:
        logits = model.mach_logits(params, h[:, -L:])
        assert logits.shape == (B, L, cfg.mach.num_repetitions,
                                cfg.mach.num_buckets)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = LanguageModel(cfg)
    tcfg = TrainConfig(total_steps=3, warmup_steps=1, peak_lr=1e-3,
                       log_every=100)
    tr = Trainer(model, tcfg,
                 loss_fn=lambda p, b: model.loss(p, b))
    state = tr.init_state(jax.random.key(0))
    batch = _batch_for(cfg)
    # snapshot before the step: the jit step donates its input state
    before = [np.array(x) for x in jax.tree.leaves(state.params)]
    state2, metrics = tr._jit_step(state, batch)
    assert int(state2.step) == 1
    assert jnp.isfinite(metrics["loss"]), arch
    # params actually moved
    moved = any(
        not np.array_equal(a, np.asarray(b))
        for a, b in zip(before, jax.tree.leaves(state2.params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x22b",
                                  "recurrentgemma-2b", "xlstm-350m",
                                  "seamless-m4t-large-v2", "paligemma-3b"])
def test_smoke_prefill_decode(arch):
    """Serving path per family: prefill + 2 decode steps, finite outputs."""
    cfg = get_config(arch, smoke=True)
    if cfg.frontend == "vision":
        pytest.skip("decode-after-prefix covered by engine test")
    model = LanguageModel(cfg)
    params, _ = model.init(jax.random.key(0))
    batch = _batch_for(cfg)
    toks = batch["tokens"][:, :8]
    pre = {"tokens": toks, **{k: v for k, v in batch.items()
                              if k in ("enc_feats",)}}
    caches, enc_kvs, h_last = model.prefill(params, pre, max_len=24)
    ids, vals = model.next_token(params, h_last)
    assert ids.shape == (B,) and ids.dtype == jnp.int32
    assert int(ids.max()) < cfg.vocab_size
    pos = jnp.full((B,), 8, jnp.int32)
    for i in range(2):
        caches, h = model.decode_step(params, caches, enc_kvs, ids, pos + i)
        ids, _ = model.next_token(params, h)
        assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))


def test_full_configs_construct_and_count_params():
    """Full configs build (no allocation) and param counts are in the
    right ballpark for their advertised sizes."""
    expected = {
        "mistral-large-123b": (100e9, 150e9),
        "granite-20b": (15e9, 25e9),
        "tinyllama-1.1b": (0.8e9, 1.4e9),
        "phi3-mini-3.8b": (3e9, 4.6e9),
        "mixtral-8x22b": (120e9, 150e9),
        "xlstm-350m": (0.25e9, 0.55e9),
        "recurrentgemma-2b": (2e9, 3.4e9),
        "paligemma-3b": (2e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        n = cfg.param_count_estimate()
        assert lo < n < hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]")
