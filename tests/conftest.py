import jax
import pytest

# CPU-only test environment: full-precision matmuls for tight tolerances.
jax.config.update("jax_default_matmul_precision", "float32")


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
