from repro.serving.engine import (ServeConfig, ServingEngine, make_decode_fn,
                                  make_prefill_fn, make_sample_decode_fn,
                                  make_sample_prefill_fn)

__all__ = ["ServeConfig", "ServingEngine", "make_prefill_fn",
           "make_decode_fn", "make_sample_prefill_fn",
           "make_sample_decode_fn"]
