from repro.serving.engine import (GREEDY, EngineMetrics, GenerationResult,
                                  Request, SamplingParams, ServeConfig,
                                  ServingEngine, make_serve_step_fn)

__all__ = ["GREEDY", "EngineMetrics", "GenerationResult", "Request",
           "SamplingParams", "ServeConfig", "ServingEngine",
           "make_serve_step_fn"]
