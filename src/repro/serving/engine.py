"""Serving engine: batched prefill/decode with MACH fused next-token.

Two layers:

* ``make_prefill_fn`` / ``make_decode_fn`` — the pure jit-compiled steps
  (these are what launch/dryrun.py lowers for the ``prefill_*`` /
  ``decode_*`` / ``long_*`` cells), plus the ``make_sample_*`` variants
  that thread a PRNG key and per-row sampling knobs through.
* ``ServingEngine`` — a host-side batcher: accepts requests, packs them
  into fixed-size batches (padding short prompts), runs prefill once and
  decode steps until max tokens.  Greedy decoding uses the paper's
  summed-score rule via the fused top-1 kernel; sampling uses the fused
  *streaming top-k* kernel (temperature / top-k / estimator per request)
  — both stay on the never-materialize path.

The MACH win at serve time is exactly the paper's O(RBd + KR) vs O(Kd):
the head matmul shrinks by V/(R·B) and the class-score aggregation never
materializes the (batch, V) logits tensor — for greedy *and* sampled
decoding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LanguageModel


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    batch_size: int = 8
    max_new_tokens: int = 64
    eos_id: int = -1          # -1: never stop early
    pad_id: int = 0
    # sampling defaults: temperature None -> greedy unless a request
    # asks for sampling via extras {"temperature": t, "top_k": k}
    temperature: Optional[float] = None
    top_k: int = 50           # fused-kernel candidate count (static cap)
    seed: int = 0


def make_prefill_fn(model: LanguageModel):
    """(params, batch) -> (caches, enc_kvs, first generated token ids)."""
    def prefill(params, batch, *, max_len: int):
        caches, enc_kvs, h_last = model.prefill(params, batch, max_len)
        ids, _ = model.next_token(params, h_last)
        return caches, enc_kvs, ids
    return prefill


def make_decode_fn(model: LanguageModel):
    """(params, caches, enc_kvs, tokens, pos) -> (caches, next token ids)."""
    def decode(params, caches, enc_kvs, tokens, pos):
        caches, h = model.decode_step(params, caches, enc_kvs, tokens, pos)
        ids, _ = model.next_token(params, h)
        return caches, ids
    return decode


def make_sample_prefill_fn(model: LanguageModel, top_k: int):
    """Sampling prefill: extra (key, temps (B,), row_k (B,)) operands.
    Stays on the fused streaming top-k path — no (B, V) tensor."""
    def prefill(params, batch, key, temps, row_k, *, max_len: int):
        caches, enc_kvs, h_last = model.prefill(params, batch, max_len)
        ids = model.sample_token(params, h_last, key, temperature=temps,
                                 top_k=top_k, row_top_k=row_k)
        return caches, enc_kvs, ids
    return prefill


def make_sample_decode_fn(model: LanguageModel, top_k: int):
    """One sampled token step (per-row temperature / top-k)."""
    def decode(params, caches, enc_kvs, tokens, pos, key, temps, row_k):
        caches, h = model.decode_step(params, caches, enc_kvs, tokens, pos)
        ids = model.sample_token(params, h, key, temperature=temps,
                                 top_k=top_k, row_top_k=row_k)
        return caches, ids
    return decode


class ServingEngine:
    """Host-side request batcher over the jitted prefill/decode steps."""

    def __init__(self, model: LanguageModel, params, scfg: ServeConfig):
        if scfg.top_k < 1:
            # the static candidate cap bounds every per-request top_k;
            # 0 would clamp requests into an empty candidate set
            raise ValueError(f"ServeConfig.top_k must be >= 1, "
                             f"got {scfg.top_k}")
        self.model = model
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(make_prefill_fn(model),
                                static_argnames=("max_len",))
        self._decode = jax.jit(make_decode_fn(model))
        self._sample_prefill = jax.jit(
            make_sample_prefill_fn(model, scfg.top_k),
            static_argnames=("max_len",))
        self._sample_decode = jax.jit(make_sample_decode_fn(model, scfg.top_k))
        self._queue: list = []
        # sampling PRNG stream: instance state so successive run() calls
        # draw fresh keys (deterministic per engine, not per call)
        self._base_key = jax.random.key(scfg.seed)
        self._chunk_i = 0

    def add_request(self, prompt_tokens: list, extras: Optional[dict] = None):
        """extras may carry frontend features ("enc_feats"/"prefix_feats")
        and per-request sampling knobs ("temperature", "top_k").  A
        per-request top_k is clamped to [1, ServeConfig.top_k] — the
        engine config's value is the fused kernel's static candidate
        cap; raise it there if requests need wider support."""
        self._queue.append((list(prompt_tokens), extras or {}))

    def _pack(self, requests):
        scfg = self.scfg
        maxp = max(len(p) for p, _ in requests)
        b = len(requests)
        toks = np.full((b, maxp), scfg.pad_id, np.int32)
        for i, (p, _) in enumerate(requests):
            toks[i, maxp - len(p):] = p          # left-pad: aligned ends
        batch = {"tokens": jnp.asarray(toks)}
        for k in ("enc_feats", "prefix_feats"):
            if requests[0][1].get(k) is not None:
                batch[k] = jnp.stack([jnp.asarray(r[1][k]) for r in requests])
        return batch, maxp

    def _sampling_knobs(self, chunk):
        """Per-row (temperature, top_k) arrays, or None for all-greedy.

        A chunk samples iff the engine default or any request asks for
        it; greedy rows inside a sampled chunk degrade to temperature
        1e-6 over their top-1 candidate (== argmax)."""
        scfg = self.scfg

        def row_samples(extras):
            return (scfg.temperature is not None
                    or "temperature" in extras or "top_k" in extras)

        if not any(row_samples(e) for _, e in chunk):
            return None
        temps, row_k = [], []
        for _, extras in chunk:
            if not row_samples(extras):         # greedy row in mixed batch
                t, k = 1e-6, 1
            else:
                # any sampling knob opts the row in: a top_k-only request
                # samples at temperature 1.0, it is not degraded to greedy
                t = extras.get("temperature", scfg.temperature)
                t = 1.0 if t is None else t
                k = extras.get("top_k", scfg.top_k)
            temps.append(max(float(t), 1e-6))
            row_k.append(int(np.clip(k, 1, scfg.top_k)))
        return (jnp.asarray(temps, jnp.float32),
                jnp.asarray(row_k, jnp.int32))

    def run(self) -> list:
        """Serve all queued requests; returns list of generated id lists."""
        scfg = self.scfg
        outputs = []
        while self._queue:
            chunk = self._queue[:scfg.batch_size]
            self._queue = self._queue[scfg.batch_size:]
            n_real = len(chunk)
            # pad the batch up to a fixed size so the jit cache is stable
            while len(chunk) < scfg.batch_size:
                chunk.append((chunk[0][0], chunk[0][1]))
            batch, plen = self._pack(chunk)
            knobs = self._sampling_knobs(chunk)
            ckey = jax.random.fold_in(self._base_key, self._chunk_i)
            self._chunk_i += 1
            if knobs is None:
                caches, enc_kvs, ids = self._prefill(
                    self.params, batch, max_len=scfg.max_len)
            else:
                temps, row_k = knobs
                caches, enc_kvs, ids = self._sample_prefill(
                    self.params, batch, jax.random.fold_in(ckey, 0),
                    temps, row_k, max_len=scfg.max_len)
            b = ids.shape[0]
            gen = [ids]
            pos = jnp.full((b,), plen, jnp.int32)
            done = jnp.zeros((b,), bool)
            for step in range(scfg.max_new_tokens - 1):
                if knobs is None:
                    caches, ids = self._decode(self.params, caches, enc_kvs,
                                               gen[-1], pos)
                else:
                    caches, ids = self._sample_decode(
                        self.params, caches, enc_kvs, gen[-1], pos,
                        jax.random.fold_in(ckey, step + 1), temps, row_k)
                gen.append(ids)
                pos = pos + 1
                if scfg.eos_id >= 0:
                    done = done | (ids == scfg.eos_id)
                    if bool(done.all()):
                        break
            stacked = np.stack([np.asarray(g) for g in gen], axis=1)
            for i in range(n_real):
                seq = stacked[i].tolist()
                if scfg.eos_id >= 0 and scfg.eos_id in seq:
                    seq = seq[:seq.index(scfg.eos_id) + 1]
                outputs.append(seq)
        return outputs
