"""Serving engine: batched prefill/decode with MACH fused next-token.

Two layers:

* ``make_prefill_fn`` / ``make_decode_fn`` — the pure jit-compiled steps
  (these are what launch/dryrun.py lowers for the ``prefill_*`` /
  ``decode_*`` / ``long_*`` cells).
* ``ServingEngine`` — a host-side batcher: accepts requests, packs them
  into fixed-size batches (padding short prompts), runs prefill once and
  decode steps until max tokens.  Greedy decoding uses the paper's
  summed-score rule via the fused Pallas kernel; sampling falls back to
  full estimated probabilities (reference path).

The MACH win at serve time is exactly the paper's O(RBd + KR) vs O(Kd):
the head matmul shrinks by V/(R·B) and the class-score aggregation never
materializes the (batch, V) logits tensor.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LanguageModel


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    batch_size: int = 8
    max_new_tokens: int = 64
    eos_id: int = -1          # -1: never stop early
    pad_id: int = 0


def make_prefill_fn(model: LanguageModel):
    """(params, batch) -> (caches, enc_kvs, first generated token ids)."""
    def prefill(params, batch, *, max_len: int):
        caches, enc_kvs, h_last = model.prefill(params, batch, max_len)
        ids, _ = model.next_token(params, h_last)
        return caches, enc_kvs, ids
    return prefill


def make_decode_fn(model: LanguageModel):
    """(params, caches, enc_kvs, tokens, pos) -> (caches, next token ids)."""
    def decode(params, caches, enc_kvs, tokens, pos):
        caches, h = model.decode_step(params, caches, enc_kvs, tokens, pos)
        ids, _ = model.next_token(params, h)
        return caches, ids
    return decode


class ServingEngine:
    """Host-side request batcher over the jitted prefill/decode steps."""

    def __init__(self, model: LanguageModel, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(make_prefill_fn(model),
                                static_argnames=("max_len",))
        self._decode = jax.jit(make_decode_fn(model))
        self._queue: list = []

    def add_request(self, prompt_tokens: list, extras: Optional[dict] = None):
        self._queue.append((list(prompt_tokens), extras or {}))

    def _pack(self, requests):
        scfg = self.scfg
        maxp = max(len(p) for p, _ in requests)
        b = len(requests)
        toks = np.full((b, maxp), scfg.pad_id, np.int32)
        for i, (p, _) in enumerate(requests):
            toks[i, maxp - len(p):] = p          # left-pad: aligned ends
        batch = {"tokens": jnp.asarray(toks)}
        for k in ("enc_feats", "prefix_feats"):
            if requests[0][1].get(k) is not None:
                batch[k] = jnp.stack([jnp.asarray(r[1][k]) for r in requests])
        return batch, maxp

    def run(self) -> list:
        """Serve all queued requests; returns list of generated id lists."""
        scfg = self.scfg
        outputs = []
        while self._queue:
            chunk = self._queue[:scfg.batch_size]
            self._queue = self._queue[scfg.batch_size:]
            n_real = len(chunk)
            # pad the batch up to a fixed size so the jit cache is stable
            while len(chunk) < scfg.batch_size:
                chunk.append((chunk[0][0], chunk[0][1]))
            batch, plen = self._pack(chunk)
            caches, enc_kvs, ids = self._prefill(self.params, batch,
                                                 max_len=scfg.max_len)
            b = ids.shape[0]
            gen = [ids]
            pos = jnp.full((b,), plen, jnp.int32)
            done = jnp.zeros((b,), bool)
            for _ in range(scfg.max_new_tokens - 1):
                caches, ids = self._decode(self.params, caches, enc_kvs,
                                           gen[-1], pos)
                gen.append(ids)
                pos = pos + 1
                if scfg.eos_id >= 0:
                    done = done | (ids == scfg.eos_id)
                    if bool(done.all()):
                        break
            stacked = np.stack([np.asarray(g) for g in gen], axis=1)
            for i in range(n_real):
                seq = stacked[i].tolist()
                if scfg.eos_id >= 0 and scfg.eos_id in seq:
                    seq = seq[:seq.index(scfg.eos_id) + 1]
                outputs.append(seq)
        return outputs
