"""Continuous-batching serving engine: slot-scheduled MACH decode.

The public API is typed: callers build a ``Request`` (prompt, optional
``SamplingParams``, per-request ``max_new_tokens``, optional frontend
features, optional streaming ``on_token`` callback), ``submit()`` it,
and drive the engine with ``step()`` (one scheduler tick) or ``run()``
(drain everything); finished requests come back as
``GenerationResult``s.

Scheduling is *continuous* (slot-based) batching: the KV cache is
allocated once as a fixed pool of ``ServeConfig.num_slots`` slots.  A
queued request is admitted by prefilling it alone (batch 1, exact
prompt length — no padding, so a request's tokens are bit-identical to
a solo decode) and scattering its caches into a free slot
(``LanguageModel.insert_cache_slot``); every decode step then advances
the whole pool with per-slot positions and per-row cache writes
(``decode_step(per_slot=True)``).  EOS or the request's
``max_new_tokens`` frees the slot immediately (``reset_cache_slot``)
and the next queued request is admitted into it on the following tick —
short requests never hold long ones hostage, and arriving requests
never wait for a whole batch to drain.  ``ServeConfig.scheduler =
"lockstep"`` keeps the old chunked policy (admit only into an empty
pool, hold every slot until the whole chunk finishes) as an ablation
baseline — ``benchmarks/bench_serve.py`` gates that continuous strictly
beats it on ragged workloads.

One jitted serve step (``make_serve_step_fn``) covers every model call:
prefill (``caches=None``) and decode (caches = the pool) both end in
the fused streaming top-k kernel with per-row temperature / top-k /
estimator — greedy is expressed as ε-temperature over the row's top-1
candidate, so greedy and sampled rows share one trace instead of two
disjoint jit caches, and neither ever materializes a (batch, V) logits
tensor: the MACH win at serve time is exactly the paper's O(RBd + KR)
vs O(Kd).

Randomness is keyed per *request*, not per batch row: row i draws from
``fold_in(fold_in(seed, request_id), token_index)``, so a request's
sampled continuation is independent of its slot, its batch neighbours,
and queue order, and free slots are inert (their ε-temperature top-1
pick is deterministic regardless of the Gumbel draw).  Caveat: MoE
blocks route tokens through shared expert-capacity groups, which
couples rows — per-request bit-parity holds for the dense / recurrent /
local-attention substrates.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import frontends
from repro.models.model import LanguageModel

_ESTIMATORS = ("unbiased", "min", "median")
_GREEDY_TEMP = 1e-6            # ε-temperature: top-1 pick through the
                               # fused streaming top-k kernel == argmax

SCHEDULERS = ("continuous", "lockstep")


def _prng_salt(seed: Optional[int], rid: int) -> int:
    """Per-request PRNG identity, folded into the engine key.

    Explicit ``SamplingParams.seed``s (odd salts) and engine-assigned
    request ids (even salts) live in disjoint namespaces, so a seeded
    request can never collide with an unseeded one's stream; the mask
    keeps user-provided seeds in int32 range for ``fold_in``."""
    if seed is not None:
        return ((2 * seed) | 1) & 0x7FFFFFFF
    return (2 * rid) & 0x7FFFFFFF


# ---------------------------------------------------------------------------
# Typed request/response surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs.

    All-default means greedy (unless ``ServeConfig.temperature`` opts
    the whole engine into sampling); setting *any* knob opts the request
    into sampling — a ``top_k``-only request samples at temperature 1.0.
    ``top_k`` is clamped to [1, ServeConfig.top_k] (the fused kernel's
    static candidate cap; raise it there if requests need wider
    support).  ``estimator`` picks the MACH score reduction (Eq. 2/7/8)
    for this request — greedy requests follow it too (top-1 of that
    estimator's scores).  ``seed`` pins the request's private random
    stream: by default it is keyed by the engine-assigned request id
    (deterministic for a fixed submission order); an explicit seed makes
    the sampled continuation reproducible regardless of submission
    order, batch neighbours, or which slot the scheduler picks."""
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    estimator: Optional[str] = None
    seed: Optional[int] = None


GREEDY = SamplingParams()


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.

    ``on_token`` (optional) streams each generated token id as soon as
    the scheduler tick that produced it completes — including the first
    token, which comes out of the prefill itself."""
    prompt: Sequence[int]
    sampling: SamplingParams = GREEDY
    max_new_tokens: Optional[int] = None     # None -> ServeConfig default
    enc_feats: Optional[Any] = None          # (S, F) encoder frontend
    prefix_feats: Optional[Any] = None       # (P, F) vision prefix
    on_token: Optional[Callable[[int], None]] = None


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    request_id: int
    tokens: tuple                 # generated ids (includes EOS if hit)
    finish_reason: str            # "eos" | "length"
    prompt_len: int
    submit_step: int              # engine tick at submit()
    finish_step: int              # engine tick that produced the last token

    @property
    def latency_steps(self) -> int:
        """Scheduler ticks from submission to completion, inclusive."""
        return self.finish_step - self.submit_step + 1


@dataclasses.dataclass
class EngineMetrics:
    """Counters over the engine's lifetime (see also ``queue_depth``)."""
    num_slots: int
    decode_steps: int = 0         # pooled decode calls
    prefills: int = 0             # admissions (one per request)
    tokens_generated: int = 0     # real request tokens (free slots excluded)
    completed: int = 0
    live_slot_steps: int = 0      # Σ over decode calls of producing slots
    peak_live_slots: int = 0      # max concurrently occupied slots
    # page-pool gauges (paged engines only; zero on the contiguous path)
    num_pages: int = 0            # pool size (0 = contiguous/strip layout)
    pages_in_use: int = 0         # pages allocated+written by live slots now
    pages_reserved: int = 0       # reserved now (incl. not yet written)
    pages_peak: int = 0           # max pages_reserved over the lifetime
    reservation_failures: int = 0  # admission ticks deferred for lack of pages

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per decode step."""
        denom = self.decode_steps * self.num_slots
        return self.live_slot_steps / denom if denom else 0.0

    @property
    def tokens_per_decode_step(self) -> float:
        return (self.tokens_generated / self.decode_steps
                if self.decode_steps else 0.0)

    @property
    def fragmentation(self) -> int:
        """Reserved − written pages: the internal fragmentation of the
        worst-case (prompt + max_new) reservations held right now."""
        return self.pages_reserved - self.pages_in_use


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048           # per-request token cap (page-table span)
    num_slots: int = 8            # fixed decode-pool width
    max_new_tokens: int = 64      # default per-request cap
    eos_id: int = -1              # -1: never stop early
    temperature: Optional[float] = None   # engine-wide sampling default
    top_k: int = 50               # fused-kernel candidate cap (static)
    seed: int = 0
    scheduler: str = "continuous"  # "continuous" | "lockstep" (baseline)
    # paged KV cache: page_size > 0 switches the linear KV caches from
    # per-slot (num_slots, max_len) strips to one shared
    # (num_pages, page_size) pool with per-slot page tables — resident
    # KV HBM becomes num_pages × page_size tokens per layer regardless
    # of max_len, so num_slots can grow at fixed memory.  num_pages = 0
    # derives num_slots × ceil(max_len / page_size) (byte-equivalent to
    # the contiguous layout).  page_size = 0 keeps the contiguous strip
    # layout (required by scheduler="lockstep").
    page_size: int = 0
    num_pages: int = 0
    # decode algorithm: None | "exact" stream all V classes; an (m, t)
    # tuple routes every serve step through the count-min candidate
    # filter (cost independent of V — see ops.mach_topk_candidates).
    # MACH models only; ignored on the OAA path.
    candidate_mode: Optional[object] = None

    @property
    def paged(self) -> bool:
        return self.page_size > 0


# ---------------------------------------------------------------------------
# The unified serve step
# ---------------------------------------------------------------------------

def make_serve_step_fn(model: LanguageModel, top_k: int,
                       candidate_mode=None):
    """One jitted step for both phases of serving.

    ``caches=None`` selects prefill: ``batch["tokens"]`` is the (1, L)
    prompt (plus optional ``enc_feats`` / ``prefix_feats``), fresh
    caches are built inside, and ``pos`` / incoming ``enc_kvs`` are
    ignored.  Otherwise one pooled decode step: ``batch["tokens"]`` is
    (S, 1), ``pos`` the per-slot absolute positions, and every row's KV
    write lands at its own cache index.

    Both phases end identically: per-estimator fused streaming top-k
    candidates (``estimators`` is the static tuple of estimators live in
    this batch; ``est_sel`` indexes into it per row), then a per-row
    keyed temperature/top-k categorical.  A batch with E distinct live
    estimators pays E fused top-k passes over the whole pool (the
    kernel's reduction is specialized per estimator) — fine for the
    common single-estimator case; a per-row estimator operand in the
    kernel would remove the multiplier if mixed-estimator traffic ever
    dominates.  Greedy rows ride the same
    trace at ε-temperature over their top-1 candidate — no separate
    greedy compilation, and no (batch, V) logits tensor in either mode.

    Returns ``(caches, enc_kvs, ids)``."""

    def serve_step(params, caches, enc_kvs, batch, pos, key, salts,
                   tok_idx, temps, row_k, est_sel, *,
                   estimators: tuple, max_len: int,
                   linear_cap: Optional[int] = None):
        if caches is None:                       # ---- prefill (batch 1)
            # linear_cap (paged engines): cap the batch-1 linear caches
            # at the prompt's page-rounded length so the strip reshapes
            # exactly into the reserved pool pages at insert time
            caches, enc_kvs, h = model.prefill(params, batch, max_len,
                                               linear_cap=linear_cap)
        else:                                    # ---- pooled decode step
            caches, h = model.decode_step(params, caches, enc_kvs,
                                          batch["tokens"][:, 0], pos,
                                          per_slot=True)
        cands = [model.topk_candidates(params, h, top_k, est,
                                       candidate_mode=candidate_mode)
                 for est in estimators]
        if len(cands) == 1:
            vals, idxs = cands[0]
        else:
            rows = jnp.arange(h.shape[0])
            vals = jnp.stack([c[0] for c in cands])[est_sel, rows]
            idxs = jnp.stack([c[1] for c in cands])[est_sel, rows]
        row_keys = jax.vmap(
            lambda r, t: jax.random.fold_in(jax.random.fold_in(key, r), t)
        )(salts, tok_idx)
        ids = model.sample_from_candidates(vals, idxs, row_keys,
                                           temperature=temps,
                                           row_top_k=row_k,
                                           per_row_keys=True)
        return caches, enc_kvs, ids

    return serve_step


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied decode slot."""
    req_id: int
    req: Request
    salt: int                     # PRNG identity: sampling.seed or req_id
    tokens: list                  # generated so far (first from prefill)
    pos: int                      # next absolute position (= cache index)
    temp: float
    row_k: int
    est: str
    max_new: int
    submit_step: int
    first_token_step: int
    done: bool = False            # lockstep only: finished, slot held
    pages: list = dataclasses.field(default_factory=list)  # pool page ids
    reserved: int = 0             # worst-case pages reserved at admission


class ServingEngine:
    """Slot-scheduled request engine over the unified jitted serve step.

    ``submit()`` validates and queues a ``Request`` (returns its id);
    ``step()`` runs one scheduler tick — admit queued requests into free
    slots (per-request prefill + scatter), then advance the pool one
    decode step — and returns the requests that finished this tick;
    ``run()`` ticks until queue and pool drain and returns all results
    in submission order.  ``metrics`` and ``queue_depth`` expose
    scheduler health (tokens/step, slot occupancy, backlog)."""

    def __init__(self, model: LanguageModel, params, scfg: ServeConfig):
        if scfg.top_k < 1:
            # the static candidate cap bounds every per-request top_k;
            # 0 would clamp requests into an empty candidate set
            raise ValueError(f"ServeConfig.top_k must be >= 1, "
                             f"got {scfg.top_k}")
        if scfg.num_slots < 1:
            raise ValueError(f"ServeConfig.num_slots must be >= 1, "
                             f"got {scfg.num_slots}")
        if scfg.scheduler not in SCHEDULERS:
            raise ValueError(f"ServeConfig.scheduler must be one of "
                             f"{SCHEDULERS}, got {scfg.scheduler!r}")
        if scfg.max_new_tokens < 1:
            raise ValueError("ServeConfig.max_new_tokens must be >= 1")
        if scfg.temperature is not None and scfg.temperature <= 0:
            # same contract as SamplingParams.temperature — 0 would
            # silently degrade to ε-greedy rather than erroring
            raise ValueError(f"ServeConfig.temperature must be > 0 (or "
                             f"None for greedy), got {scfg.temperature}")
        if scfg.page_size < 0 or scfg.num_pages < 0:
            raise ValueError("ServeConfig.page_size / num_pages must be >= 0")
        if scfg.num_pages and not scfg.page_size:
            raise ValueError("ServeConfig.num_pages requires page_size > 0")
        if scfg.paged and scfg.scheduler == "lockstep":
            # the lockstep ablation is the contiguous-strip baseline by
            # definition — keeping it on KVCache strips is what makes it
            # a layout ablation rather than a second paged scheduler
            raise ValueError("scheduler='lockstep' runs on the contiguous "
                             "cache layout; unset page_size for lockstep")
        self.model = model
        self.params = params
        self.scfg = scfg
        # caches/enc_kvs (args 1, 2) are donated: the steady-state decode
        # loop aliases the slot pool in place instead of copying the whole
        # num_slots × max_len cache every token (prefill passes None there
        # — donating an empty pytree is a no-op); _insert/_reset donate
        # the pool for the same reason
        cm = scfg.candidate_mode
        if cm is not None and cm != "exact":
            try:
                m, t = cm
            except (TypeError, ValueError):
                raise ValueError(
                    f"ServeConfig.candidate_mode must be None, 'exact' or "
                    f"an (m, t) tuple, got {cm!r}")
            if getattr(model.cfg, "mach", None) is not None:
                # build the inverted table once, outside any trace
                model.mach_inverted_table()
        self._serve_step = jax.jit(
            make_serve_step_fn(model, scfg.top_k, scfg.candidate_mode),
            static_argnames=("estimators", "max_len", "linear_cap"),
            donate_argnums=(1, 2))
        self._insert = jax.jit(model.insert_cache_slot, donate_argnums=(0,))
        self._reset = jax.jit(model.reset_cache_slot,
                              static_argnames=("max_len",),
                              donate_argnums=(0,))
        self._key = jax.random.key(scfg.seed)
        # the fixed slot pool — allocated once, reused for every request
        if scfg.paged:
            ps = scfg.page_size
            self._max_pages = -(-scfg.max_len // ps)
            num_pages = scfg.num_pages or scfg.num_slots * self._max_pages
            self._num_pages = num_pages
            self._pool = model.init_paged_caches(
                scfg.num_slots, scfg.max_len, ps, num_pages)
            # deterministic FIFO free list: pages come back in the order
            # they were freed, so allocation is a pure function of the
            # request sequence (alloc/free/reuse determinism tests)
            self._free_pages: collections.deque = collections.deque(
                range(num_pages))
            self._insert_paged = jax.jit(model.insert_cache_slot_paged,
                                         donate_argnums=(0,))
            self._reset_paged = jax.jit(model.reset_cache_slot_paged,
                                        static_argnames=("max_len",),
                                        donate_argnums=(0,))
            # slot/page_idx/page_id ride as traced scalars: one trace
            # covers every boundary crossing
            self._append = jax.jit(model.append_cache_page,
                                   donate_argnums=(0,))
        else:
            self._num_pages = 0
            self._pool = model.init_caches(scfg.num_slots, scfg.max_len)
        self._enc_pool = None        # lazily shaped from the first request
        self._slots: list = [None] * scfg.num_slots
        self._queue: collections.deque = collections.deque()
        self._next_id = 0
        self._tick = 0               # scheduler ticks (latency unit)
        self._enc_shape = None       # pinned (S, F) across requests
        self.metrics = EngineMetrics(num_slots=scfg.num_slots,
                                     num_pages=self._num_pages)

    def __repr__(self) -> str:
        m = self.metrics
        live = sum(s is not None for s in self._slots)
        body = (f"slots={live}/{self.scfg.num_slots} "
                f"queue={len(self._queue)} tick={self._tick} "
                f"completed={m.completed}")
        if self.scfg.paged:
            body += (f" pages={m.pages_in_use}/{self._num_pages}"
                     f" reserved={m.pages_reserved}"
                     f" frag={m.fragmentation} peak={m.pages_peak}"
                     f" resv_fail={m.reservation_failures}")
        return f"<ServingEngine {body}>"

    # ------------------------------------------------------------- submit
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def submit(self, request: Request) -> int:
        """Validate and enqueue; returns the request id (results carry
        it, and ``run()`` orders by it)."""
        cfg = self.model.cfg
        scfg = self.scfg
        prompt = list(request.prompt)
        if not prompt:
            raise ValueError("Request.prompt must be non-empty")
        sp = request.sampling
        if sp.temperature is not None and sp.temperature <= 0:
            raise ValueError(f"SamplingParams.temperature must be > 0, "
                             f"got {sp.temperature}")
        if sp.top_k is not None and sp.top_k < 1:
            raise ValueError(f"SamplingParams.top_k must be >= 1, "
                             f"got {sp.top_k}")
        if sp.estimator is not None:
            if cfg.mach is None:
                raise ValueError("SamplingParams.estimator is a MACH-head "
                                 "knob; this model serves the OAA head")
            if sp.estimator not in _ESTIMATORS:
                raise ValueError(f"SamplingParams.estimator must be one of "
                                 f"{_ESTIMATORS}, got {sp.estimator!r}")
        max_new = (request.max_new_tokens
                   if request.max_new_tokens is not None
                   else scfg.max_new_tokens)
        if max_new < 1:
            raise ValueError("Request.max_new_tokens must be >= 1")
        prefix = cfg.num_prefix_tokens if request.prefix_feats is not None \
            else 0
        if prefix + len(prompt) + max_new - 1 > scfg.max_len:
            raise ValueError(
                f"prompt ({prefix + len(prompt)} tokens incl. prefix) + "
                f"max_new_tokens ({max_new}) exceeds the slot capacity "
                f"ServeConfig.max_len={scfg.max_len}")
        if scfg.paged:
            need = self._pages_for(prefix + len(prompt) + max_new - 1)
            if need > self._num_pages:
                # can never be satisfied even by an empty pool — reject
                # now instead of blocking the queue head forever
                raise ValueError(
                    f"request needs {need} pages (worst case) but the "
                    f"pool holds {self._num_pages}; raise "
                    f"ServeConfig.num_pages or page_size")
        self._validate_feats(request)
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, request, max_new, self._tick))
        return rid

    def _validate_feats(self, request: Request) -> None:
        """Frontend-feature consistency: the model decides whether
        features are required, and every request in one engine must
        agree on their shape (the cross-attention enc-KV pool is one
        fixed allocation, like the KV pool)."""
        cfg = self.model.cfg
        if cfg.num_encoder_layers:
            if request.enc_feats is None:
                raise ValueError(
                    f"model {cfg.name!r} has an encoder: every Request "
                    f"needs enc_feats (S, F) — a batch where only some "
                    f"requests carry features is inconsistent")
            ef = np.asarray(request.enc_feats)
            want_f = frontends.frontend_feature_dim(cfg.frontend or "audio")
            if ef.ndim != 2 or ef.shape[1] != want_f:
                raise ValueError(f"enc_feats must be (S, {want_f}), "
                                 f"got {ef.shape}")
            if self._enc_shape is not None and ef.shape != self._enc_shape:
                raise ValueError(
                    f"enc_feats shape {ef.shape} conflicts with this "
                    f"engine's pinned {self._enc_shape}: the enc-KV slot "
                    f"pool is one fixed allocation, so every request must "
                    f"use the same encoder feature shape")
            enc_shape = ef.shape
        else:
            enc_shape = None
            if request.enc_feats is not None:
                raise ValueError(f"model {cfg.name!r} has no encoder; "
                                 f"enc_feats would be silently dropped")
        if cfg.frontend == "vision":
            if request.prefix_feats is None:
                raise ValueError(f"model {cfg.name!r} has a vision "
                                 f"frontend: every Request needs "
                                 f"prefix_feats (P, F)")
            pf = np.asarray(request.prefix_feats)
            if pf.ndim != 2 or pf.shape != (cfg.num_prefix_tokens,
                                            frontends.VISION_FEATURE_DIM):
                raise ValueError(
                    f"prefix_feats must be ({cfg.num_prefix_tokens}, "
                    f"{frontends.VISION_FEATURE_DIM}), got {pf.shape}")
        elif request.prefix_feats is not None:
            raise ValueError(f"model {cfg.name!r} has no vision frontend; "
                             f"prefix_feats would be silently dropped")
        # pin only after the whole request validated — a rejected request
        # must not constrain future submissions
        if enc_shape is not None and self._enc_shape is None:
            self._enc_shape = enc_shape

    # ----------------------------------------------------------- sampling
    def _row_knobs(self, req: Request) -> tuple:
        """(temperature, row_top_k, estimator) for one request's row.

        A request samples iff it sets any knob or the engine default
        temperature is set; otherwise it rides the greedy ε-temperature
        top-1 path (of its estimator's scores)."""
        cfg, scfg = self.model.cfg, self.scfg
        sp = req.sampling
        est = sp.estimator or (cfg.mach.estimator if cfg.mach is not None
                               else "unbiased")
        samples = (sp.temperature is not None or sp.top_k is not None
                   or scfg.temperature is not None)
        if not samples:
            return _GREEDY_TEMP, 1, est
        t = sp.temperature if sp.temperature is not None else scfg.temperature
        t = 1.0 if t is None else t          # top_k-only request: temp 1.0
        k = sp.top_k if sp.top_k is not None else scfg.top_k
        return max(float(t), _GREEDY_TEMP), int(np.clip(k, 1, scfg.top_k)), est

    # ---------------------------------------------------------- scheduling
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    # ------------------------------------------------------ page allocator
    def _pages_for(self, tokens: int) -> int:
        return -(-tokens // self.scfg.page_size)

    def _alloc_pages(self, n: int) -> list:
        """Pop ``n`` page ids FIFO; caller must have reserved them."""
        assert len(self._free_pages) >= n, (len(self._free_pages), n)
        ids = [self._free_pages.popleft() for _ in range(n)]
        self.metrics.pages_in_use += n
        return ids

    def _release_pages(self, slot: _Slot) -> None:
        """Return a finished slot's pages (FIFO) and drop its worst-case
        reservation — the next admission sees them immediately."""
        self._free_pages.extend(slot.pages)
        self.metrics.pages_in_use -= len(slot.pages)
        self.metrics.pages_reserved -= slot.reserved
        slot.pages = []
        slot.reserved = 0

    def _finish(self, slot: _Slot, reason: str) -> GenerationResult:
        self.metrics.completed += 1
        return GenerationResult(
            request_id=slot.req_id, tokens=tuple(slot.tokens),
            finish_reason=reason, prompt_len=len(slot.req.prompt),
            submit_step=slot.submit_step, finish_step=self._tick)

    def _admit(self, finished: list) -> None:
        scfg = self.scfg
        if scfg.scheduler == "lockstep" and any(
                s is not None for s in self._slots):
            return                       # baseline: drain the whole chunk
        while self._queue:
            slot_i = self._free_slot()
            if slot_i is None:
                return
            rid, req, max_new, submit_step = self._queue[0]   # peek
            prefix = (self.model.cfg.num_prefix_tokens
                      if req.prefix_feats is not None else 0)
            need, pages, linear_cap = 0, [], None
            if scfg.paged:
                # reserve worst-case (prompt + max_new, page-rounded) up
                # front so a mid-decode boundary crossing can never find
                # the pool empty; only the prompt pages are allocated now
                need = self._pages_for(prefix + len(req.prompt)
                                       + max_new - 1)
                if need > self._num_pages - self.metrics.pages_reserved:
                    # backpressure: the head of the queue waits (FIFO —
                    # no later, smaller request jumps it) until EOS
                    # returns enough pages
                    self.metrics.reservation_failures += 1
                    return
            self._queue.popleft()
            temp, row_k, est = self._row_knobs(req)
            salt = _prng_salt(req.sampling.seed, rid)
            batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
            if req.enc_feats is not None:
                batch["enc_feats"] = jnp.asarray(req.enc_feats)[None]
            if req.prefix_feats is not None:
                batch["prefix_feats"] = jnp.asarray(req.prefix_feats)[None]
            if scfg.paged:
                self.metrics.pages_reserved += need
                self.metrics.pages_peak = max(self.metrics.pages_peak,
                                              self.metrics.pages_reserved)
                pages = self._alloc_pages(
                    self._pages_for(prefix + len(req.prompt)))
                # page-rounded prefill capacity: the batch-1 linear
                # strips reshape exactly into the reserved pages
                linear_cap = len(pages) * scfg.page_size
            one = lambda v, dt: jnp.asarray([v], dt)       # noqa: E731
            caches, enc_kvs, ids = self._serve_step(
                self.params, None, None, batch,
                one(0, jnp.int32), self._key, one(salt, jnp.int32),
                one(0, jnp.int32), one(temp, jnp.float32),
                one(row_k, jnp.int32), one(0, jnp.int32),
                estimators=(est,), max_len=scfg.max_len,
                linear_cap=linear_cap)
            self.metrics.prefills += 1
            tok = int(ids[0])
            self.metrics.tokens_generated += 1
            if req.on_token is not None:
                req.on_token(tok)
            slot = _Slot(req_id=rid, req=req, salt=salt, tokens=[tok],
                         pos=prefix + len(req.prompt), temp=temp,
                         row_k=row_k, est=est, max_new=max_new,
                         submit_step=submit_step,
                         first_token_step=self._tick,
                         pages=pages, reserved=need)
            if (scfg.eos_id >= 0 and tok == scfg.eos_id) or max_new == 1:
                # finished at prefill — the slot is never occupied
                if scfg.paged:
                    self._release_pages(slot)
                reason = "eos" if (scfg.eos_id >= 0
                                   and tok == scfg.eos_id) else "length"
                finished.append(self._finish(slot, reason))
                continue
            if scfg.paged:
                self._pool = self._insert_paged(
                    self._pool, caches, slot_i,
                    jnp.asarray(slot.pages, jnp.int32))
            else:
                self._pool = self._insert(self._pool, caches, slot_i)
            if enc_kvs is not None:
                if self._enc_pool is None:
                    self._enc_pool = jax.tree.map(
                        lambda x: jnp.zeros(
                            x.shape[:1] + (scfg.num_slots,) + x.shape[2:],
                            x.dtype), enc_kvs)
                self._enc_pool = self._insert(self._enc_pool, enc_kvs,
                                              slot_i)
            self._slots[slot_i] = slot

    def _decode_once(self, finished: list) -> None:
        scfg = self.scfg
        live = [s for s in self._slots if s is not None and not s.done]
        if not live:
            return
        self.metrics.peak_live_slots = max(self.metrics.peak_live_slots,
                                           len(live))
        if scfg.paged:
            # lazy page append: a slot whose next write crosses a page
            # boundary gets its next reserved page now.  The reservation
            # made at admission guarantees the free list is never empty
            # here.
            for i, s in enumerate(self._slots):
                if s is None or s.done:
                    continue
                pj = s.pos // scfg.page_size
                if pj >= len(s.pages):
                    (pid,) = self._alloc_pages(1)
                    s.pages.append(pid)
                    self._pool = self._append(
                        self._pool, jnp.int32(i), jnp.int32(pj),
                        jnp.int32(pid))
        estimators = tuple(sorted({s.est for s in live}))
        n = scfg.num_slots
        toks = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        req_ids = np.zeros((n,), np.int32)
        tok_idx = np.zeros((n,), np.int32)
        temps = np.full((n,), _GREEDY_TEMP, np.float32)
        row_k = np.ones((n,), np.int32)
        est_sel = np.zeros((n,), np.int32)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            toks[i, 0] = s.tokens[-1]
            pos[i] = s.pos
            if s.done:
                continue                 # lockstep hold: inert greedy row
            req_ids[i] = s.salt
            tok_idx[i] = len(s.tokens)
            temps[i] = s.temp
            row_k[i] = s.row_k
            est_sel[i] = estimators.index(s.est)
        self._pool, self._enc_pool, ids = self._serve_step(
            self.params, self._pool, self._enc_pool,
            {"tokens": jnp.asarray(toks)}, jnp.asarray(pos), self._key,
            jnp.asarray(req_ids), jnp.asarray(tok_idx),
            jnp.asarray(temps), jnp.asarray(row_k), jnp.asarray(est_sel),
            estimators=estimators, max_len=scfg.max_len)
        ids = np.asarray(ids)
        self.metrics.decode_steps += 1
        self.metrics.live_slot_steps += len(live)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            s.pos += 1                   # every slot's cache advanced
            if s.done:
                continue
            tok = int(ids[i])
            s.tokens.append(tok)
            self.metrics.tokens_generated += 1
            if s.req.on_token is not None:
                s.req.on_token(tok)
            reason = None
            if scfg.eos_id >= 0 and tok == scfg.eos_id:
                reason = "eos"
            elif len(s.tokens) >= s.max_new:
                reason = "length"
            if reason is None:
                continue
            finished.append(self._finish(s, reason))
            if scfg.scheduler == "continuous":
                # free immediately: next tick admits into this slot
                if scfg.paged:
                    self._release_pages(s)
                    self._pool = self._reset_paged(self._pool, i,
                                                   max_len=scfg.max_len)
                else:
                    self._pool = self._reset(self._pool, i,
                                             max_len=scfg.max_len)
                self._slots[i] = None
            else:
                s.done = True            # lockstep: hold until chunk drains
        if scfg.scheduler == "lockstep" and all(
                s is None or s.done for s in self._slots):
            for i, s in enumerate(self._slots):
                if s is not None:
                    self._pool = self._reset(self._pool, i,
                                             max_len=scfg.max_len)
                    self._slots[i] = None

    def step(self) -> list:
        """One scheduler tick: admit into free slots, advance the pool
        one decode step.  Returns the ``GenerationResult``s that
        finished this tick."""
        finished: list = []
        self._admit(finished)
        self._decode_once(finished)
        self._tick += 1
        return finished

    def run(self) -> list:
        """Drain queue and pool; results in submission order."""
        out: list = []
        while self._queue or any(s is not None for s in self._slots):
            out.extend(self.step())
        return sorted(out, key=lambda r: r.request_id)
