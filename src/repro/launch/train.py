"""Pod-scale training driver.

The same step that launch/dryrun.py lowers for the production meshes,
executed for real: mesh + logical-axis shardings + jit train step +
checkpoint-restart + straggler monitor.  On this CPU container it runs
with the local mesh (``--local``) at a reduced config; on a TPU pod the
identical code path runs the full config (device count and mesh shape
are the only differences).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --local --steps 20 --seq-len 64 --global-batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data import LMDataConfig, SyntheticLMStream
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.frontends import AUDIO_FEATURE_DIM, VISION_FEATURE_DIM
from repro.models.model import LanguageModel
from repro.sharding import partitioning as part
from repro.train.fault_tolerance import StragglerMonitor, run_with_restarts
from repro.train.trainer import TrainConfig, make_train_step
from repro.train.train_state import new_train_state


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need a real pod)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--local", action="store_true",
                    help="local-device mesh instead of the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pod_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LanguageModel(cfg)
    mesh = (make_local_mesh() if args.local
            else make_production_mesh(multi_pod=args.multi_pod))
    rules = part.ShardingRules(fsdp=True, sp=False)
    tcfg = TrainConfig(total_steps=args.steps, warmup_steps=2,
                       peak_lr=args.lr, checkpoint_every=max(5, args.steps // 4),
                       log_every=5)
    step_fn, opt = make_train_step(model.loss, tcfg)

    with part.activate(mesh, rules):
        state_shapes, state_shard, _ = part.state_shardings(
            mesh, rules, model, opt)
        data_cfg = LMDataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len,
            global_batch=args.global_batch,
            enc_feats_dim=AUDIO_FEATURE_DIM if cfg.num_encoder_layers else 0,
            enc_len=max(1, args.seq_len // 4),
            prefix_feats_dim=(VISION_FEATURE_DIM
                              if cfg.frontend == "vision" else 0),
            prefix_len=cfg.num_prefix_tokens)
        stream = SyntheticLMStream(data_cfg)
        batch_shard = part.batch_shardings(mesh, rules, stream.batch_at(0))
        rep = NamedSharding(mesh, P())
        metrics_shapes = jax.eval_shape(step_fn, state_shapes,
                                        stream.batch_at(0))[1]
        jit_step = jax.jit(step_fn,
                           in_shardings=(state_shard, batch_shard),
                           out_shardings=(state_shard,
                                          jax.tree.map(lambda _: rep,
                                                       metrics_shapes)),
                           donate_argnums=(0,))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        monitor = StragglerMonitor()

        def init_state():
            params, _ = model.init(jax.random.key(0))
            state = new_train_state(params, opt)
            return jax.device_put(state, state_shard)

        def train_once(state, remaining):
            start = int(state.step)
            for s in range(start, start + remaining):
                t0 = time.perf_counter()
                batch = jax.device_put(stream.batch_at(s), batch_shard)
                state, metrics = jit_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                slow = monitor.record(s, time.perf_counter() - t0)
                if (s + 1) % tcfg.log_every == 0:
                    print(f"step {s+1}: loss={float(metrics['loss']):.4f} "
                          f"lr={float(metrics['lr']):.2e}"
                          f"{'  [straggler]' if slow else ''}")
                if (s + 1) % tcfg.checkpoint_every == 0:
                    mgr.save(s + 1, state, blocking=False)
            mgr.save(start + remaining, state)
            return state

        state = run_with_restarts(train_once, init_state, mgr, args.steps)
        print(f"finished at step {int(state.step)}; "
              f"stragglers: {len(monitor.flagged)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
