import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first
# init, and the production-mesh dry-run needs 512 placeholder devices.
# Everything below this line may import jax.

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.models.model import LanguageModel
from repro.models.frontends import AUDIO_FEATURE_DIM, VISION_FEATURE_DIM
from repro.serving.engine import make_serve_step_fn
from repro.sharding import partitioning as part
from repro.train.trainer import TrainConfig, make_train_step
from repro.train.train_state import new_train_state

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the optimized
    (post-SPMD) per-device HLO module.  Grouped by op kind; '-start'
    variants counted once (async pairs)."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        for op in COLLECTIVE_OPS:
            # match ` op(`, ` op-start(` but not fusion mentions
            if re.search(rf"\s{op}(-start)?\(", rhs) or \
               rhs.startswith(f"{op}(") or rhs.startswith(f"{op}-start("):
                if f"{op}-done" in rhs:
                    break
                lhs_types = rhs.split(op)[0]
                out[op]["count"] += 1
                out[op]["bytes"] += _shape_bytes(lhs_types)
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg, seq_len: int, global_batch: int) -> dict:
    specs = {}
    text_len = seq_len
    if cfg.frontend == "vision":
        text_len = seq_len - cfg.num_prefix_tokens
        specs["prefix_feats"] = _sds((global_batch, cfg.num_prefix_tokens,
                                      VISION_FEATURE_DIM), jnp.float32)
    specs["tokens"] = _sds((global_batch, text_len + 1), jnp.int32)
    if cfg.num_encoder_layers:
        # audio frames are length-adapted ~4x shorter than target text
        specs["enc_feats"] = _sds((global_batch, max(1, seq_len // 4),
                                   AUDIO_FEATURE_DIM), jnp.float32)
    return specs


def prefill_batch_specs(cfg, seq_len: int, global_batch: int) -> dict:
    specs = train_batch_specs(cfg, seq_len, global_batch)
    specs["tokens"] = _sds((specs["tokens"].shape[0],
                            specs["tokens"].shape[1] - 1), jnp.int32)
    return specs


def cast_float_leaves(tree, dtype):
    def per(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return _sds(s.shape, dtype)
        return s
    return jax.tree.map(per, tree)


# ---------------------------------------------------------------------------
# sharding helpers for caches / enc_kvs
# ---------------------------------------------------------------------------

def tree_shardings_by_rank(mesh, rules_cfg, tree, cfg):
    """Heuristic for serve-side state: dim0 = layers (replicated),
    dim1 = batch; last dim of >=4D leaves tries 'model' via kv-heads/
    width divisibility."""
    rules = rules_cfg.table(mesh)

    def per(s):
        nd = len(s.shape)
        logical = [None] * nd
        if nd >= 2:
            logical[1] = "batch"
        if nd >= 4:
            # (layers, batch, seq, kv, hd) or (layers, batch, kv, hd, hd)
            logical[-2] = "kv_heads" if nd == 5 else "heads"
            logical[-1] = None
        if nd == 3:
            logical[-1] = "mlp"      # recurrent h (layers, batch, width)
        return NamedSharding(mesh, part.resolve_spec(mesh, rules, logical,
                                                     s.shape))

    return jax.tree.map(per, tree)


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    seconds: float = 0.0
    data: Optional[dict] = None


def _train_cfg_for(cfg, global_batch: int, mesh) -> TrainConfig:
    data_size = mesh.shape["data"] * mesh.shape.get("pod", 1)
    if cfg.d_model >= 12288 or cfg.num_experts >= 8:
        micro = 16                       # 100B-class: 1 row/device/micro
    elif cfg.d_model >= 6144:
        micro = 8
    else:
        micro = 4
    while micro > 1 and (global_batch % (micro * data_size)) != 0:
        micro //= 2
    return TrainConfig(optimizer="adamw", num_microbatches=micro,
                       master_weights=cfg.param_dtype is not None,
                       total_steps=10_000, warmup_steps=500)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               fsdp: bool = True, sp: Optional[bool] = None,
               mach: str = "auto", save_hlo: bool = False,
               page_size: int = 0, num_pages: int = 0) -> CellResult:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch, mach=mach)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return CellResult(arch, shape, mesh_name, ok=True, skipped=True,
                          reason=reason)
    spec = SHAPES[shape]
    t0 = time.time()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    if sp is None:
        # §Perf cell 1 (mistral-large train): sequence-parallel residual
        # sharding REGRESSED collectives 11x (per-layer full-seq
        # all-gathers) and is unnecessary for memory once params are
        # bf16 with f32 masters — default OFF, opt-in via --sp on.
        sp = False
    # serving: TP-only params for small models; weight sharding over the
    # data axis too (serve-FSDP, gathered layer-by-layer) once the
    # per-chip TP shard alone would blow HBM (mistral-123b: 15.4 GB)
    serve_fsdp = (cfg.param_count_estimate() * 2 / 16 > 6e9)
    rules = part.ShardingRules(
        fsdp=(fsdp if spec["kind"] == "train" else serve_fsdp), sp=sp)
    model = LanguageModel(cfg)
    kind = spec["kind"]

    with part.activate(mesh, rules):
        if kind == "train":
            lowered = _lower_train(model, cfg, mesh, rules, spec)
        elif kind == "prefill":
            lowered = _lower_prefill(model, cfg, mesh, rules, spec)
        else:
            lowered = _lower_decode(model, cfg, mesh, rules, spec,
                                    page_size=page_size, num_pages=num_pages)
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)                # raw, body-once (reference)
    corrected = hlo_analysis.analyze(hlo)        # trip-count-corrected
    n_chips = int(np.prod(list(mesh.shape.values())))

    # XLA's cost_analysis counts while bodies ONCE (verified); the
    # corrected numbers multiply loop bodies by parsed trip counts.
    flops_dev = float(corrected["flops"])
    bytes_dev = float(corrected["bytes"])
    compute_s = flops_dev / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_dev / mesh_lib.HBM_BW
    coll_s = corrected["collective_wire_bytes"] / mesh_lib.ICI_BW
    n_params = cfg.param_count_estimate()
    # MODEL_FLOPS: 6·N·D for train; 2·N·D for inference forward
    spec_d = SHAPES[shape]
    tokens = spec_d["seq_len"] * spec_d["global_batch"] if kind != "decode" \
        else spec_d["global_batch"]
    n_active = _active_params(cfg)
    model_flops = (6 if kind == "train" else 2) * n_active * tokens
    hlo_flops_global = flops_dev * n_chips

    data = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "kind": kind,
        "chips": n_chips,
        "memory": _memory_record(ma, hlo),
        "cost": {
            "flops_per_device": flops_dev,
            "flops_global": hlo_flops_global,
            "bytes_accessed_per_device": bytes_dev,
            "xla_raw_flops_body_once": float(ca.get("flops", 0.0)),
            "xla_raw_bytes_body_once": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": corrected["collectives"],
        "collectives_raw_body_once": coll,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "bottleneck": max(
                (("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)), key=lambda kv: kv[1])[0],
            "model_flops": model_flops,
            "useful_flops_fraction": (model_flops / hlo_flops_global
                                      if hlo_flops_global else 0.0),
        },
        "config": {
            "params_analytic": n_params, "params_active": n_active,
            "fsdp": rules.fsdp, "sp": rules.sp,
            "mach": (dataclasses.asdict(cfg.mach) if cfg.mach else None),
        },
    }
    res = CellResult(arch, shape, mesh_name, ok=True,
                     seconds=time.time() - t0, data=data)
    if save_hlo:
        res.data["hlo_path"] = _save_hlo(arch, shape, mesh_name, hlo)
    return res


def _memory_record(ma, hlo: str) -> dict:
    """Per-device HBM accounting.

    The CPU backend cannot matmul bf16, so XLA materializes hoisted f32
    copies of large bf16 buffers (KV caches, saved activation history)
    that DO NOT EXIST in a TPU compile (MXUs read bf16 natively) — see
    hlo_analysis.hoisted_f32_copy_bytes.  We report both the raw
    CPU-backend numbers and the TPU-adjusted figure (raw temp minus the
    top-3 hoisted copies, floored at 10% of temp); `fits_hbm` uses the
    adjusted figure, `fits_hbm_cpu_raw` the raw one.
    """
    hoisted = hlo_analysis.hoisted_f32_copy_bytes(hlo)
    temp_adj = int(max(ma.temp_size_in_bytes - hoisted,
                       0.1 * ma.temp_size_in_bytes))
    out_net = max(0, ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "per_device_peak_bytes": int(ma.peak_memory_in_bytes),
        "per_device_argument_bytes": int(ma.argument_size_in_bytes),
        "per_device_temp_bytes": int(ma.temp_size_in_bytes),
        "per_device_temp_tpu_adjusted_bytes": temp_adj,
        "per_device_hoisted_f32_copy_bytes": int(hoisted),
        "per_device_output_bytes": int(ma.output_size_in_bytes),
        "per_device_alias_bytes": int(ma.alias_size_in_bytes),
        "fits_hbm": bool(ma.argument_size_in_bytes + temp_adj + out_net
                         <= mesh_lib.HBM_PER_CHIP),
        "fits_hbm_cpu_raw": bool(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes + out_net
            <= mesh_lib.HBM_PER_CHIP),
    }


def _active_params(cfg) -> int:
    """Active (per-token) params: MoE counts top-k + shared experts only."""
    total = cfg.param_count_estimate()
    if not cfg.num_experts:
        return total
    mo = cfg.moe_d_ff or cfg.d_ff
    per_layer_all = cfg.num_experts * 3 * cfg.d_model * mo
    per_layer_act = cfg.experts_top_k * 3 * cfg.d_model * mo
    n_moe_layers = sum(1 for k in cfg.layout() if k == "moe")
    return total - n_moe_layers * (per_layer_all - per_layer_act)


def _lower_train(model, cfg, mesh, rules, spec):
    tcfg = _train_cfg_for(cfg, spec["global_batch"], mesh)
    step_fn, opt = make_train_step(model.loss, tcfg)
    state_shapes, state_shard, _ = part.state_shardings(mesh, rules, model, opt)
    batch_specs = train_batch_specs(cfg, spec["seq_len"], spec["global_batch"])
    batch_shard = part.batch_shardings(mesh, part.ShardingRules(
        fsdp=rules.fsdp, sp=False), batch_specs)
    rep = NamedSharding(mesh, P())
    metrics_shapes = jax.eval_shape(step_fn, state_shapes, batch_specs)[1]
    metrics_shard = jax.tree.map(lambda _: rep, metrics_shapes)
    return jax.jit(step_fn,
                   in_shardings=(state_shard, batch_shard),
                   out_shardings=(state_shard, metrics_shard),
                   donate_argnums=(0,)).lower(state_shapes, batch_specs)


def _serve_param_shapes(model, cfg, mesh, rules):
    params_shapes, axes = part.eval_shape_with_axes(model.init,
                                                    jax.random.key(0))
    params_shapes = cast_float_leaves(params_shapes, cfg.dtype)
    p_shard = part.params_shardings(mesh, rules, axes, params_shapes)
    return params_shapes, p_shard


def _greedy_serve_operands(model, b: int):
    """Greedy per-row operands for the unified serve step (ε-temperature
    over each row's top-1 candidate — the serving engine's greedy
    path)."""
    est = (model.cfg.mach.estimator if model.cfg.mach is not None
           else "unbiased")
    zeros = jnp.zeros((b,), jnp.int32)
    return (jax.random.key(0), zeros, zeros,
            jnp.full((b,), 1e-6, jnp.float32),
            jnp.ones((b,), jnp.int32), zeros, est)


def _lower_prefill(model, cfg, mesh, rules, spec):
    params_shapes, p_shard = _serve_param_shapes(model, cfg, mesh, rules)
    batch_specs = prefill_batch_specs(cfg, spec["seq_len"],
                                      spec["global_batch"])
    batch_shard = part.batch_shardings(mesh, rules, batch_specs)
    serve_step = make_serve_step_fn(model, top_k=8)

    def fn(p, b):
        gb = b["tokens"].shape[0]
        key, salts, tok_idx, temps, row_k, est_sel, est = \
            _greedy_serve_operands(model, gb)
        return serve_step(p, None, None, b, jnp.zeros((gb,), jnp.int32),
                          key, salts, tok_idx, temps, row_k, est_sel,
                          estimators=(est,),
                          max_len=spec["seq_len"] + 64)

    out_shapes = jax.eval_shape(fn, params_shapes, batch_specs)
    ids_shard = part.batch_shardings(mesh, rules, out_shapes[2])
    # caches / enc_kvs out-shardings stay UNSPECIFIED: XLA places the
    # serve-state (it shards GQA kv groups over mesh subgroups, which
    # PartitionSpec cannot express) — pinning them forces reshard
    # all-gathers of the whole cache at the step boundary.
    return jax.jit(fn, in_shardings=(p_shard, batch_shard),
                   out_shardings=(None, None, ids_shard)
                   ).lower(params_shapes, batch_specs)


def _lower_decode(model, cfg, mesh, rules, spec, page_size: int = 0,
                  num_pages: int = 0):
    params_shapes, p_shard = _serve_param_shapes(model, cfg, mesh, rules)
    gb, s = spec["global_batch"], spec["seq_len"]
    if page_size:
        # paged decode cell: the linear KV state is the shared page pool
        # (num_pages × page_size tokens/layer) instead of gb × s strips
        np_ = num_pages or gb * (-(-s // page_size))
        caches_shapes = jax.eval_shape(
            lambda: model.init_paged_caches(gb, s, page_size, np_))
    else:
        caches_shapes = jax.eval_shape(lambda: model.init_caches(gb, s))
    enc_shapes = None
    if cfg.num_encoder_layers:
        enc_out = _sds((gb, max(1, s // 4), cfg.d_model), cfg.dtype)
        enc_shapes = jax.eval_shape(
            model.enc_kvs,
            part.eval_shape_with_axes(model.init, jax.random.key(0))[0],
            enc_out)
        enc_shapes = cast_float_leaves(enc_shapes, cfg.dtype)
    tok_specs = _sds((gb,), jnp.int32)
    pos_specs = _sds((gb,), jnp.int32)
    tok_shard = part.batch_shardings(mesh, rules, tok_specs)
    serve_step = make_serve_step_fn(model, top_k=8)

    def decode(p, caches, enc_kvs, tokens, pos):
        key, salts, tok_idx, temps, row_k, est_sel, est = \
            _greedy_serve_operands(model, tokens.shape[0])
        caches, _, ids = serve_step(p, caches, enc_kvs,
                                    {"tokens": tokens[:, None]}, pos,
                                    key, salts, tok_idx, temps, row_k,
                                    est_sel, estimators=(est,), max_len=s)
        return caches, ids

    ids_shard = part.batch_shardings(mesh, rules, tok_specs)
    # cache/enc_kv shardings UNSPECIFIED (XLA GSPMD places loop state —
    # see _lower_prefill) + donated: the output cache aliases the input,
    # matching the steady-state serving loop.  NOTE: the unified step
    # decodes per-slot (each row's KV write at its own index — a vmapped
    # scatter), so slot pools should be sharded over replicas, not over
    # the cache's sequence axis; see cache_update_decode.
    return jax.jit(decode,
                   in_shardings=(p_shard, None, None, tok_shard, tok_shard),
                   out_shardings=(None, ids_shard),
                   donate_argnums=(1,),
                   ).lower(params_shapes, caches_shapes, enc_shapes,
                           tok_specs, pos_specs)


def _save_hlo(arch, shape, mesh_name, hlo) -> str:
    d = os.path.join(ARTIFACT_DIR, mesh_name)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{arch}__{shape}.hlo.txt")
    with open(path, "w") as f:
        f.write(hlo)
    return path


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_one(args) -> int:
    res = lower_cell(args.arch, args.shape, args.multi_pod,
                     fsdp=not args.no_fsdp,
                     sp=None if args.sp == "auto" else args.sp == "on",
                     mach=args.mach, save_hlo=args.save_hlo,
                     page_size=args.page_size, num_pages=args.num_pages)
    d = os.path.join(ARTIFACT_DIR, res.mesh)
    os.makedirs(d, exist_ok=True)
    out = os.path.join(d, f"{args.arch}__{args.shape}.json")
    payload = dataclasses.asdict(res)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    if res.skipped:
        print(f"SKIP {args.arch} × {args.shape} [{res.mesh}]: {res.reason}")
        return 0
    rf = res.data["roofline"]
    mem = res.data["memory"]
    print(f"OK {args.arch} × {args.shape} [{res.mesh}] "
          f"{res.seconds:.0f}s  peak/dev={mem['per_device_peak_bytes']/2**30:.2f}GiB "
          f"fits={mem['fits_hbm']}  "
          f"compute={rf['compute_s']*1e3:.2f}ms memory={rf['memory_s']*1e3:.2f}ms "
          f"coll={rf['collective_s']*1e3:.2f}ms -> {rf['bottleneck']}")
    print(json.dumps({"memory_analysis": res.data["memory"],
                      "cost_analysis": res.data["cost"]}, indent=1))
    return 0


def run_all(args) -> int:
    """Spawn one subprocess per cell (isolates compile memory; a failed
    cell doesn't kill the sweep)."""
    fails = []
    meshes = [False, True] if args.mesh == "both" else \
        [args.mesh == "multi"]
    for multi in meshes:
        for arch in (args.archs or ARCH_IDS):
            for shape in (args.shapes or SHAPES):
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                out = os.path.join(ARTIFACT_DIR, mesh_name,
                                   f"{arch}__{shape}.json")
                if args.resume and os.path.exists(out):
                    with open(out) as f:
                        if json.load(f).get("ok"):
                            continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape]
                if multi:
                    cmd.append("--multi-pod")
                for flag in ("--no-fsdp",):
                    if getattr(args, flag.strip("-").replace("-", "_"), False):
                        cmd.append(flag)
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=3600)
                tail = (r.stdout.strip().splitlines() or [""])[0]
                print(f"[{time.strftime('%H:%M:%S')}] {mesh_name} {arch} × "
                      f"{shape}: rc={r.returncode} ({time.time()-t0:.0f}s) "
                      f"{tail[:110]}")
                if r.returncode != 0:
                    fails.append((mesh_name, arch, shape))
                    err = (r.stderr or "").strip().splitlines()
                    print("   " + "\n   ".join(err[-6:]))
    print(f"\n{'ALL CELLS PASS' if not fails else f'{len(fails)} FAILURES'}")
    for f3 in fails:
        print("  FAIL:", *f3)
    return 1 if fails else 0


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true", dest="multi_pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    ap.add_argument("--no-fsdp", action="store_true", dest="no_fsdp")
    ap.add_argument("--sp", choices=("auto", "on", "off"), default="auto")
    ap.add_argument("--mach", choices=("auto", "on", "off"), default="auto")
    ap.add_argument("--save-hlo", action="store_true", dest="save_hlo")
    ap.add_argument("--page-size", type=int, default=0, dest="page_size",
                    help="decode cells: paged KV pool page size "
                         "(0: contiguous strips)")
    ap.add_argument("--num-pages", type=int, default=0, dest="num_pages",
                    help="decode cells: KV pool pages (0: derive "
                         "batch * ceil(seq_len / page_size))")
    args = ap.parse_args()
    if args.all:
        return run_all(args)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        return run_one(args)
    except Exception:
        traceback.print_exc()
        return 1


if __name__ == "__main__":
    sys.exit(main())
