"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE,
regardless of trip count (verified in this environment: a scan of length
2, 4 or 8 reports identical flops).  Layer-scanned models therefore
undercount FLOPs, bytes and collective volume by ~num_layers.  This
module re-derives the three roofline inputs directly from
``compiled.as_text()`` with while-body costs multiplied by trip counts
parsed from the loop condition (jax scans lower to ``iter < C`` with a
literal C).

Costs are per-device (the SPMD module is the per-partition program):

  flops            dot ops exact (2·|out|·K), elementwise/reduce ~|shape|
  bytes            at fusion/kernel boundaries: operands + outputs
  collectives      per kind: count, in/out bytes, wire bytes = max(in,out)

Validated against XLA's cost_analysis on scan-free programs
(tests/test_hlo_analysis.py) to within a few percent on dot-dominated
graphs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "power", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "atan2",
    "remainder", "clamp", "select", "compare", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}


def _shape_list(text: str):
    """All (dtype, dims) tuples in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n))
    return out


def _bytes_of(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shape_list(text))


def _elems_of(text: str) -> int:
    return sum(n for _, n in _shape_list(text))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    raw_operands: str = ""
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    by_name: dict


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],:\sSTE(){}#*]+?)\s+"
    r"([\w\-]+)\((.*)$")


def parse_module(hlo: str) -> tuple[dict, Optional[str]]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        s = line.strip()
        # cut metadata (contains braces/parens that confuse parsing)
        s = re.split(r",\s*metadata=\{", s)[0]
        m = _OP_RE.match(s)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operand list: up to the matching close paren at depth 0
        depth = 1
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        attrs = rest[end + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        op = Op(name, type_str, opcode, operands, attrs,
                raw_operands=operand_str,
                is_root=s.startswith("ROOT "))
        cur.ops.append(op)
        cur.by_name[name] = op
    return comps, entry


# NOTE: the generic _OP_RE drops constant literals (they are not %refs).
# We re-scan the raw text for while conditions instead, which is simpler
# and robust: build {comp_name: max_s32_literal} in one pass.

_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _cond_literals(hlo: str) -> dict:
    lits: dict = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            continue
        if line == "}":
            cur = None
            continue
        if cur:
            c = _CONST_RE.search(line)
            if c:
                v = int(c.group(1))
                if v > lits.get(cur, 0):
                    lits[cur] = v
    return lits


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.coll.items():
            slot = self.coll.setdefault(
                k, {"count": 0.0, "in_bytes": 0.0, "out_bytes": 0.0,
                    "wire_bytes": 0.0})
            for kk in slot:
                slot[kk] += v[kk] * mult


def _dot_flops(op: Op, comp: Computation, shapes: dict) -> float:
    out_elems = _elems_of(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs_shape = shapes.get(op.operands[0])
    if lhs_shape is None:
        return 2.0 * out_elems
    dims = [int(d) for d in m.group(1).split(",") if d]
    k = 1
    for d in dims:
        if d < len(lhs_shape):
            k *= lhs_shape[d]
    return 2.0 * out_elems * k


def _op_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def analyze(hlo: str, top_k: int = 0) -> dict:
    """top_k > 0: also return the top byte-contributing (op, shape) sites
    with loop multipliers applied — the dry-run 'profiler' for §Perf."""
    comps, entry = parse_module(hlo)
    lits = _cond_literals(hlo)
    memo: dict = {}
    contrib: dict = {}

    def note(op, bts, mult):
        if top_k and bts:
            key = (op.opcode, op.type_str[:64])
            contrib[key] = contrib.get(key, 0.0) + bts * mult

    # --- convert look-through -------------------------------------------
    # The CPU backend materializes f32 copies of every bf16 dot operand
    # (TPU MXUs read bf16 natively).  To keep byte counts
    # hardware-faithful we (a) treat `convert` ops and convert-only
    # fusions as transparent (zero traffic of their own) and (b) count
    # every operand at the byte-width of the tensor *behind* the convert.

    _TRANSPARENT_INNER = {"parameter", "convert", "bitcast", "copy",
                          "tuple", "get-tuple-element"}
    _transparent_fusion: dict = {}

    def is_transparent_fusion(called: str) -> bool:
        if called in _transparent_fusion:
            return _transparent_fusion[called]
        c = comps.get(called)
        ok = c is not None and all(o.opcode in _TRANSPARENT_INNER
                                   for o in c.ops)
        _transparent_fusion[called] = ok
        return ok

    def _is_transparent_op(comp, op) -> bool:
        if op.opcode == "convert":
            return True
        if op.opcode == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            return bool(cm and is_transparent_fusion(cm.group(1)))
        return False

    def effective_type(comp, name: str, depth: int = 0) -> str:
        op = comp.by_name.get(name)
        if op is None:
            return ""
        if depth <= 8 and _is_transparent_op(comp, op) and op.operands:
            inner = effective_type(comp, op.operands[0], depth + 1)
            m_in = _SHAPE_RE.search(inner) if inner else None
            m_out = _SHAPE_RE.search(op.type_str)
            if m_in and m_out:
                # dims of this op, dtype (byte width) of the source
                return f"{m_in.group(1)}[{m_out.group(2)}]"
        return op.type_str

    def operand_bytes(comp, op) -> int:
        return sum(_bytes_of(effective_type(comp, o))
                   for o in op.operands if o in comp.by_name)

    def fusion_boundary_bytes(comp, op, called_name: str) -> int:
        """HBM traffic of a fusion kernel: inputs + outputs, but
        (a) a parameter consumed only through dynamic-slice is charged
            at the slice size (loop reads of stacked scan buffers), and
        (b) a root dynamic-update-slice aliases its target operand:
            charge 2x the update size, not the whole buffer (loop
            writes into stacked scan buffers)."""
        called = comps.get(called_name)
        if called is None:
            return operand_bytes(comp, op) + _bytes_of(op.type_str)
        # map parameter index -> charged bytes override
        param_ops = {}
        for o in called.ops:
            if o.opcode == "parameter":
                try:
                    param_ops[o.name] = int(o.raw_operands.strip())
                except ValueError:
                    pass

        def resolve(name, depth=0):
            """Follow convert/bitcast/copy chains to the source op."""
            o = called.by_name.get(name)
            while o is not None and depth < 8 and \
                    o.opcode in ("convert", "bitcast", "copy") and o.operands:
                o = called.by_name.get(o.operands[0])
                depth += 1
            return o

        override: dict = {}          # param index -> bytes
        root = None
        for o in called.ops:
            if o.is_root:
                root = o
        for o in called.ops:
            if o.opcode == "dynamic-slice" and o.operands:
                srcop = resolve(o.operands[0])
                if srcop is not None and srcop.name in param_ops:
                    idx = param_ops[srcop.name]
                    override[idx] = min(
                        override.get(idx, 1 << 62), _bytes_of(o.type_str))
        out_bytes = _bytes_of(op.type_str)

        def find_dus(name, depth=0):
            """BFS back from the root through convert/bitcast/copy/select
            to a dynamic-update-slice (scan ys-writes are often gated by
            a bounds-check select around the DUS)."""
            o = called.by_name.get(name)
            if o is None or depth > 8:
                return None
            if o.opcode == "dynamic-update-slice":
                return o
            if o.opcode in ("convert", "bitcast", "copy") and o.operands:
                return find_dus(o.operands[0], depth + 1)
            if o.opcode == "select" and len(o.operands) == 3:
                for cand in (o.operands[1], o.operands[2]):
                    hit = find_dus(cand, depth + 1)
                    if hit is not None:
                        return hit
            return None

        root_r = find_dus(root.name) if root is not None else None
        if root_r is not None and root_r.opcode == "dynamic-update-slice" \
                and root_r.operands:
            tgt = resolve(root_r.operands[0])
            if tgt is not None and tgt.name in param_ops:
                override[param_ops[tgt.name]] = 0   # aliased in-place
            upd = (called.by_name.get(root_r.operands[1])
                   if len(root_r.operands) > 1 else None)
            if upd is not None:
                # charge the update window at the *storage* dtype width
                m_out = _SHAPE_RE.search(op.type_str)
                bw = _DTYPE_BYTES.get(m_out.group(1), 4) if m_out else 4
                out_bytes = 2 * _elems_of(upd.type_str) * bw
        total_in = 0
        for pos, o in enumerate(op.operands):
            if pos in override:
                total_in += override[pos]
            elif o in comp.by_name:
                total_in += _bytes_of(effective_type(comp, o))
        return total_in + out_bytes

    def shapes_table(comp: Computation) -> dict:
        tab = {}
        for op in comp.ops:
            tab[op.name] = _op_shape_dims(op.type_str)
        return tab

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        total = Cost()
        if comp is None:
            memo[name] = total
            return total
        shapes = shapes_table(comp)
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "convert"):
                continue
            if oc == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                trips = lits.get(cond.group(1), 1) if cond else 1
                if body:
                    total.add(comp_cost(body.group(1)), float(max(trips, 1)))
                continue
            if oc == "conditional":
                for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%?([\w.\-]+))",
                                     op.attrs):
                    for g in m.groups():
                        if g:
                            for b in re.findall(r"%?([\w.\-]+)", g):
                                total.add(comp_cost(b), 1.0)
                continue
            if oc in ("call", "async-start"):
                cm = re.search(r"to_apply=%?([\w.\-]+)", op.attrs)
                if cm:
                    total.add(comp_cost(cm.group(1)), 1.0)
                continue
            is_coll = None
            for c in COLLECTIVES:
                if oc == c or oc == c + "-start":
                    is_coll = c
                    break
            if is_coll:
                out_b = _bytes_of(op.type_str)
                in_b = operand_bytes(comp, op)
                slot = total.coll.setdefault(
                    is_coll, {"count": 0.0, "in_bytes": 0.0, "out_bytes": 0.0,
                              "wire_bytes": 0.0})
                slot["count"] += 1
                slot["in_bytes"] += in_b
                slot["out_bytes"] += out_b
                slot["wire_bytes"] += max(in_b, out_b)
                total.bytes += in_b + out_b
                continue
            if oc == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if cm:
                    if is_transparent_fusion(cm.group(1)):
                        continue          # pure dtype/layout shim
                    sub = comp_cost(cm.group(1))
                    total.flops += sub.flops
                    total.transcendentals += sub.transcendentals
                    total.bytes += fusion_boundary_bytes(comp, op,
                                                         cm.group(1))
                else:
                    total.bytes += (operand_bytes(comp, op)
                                    + _bytes_of(op.type_str))
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp, shapes)
                total.bytes += operand_bytes(comp, op) + _bytes_of(op.type_str)
                continue
            if oc in ("dynamic-update-slice",):
                upd_t = (effective_type(comp, op.operands[1])
                         if len(op.operands) > 1 else op.type_str)
                total.bytes += 2 * _bytes_of(upd_t)
                continue
            if oc in ("dynamic-slice", "slice"):
                # only the sliced window moves, not the source buffer
                total.bytes += 2 * _bytes_of(op.type_str)
                continue
            if oc in ("gather", "scatter", "copy",
                      "transpose", "reshape", "concatenate",
                      "broadcast", "reverse", "pad", "reduce", "iota",
                      "reduce-window", "select-and-scatter",
                      "sort", "custom-call", "rng", "rng-bit-generator",
                      "cholesky", "fft", "triangular-solve", "map",
                      "clz", "popcnt"):
                ob = _bytes_of(op.type_str)
                ib = operand_bytes(comp, op)
                total.bytes += ib + ob
                if oc == "reduce":
                    total.flops += ib / 4.0  # ~1 op/elem
                continue
            if oc in _ELEMENTWISE_FLOP_OPS:
                n = _elems_of(op.type_str)
                total.flops += n
                if oc in ("exponential", "log", "tanh", "logistic", "rsqrt",
                          "sqrt", "power", "cosine", "sine", "atan2",
                          "exponential-minus-one", "log-plus-one"):
                    total.transcendentals += n
                total.bytes += operand_bytes(comp, op) + _bytes_of(op.type_str)
                continue
            # unknown op: count boundary bytes conservatively
            total.bytes += _bytes_of(op.type_str)
        memo[name] = total
        return total

    if entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    c = comp_cost(entry)

    if top_k:
        # second walk attributing per-op bytes with multipliers
        def walk(name: str, mult: float):
            comp = comps.get(name)
            if comp is None:
                return
            for op in comp.ops:
                oc = op.opcode
                if oc == "while":
                    body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                    cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                    trips = lits.get(cond.group(1), 1) if cond else 1
                    if body:
                        walk(body.group(1), mult * max(trips, 1))
                    continue
                if oc in ("parameter", "constant", "tuple",
                          "get-tuple-element", "bitcast", "convert",
                          "after-all"):
                    continue
                if oc == "fusion":
                    cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                    if cm and is_transparent_fusion(cm.group(1)):
                        continue
                    if cm:
                        note(op, fusion_boundary_bytes(comp, op, cm.group(1)),
                             mult)
                    continue
                if oc in ("dynamic-slice", "slice"):
                    note(op, 2 * _bytes_of(op.type_str), mult)
                    continue
                if oc == "dynamic-update-slice":
                    upd_t = (effective_type(comp, op.operands[1])
                             if len(op.operands) > 1 else op.type_str)
                    note(op, 2 * _bytes_of(upd_t), mult)
                    continue
                note(op, operand_bytes(comp, op) + _bytes_of(op.type_str),
                     mult)
        walk(entry, 1.0)
    coll_wire = sum(v["wire_bytes"] for v in c.coll.values())
    coll_count = sum(v["count"] for v in c.coll.values())
    out = {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collectives": c.coll,
        "collective_wire_bytes": coll_wire,
        "collective_count": coll_count,
    }
    if top_k:
        top = sorted(contrib.items(), key=lambda kv: -kv[1])[:top_k]
        out["top_bytes"] = [
            {"op": k[0], "type": k[1], "bytes": v} for k, v in top]
    return out


def hoisted_f32_copy_bytes(hlo: str, min_bytes: int = 1 << 26) -> int:
    """Bytes of large f32 buffers materialized by `convert` from bf16.

    The CPU backend cannot matmul bf16 natively, so it converts bf16
    operands to f32; XLA then hoists loop-invariant converts into whole-
    buffer f32 copies (e.g. an f32 duplicate of the entire KV cache or of
    the saved activation history).  TPU MXUs read bf16 directly — these
    copies do not exist in a TPU compile.  Dry-run memory accounting
    subtracts them ("tpu_adjusted_temp").
    """
    comps, _ = parse_module(hlo)
    sizes = []
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode != "convert":
                continue
            m_out = _SHAPE_RE.search(op.type_str)
            if not m_out or m_out.group(1) != "f32":
                continue
            nbytes = _bytes_of(op.type_str)
            if nbytes < min_bytes:
                continue
            srcop = comp.by_name.get(op.operands[0]) if op.operands else None
            src_t = srcop.type_str if srcop is not None else ""
            m_in = _SHAPE_RE.search(src_t)
            if m_in and m_in.group(1) == "bf16":
                sizes.append(nbytes)
    # Only the few largest copies plausibly coexist with their bf16
    # sources at the peak; everything else is buffer-reused.
    return sum(sorted(sizes, reverse=True)[:3])
