"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
initialization and only then calls these.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — used by tests/examples on CPU."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link (~uni-directional)
HBM_PER_CHIP = 16 * 2**30      # bytes
