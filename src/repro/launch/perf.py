import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Perf hillclimbing driver (§Perf): lowers named VARIANTS of the three
# hillclimb cells and records the roofline deltas.  Each variant is a
# (config transform, rules override) pair; results go to
# artifacts/perf/<cell>__<variant>.json for EXPERIMENTS.md §Perf.

import argparse
import dataclasses
import json
import sys

import jax

from repro.configs import get_config, SHAPES
from repro.launch import dryrun as dr
from repro.launch import mesh as mesh_lib
from repro.launch import hlo_analysis
from repro.models.model import LanguageModel
from repro.sharding import partitioning as part

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "perf")


def lower_variant(arch: str, shape: str, *, multi_pod=False, mach="auto",
                  cfg_updates=None, fsdp=True, sp=None,
                  mach_pod_parallel=False, micro=None, top_bytes=0):
    cfg = get_config(arch, mach=mach)
    if cfg_updates:
        cfg = dataclasses.replace(cfg, **cfg_updates)
    spec = SHAPES[shape]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    if sp is None:
        sp = False       # §Perf cell 1: SP regresses collectives 11x
    serve_fsdp = (cfg.param_count_estimate() * 2 / 16 > 6e9)
    rules = part.ShardingRules(
        fsdp=(fsdp if spec["kind"] == "train" else serve_fsdp), sp=sp,
        mach_pod_parallel=mach_pod_parallel)
    model = LanguageModel(cfg)
    kind = spec["kind"]

    if micro is not None:
        orig = dr._train_cfg_for

        def patched(cfg2, gb, mesh2):
            t = orig(cfg2, gb, mesh2)
            return dataclasses.replace(t, num_microbatches=micro)
        dr._train_cfg_for = patched
    try:
        with part.activate(mesh, rules):
            if kind == "train":
                lowered = dr._lower_train(model, cfg, mesh, rules, spec)
            elif kind == "prefill":
                lowered = dr._lower_prefill(model, cfg, mesh, rules, spec)
            else:
                lowered = dr._lower_decode(model, cfg, mesh, rules, spec)
            compiled = lowered.compile()
    finally:
        if micro is not None:
            dr._train_cfg_for = orig

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    res = hlo_analysis.analyze(hlo, top_k=top_bytes)
    out = {
        "flops_dev": res["flops"],
        "bytes_dev": res["bytes"],
        "coll_wire": res["collective_wire_bytes"],
        "collectives": res["collectives"],
        "compute_s": res["flops"] / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": res["bytes"] / mesh_lib.HBM_BW,
        "collective_s": res["collective_wire_bytes"] / mesh_lib.ICI_BW,
        "memory": dr._memory_record(ma, hlo),
    }
    if top_bytes:
        out["top_bytes"] = res["top_bytes"]
    return out


def report(cell, variant, r):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{cell}__{variant}.json"), "w") as f:
        json.dump(r, f, indent=1)
    m = r["memory"]
    print(f"{cell} [{variant}]: compute={r['compute_s']:.2f}s "
          f"memory={r['memory_s']:.2f}s coll={r['collective_s']:.2f}s | "
          f"args={m['per_device_argument_bytes']/2**30:.1f}G "
          f"temp_adj={m['per_device_temp_tpu_adjusted_bytes']/2**30:.1f}G "
          f"fits={m['fits_hbm']}", flush=True)
    for k, v in r["collectives"].items():
        print(f"    {k}: n={v['count']:.0f} wire={v['wire_bytes']/1e9:.1f}GB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=["paligemma_train", "mistral_train",
                             "mixtral_prefill", "qwen_train",
                             "paligemma_decode"])
    ap.add_argument("--variant", required=True)
    args = ap.parse_args()
    c, v = args.cell, args.variant

    if c == "paligemma_train":
        kw = dict(arch="paligemma-3b", shape="train_4k")
        variants = {
            "oaa_head": dict(mach="off"),                 # paper's baseline
            "mach_head": dict(mach="auto"),               # paper technique
            "mach_sp": dict(mach="auto", sp=True),
            "mach_pod_parallel": dict(mach="auto", multi_pod=True,
                                      mach_pod_parallel=True),
            "mach_multipod": dict(mach="auto", multi_pod=True),
        }
    elif c == "mistral_train":
        kw = dict(arch="mistral-large-123b", shape="train_4k")
        variants = {
            "base": dict(top_bytes=12),
            "no_sp": dict(sp=False),
            "micro8": dict(micro=8),
            "micro8_nosp": dict(micro=8, sp=False),
            "final_top": dict(micro=8, sp=False, top_bytes=14),
            "sp_on": dict(sp=True, top_bytes=12),
        }
    elif c == "qwen_train":
        kw = dict(arch="qwen2-moe-a2.7b", shape="train_4k")
        variants = {
            "oaa_head": dict(mach="off"),
            "mach_head": dict(mach="auto"),
            "mach_B4096_R4": dict(mach="auto", cfg_updates=dict(
                mach=__import__("repro.core.mach", fromlist=["MACHConfig"]
                                ).MACHConfig(151936, 4096, 4))),
        }
    elif c == "paligemma_decode":
        kw = dict(arch="paligemma-3b", shape="decode_32k")
        variants = {
            "oaa_head": dict(mach="off"),
            "mach_head": dict(mach="auto"),
        }
    else:
        kw = dict(arch="mixtral-8x22b", shape="prefill_32k")
        variants = {
            "base": dict(top_bytes=12),
            "group4096": dict(cfg_updates=dict(moe_group_size=4096)),
            "group8192": dict(cfg_updates=dict(moe_group_size=8192)),
            "bigchunks": dict(cfg_updates=dict(chunk_q=1024, chunk_k=2048)),
            "group512": dict(cfg_updates=dict(moe_group_size=512)),
            "final_top": dict(cfg_updates=dict(moe_group_size=512),
                              top_bytes=14),
            "ep_pad16": dict(cfg_updates=dict(moe_group_size=512,
                                              num_experts=16)),
        }
    report(c, v, lower_variant(**kw, **variants[v]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
