"""Pod-scale serving driver — mesh-sharded continuous-batching inference.

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
        --local --requests 6 --slots 4 --max-new 12 --scheduler continuous
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.frontends import AUDIO_FEATURE_DIM, VISION_FEATURE_DIM
from repro.models.model import LanguageModel
from repro.serving import Request, SamplingParams, ServeConfig, ServingEngine
from repro.serving.engine import SCHEDULERS
from repro.sharding import partitioning as part


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    # scheduler knobs (ServeConfig)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode-pool width (concurrent requests)")
    ap.add_argument("--max-len", type=int, default=64,
                    help="per-slot cache capacity")
    ap.add_argument("--max-new", type=int, default=12,
                    help="default per-request max_new_tokens")
    ap.add_argument("--scheduler", choices=SCHEDULERS, default="continuous")
    ap.add_argument("--eos", type=int, default=-1,
                    help="EOS token id (-1: never stop early)")
    ap.add_argument("--temperature", type=float, default=None,
                    help="engine-wide sampling default (None: greedy)")
    ap.add_argument("--top-k", type=int, default=50,
                    help="fused-kernel candidate cap")
    ap.add_argument("--estimator", choices=("unbiased", "min", "median"),
                    default=None,
                    help="per-request MACH estimator override")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged KV cache: tokens per page (0: contiguous "
                         "per-slot strips)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="shared KV page-pool size (0: derive "
                         "slots * ceil(max_len / page_size))")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LanguageModel(cfg)
    mesh = make_local_mesh() if args.local else make_production_mesh()
    rules = part.ShardingRules(fsdp=False, sp=False)

    with part.activate(mesh, rules):
        params, axes = model.init(jax.random.key(0))
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        p_shard = part.params_shardings(mesh, rules, axes, shapes)
        params = jax.device_put(params, p_shard)

        engine = ServingEngine(model, params,
                               ServeConfig(max_len=args.max_len,
                                           num_slots=args.slots,
                                           max_new_tokens=args.max_new,
                                           eos_id=args.eos,
                                           temperature=args.temperature,
                                           top_k=args.top_k,
                                           seed=args.seed,
                                           scheduler=args.scheduler,
                                           page_size=args.page_size,
                                           num_pages=args.num_pages))
        rng = np.random.default_rng(0)
        feats = {}
        if cfg.num_encoder_layers:
            feats["enc_feats"] = rng.standard_normal(
                (8, AUDIO_FEATURE_DIM)).astype(np.float32)
        if cfg.frontend == "vision":
            feats["prefix_feats"] = rng.standard_normal(
                (cfg.num_prefix_tokens, VISION_FEATURE_DIM)
            ).astype(np.float32)
        sampling = SamplingParams(estimator=args.estimator)
        for i in range(args.requests):
            plen = int(rng.integers(2, 8))
            engine.submit(Request(
                prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                sampling=sampling, **feats))
        t0 = time.perf_counter()
        outs = engine.run()
        dt = time.perf_counter() - t0
        for r in outs:
            print(f"request {r.request_id} ({r.finish_reason}, "
                  f"{r.latency_steps} ticks): {list(r.tokens)}")
        m = engine.metrics
        print(f"{len(outs)} requests, "
              f"{m.tokens_generated/dt:.1f} tok/s, "
              f"{m.decode_steps} decode steps, "
              f"occupancy {m.occupancy:.2f}")
        if args.page_size:
            print(f"page pool: {m.num_pages} pages x {args.page_size} "
                  f"tokens, peak {m.pages_peak} reserved, "
                  f"{m.reservation_failures} reservation stalls")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
