"""Pod-scale serving driver — mesh-sharded batched inference.

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
        --local --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.frontends import AUDIO_FEATURE_DIM, VISION_FEATURE_DIM
from repro.models.model import LanguageModel
from repro.serving import ServeConfig, ServingEngine
from repro.sharding import partitioning as part


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = LanguageModel(cfg)
    mesh = make_local_mesh() if args.local else make_production_mesh()
    rules = part.ShardingRules(fsdp=False, sp=False)

    with part.activate(mesh, rules):
        params, axes = model.init(jax.random.key(0))
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        p_shard = part.params_shardings(mesh, rules, axes, shapes)
        params = jax.device_put(params, p_shard)

        engine = ServingEngine(model, params,
                               ServeConfig(max_len=64,
                                           batch_size=args.batch,
                                           max_new_tokens=args.max_new))
        rng = np.random.default_rng(0)
        extras = {}
        if cfg.num_encoder_layers:
            extras["enc_feats"] = rng.standard_normal(
                (8, AUDIO_FEATURE_DIM)).astype(np.float32)
        if cfg.frontend == "vision":
            extras["prefix_feats"] = rng.standard_normal(
                (cfg.num_prefix_tokens, VISION_FEATURE_DIM)
            ).astype(np.float32)
        for i in range(args.requests):
            plen = int(rng.integers(2, 8))
            engine.add_request(list(rng.integers(1, cfg.vocab_size, plen)),
                               extras or None)
        t0 = time.perf_counter()
        outs = engine.run()
        dt = time.perf_counter() - t0
        for i, o in enumerate(outs):
            print(f"request {i}: {o}")
        print(f"{len(outs)} requests, "
              f"{sum(len(o) for o in outs)/dt:.1f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
