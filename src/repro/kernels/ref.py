"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references with
``interpret=True`` across shape/dtype sweeps (see tests/test_kernels.py).
The references are also the *paper-faithful* computations: e.g.
``mach_decode_ref`` materializes the full N×K global score matrix G
exactly as Algorithm 2 does, while the Pallas kernel never does.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# MACH decode (Algorithm 2): meta-probs -> top-1 class.
# ---------------------------------------------------------------------------

def mach_scores_ref(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Global score matrix G[n, k] = sum_r P[n, r, h_r(k)].

    meta_probs: (N, R, B); table: (R, K) -> G: (N, K)  (float32)

    Computed with the same one-hot contraction the kernel uses
    (S_r[b,k] = 1[h_r(k) = b]; G = sum_r P_r @ S_r), which is exactly
    Algorithm 2's gather-sum.
    """
    n, r, b = meta_probs.shape
    onehot = jax.nn.one_hot(table, b, dtype=jnp.float32, axis=-1)  # (R, K, B)
    return jnp.einsum("nrb,rkb->nk", meta_probs.astype(jnp.float32), onehot)


def mach_decode_ref(meta_probs: jnp.ndarray, table: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-1 (value, index) of the summed scores — argmax of the paper's
    unbiased estimator (the affine map of Eq. 2 is monotone in the sum).

    Returns (values (N,) float32, indices (N,) int32).
    """
    g = mach_scores_ref(meta_probs, table)
    idx = jnp.argmax(g, axis=-1)
    val = jnp.take_along_axis(g, idx[:, None], axis=-1)[:, 0]
    return val.astype(jnp.float32), idx.astype(jnp.int32)


def mach_estimator_scores_ref(meta_probs: jnp.ndarray, table: jnp.ndarray,
                              estimator: str = "unbiased") -> jnp.ndarray:
    """Estimator score matrix (N, K) — Eq. 2 / 7 / 8 via the explicit
    (R, N, K) gather.  The paper-faithful reference for the streaming
    top-k kernel, which never materializes any of these.

    meta_probs: (N, R, B); table: (R, K).  Delegates to the semantic
    definitions in ``core.estimators`` (single source of the paper's
    formulas); only the layout transpose lives here.
    """
    from repro.core.estimators import estimate_class_probs
    return estimate_class_probs(
        jnp.moveaxis(meta_probs.astype(jnp.float32), 1, 0), table, estimator)


def mach_topk_ref(meta_probs: jnp.ndarray, table: jnp.ndarray, k: int,
                  estimator: str = "unbiased"
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k (values, class ids) of the estimator scores — the oracle for
    ``mach_topk_pallas``.  Returns ((N, k) f32, (N, k) int32)."""
    scores = mach_estimator_scores_ref(meta_probs, table, estimator)
    val, idx = jax.lax.top_k(scores, k)
    return val.astype(jnp.float32), idx.astype(jnp.int32)


def mach_candidate_topk_ref(meta_probs: jnp.ndarray, table: jnp.ndarray,
                            k: int, m: int, t: int = 1,
                            estimator: str = "unbiased"
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Brute-force candidate-filtered top-k — the oracle for
    ``mach_topk_candidates``.

    Semantics: a class is a *candidate* iff its bucket value is >= the
    m-th largest bucket value (i.e. its bucket is in the top-m) in at
    least t of the R repetitions; the top-k ranks candidates by the
    estimator score.  Filtered slots come back as (-inf, -1); a row
    with no count>=t candidate backfills slot 0 with its best count>=1
    candidate.  Materializes the (N, K) membership and score matrices
    by design — the production paths never do.
    """
    n, r, b = meta_probs.shape
    meta = meta_probs.astype(jnp.float32)
    scores = mach_estimator_scores_ref(meta, table, estimator)    # (N, K)
    tau = jnp.min(jax.lax.top_k(meta, m)[0], axis=-1)             # (N, R)
    g = jnp.moveaxis(jnp.take_along_axis(
        jnp.moveaxis(meta, 1, 0), table[:, None, :], axis=-1), 0, -1)
    count = jnp.sum(g >= tau[:, None, :], axis=-1)                # (N, K)
    val, idx = jax.lax.top_k(jnp.where(count >= t, scores, -jnp.inf), k)
    idx = idx.astype(jnp.int32)
    if t > 1:
        s1 = jnp.where(count >= 1, scores, -jnp.inf)
        v1 = jnp.max(s1, axis=-1)
        i1 = jnp.argmax(s1, axis=-1).astype(jnp.int32)
        fill = (val[:, 0] == -jnp.inf) & (v1 > -jnp.inf)
        val = val.at[:, 0].set(jnp.where(fill, v1, val[:, 0]))
        idx = idx.at[:, 0].set(jnp.where(fill, i1, idx[:, 0]))
    idx = jnp.where(val == -jnp.inf, -1, idx)
    return val.astype(jnp.float32), idx


# ---------------------------------------------------------------------------
# MACH fused cross-entropy (training loss, Algorithm 1).
# ---------------------------------------------------------------------------

def mach_xent_ref(logits: jnp.ndarray, hashed_labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example summed R-head cross-entropy.

    logits: (N, R, B) — R independent B-way heads
    hashed_labels: (N, R) int32 bucket ids
    returns: (N,) float32,  loss_n = sum_r [ lse(logits[n,r]) - logits[n,r,y_nr] ]
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                      # (N, R)
    picked = jnp.take_along_axis(lg, hashed_labels[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]            # (N, R)
    return jnp.sum(lse - picked, axis=-1)


def mach_fused_xent_ref(h2: jnp.ndarray, w: jnp.ndarray,
                        hashed_labels: jnp.ndarray,
                        num_buckets: int,
                        bias: jnp.ndarray = None) -> jnp.ndarray:
    """Logit-materializing oracle for the fused projection+CE kernel.

    h2: (N, d); w: (d, R·B); hashed_labels: (N, R) int32; optional
    bias (R·B,) added to every logits row (the kernel's in-VMEM
    broadcast-add) -> (N,) f32.  Exactly the computation the fused
    kernel avoids: the full (N, R·B) logits tensor is formed (in f32,
    matching the kernel's accumulation dtype), then reduced by
    ``mach_xent_ref``.
    """
    n = h2.shape[0]
    r = hashed_labels.shape[-1]
    logits = jnp.dot(h2.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    return mach_xent_ref(logits.reshape(n, r, num_buckets), hashed_labels)


def csr_densify_ref(indptr: jnp.ndarray, indices: jnp.ndarray,
                    values: jnp.ndarray, num_features: int) -> jnp.ndarray:
    """CSR (indptr (N+1,), indices (nnz,), values (nnz,)) -> dense
    (N, d).  Duplicate indices within a row scatter-ADD, matching the
    one-hot densification the sparse kernel performs per tile."""
    n = indptr.shape[0] - 1
    nnz = indices.shape[0]
    if nnz == 0:
        return jnp.zeros((n, num_features), values.dtype)
    rows = jnp.repeat(jnp.arange(n), jnp.diff(indptr),
                      total_repeat_length=nnz)
    return jnp.zeros((n, num_features), values.dtype) \
        .at[rows, indices].add(values)


def mach_fused_xent_csr_ref(indptr: jnp.ndarray, indices: jnp.ndarray,
                            values: jnp.ndarray, w: jnp.ndarray,
                            hashed_labels: jnp.ndarray,
                            num_buckets: int,
                            bias: jnp.ndarray = None) -> jnp.ndarray:
    """Dense-densified oracle for the sparse fused projection+CE kernel.

    Exactly the computation the sparse kernel avoids: the CSR batch is
    scattered into a dense (N, d) activation (in f32 — the kernel's
    per-tile densification accumulates duplicate ids in f32, so the
    oracle must too, like ``mach_fused_xent_ref``'s f32 logits), then
    reduced through the materializing ``mach_fused_xent_ref``, whose
    ``bias`` (R·B,) broadcast-add matches the kernels' in-VMEM bias
    operand — d/d(bias) flows through the same path."""
    x = csr_densify_ref(indptr, indices, values.astype(jnp.float32),
                        w.shape[0])
    return mach_fused_xent_ref(x, w, hashed_labels, num_buckets,
                               bias=bias)


def flash_attention_ref(q, k, v, causal: bool = True, window=None):
    """Materializing attention oracle for ``ops.flash_attention`` — the
    exact jnp computation (scores in HBM) the Pallas kernel avoids."""
    from repro.models import attention as attn_lib  # deferred: models import kernels
    b, t = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    return attn_lib.attend(q, k, v, pos, pos, causal=causal, window=window,
                           flash_threshold=1 << 62)


def mach_xent_grad_ref(logits: jnp.ndarray, hashed_labels: jnp.ndarray,
                       g: jnp.ndarray) -> jnp.ndarray:
    """d loss / d logits = g * (softmax(logits) - onehot(labels)); (N, R, B)."""
    lg = logits.astype(jnp.float32)
    p = jax.nn.softmax(lg, axis=-1)
    oh = jax.nn.one_hot(hashed_labels, lg.shape[-1], dtype=jnp.float32)
    return (g[:, None, None] * (p - oh)).astype(logits.dtype)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (recurrentgemma substrate).
# ---------------------------------------------------------------------------

def lru_scan_ref(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + x_t.

    a, x: (B, T, D); h0: (B, D) -> h: (B, T, D)

    Implemented with an associative scan (Blelloch) — O(log T) depth,
    numerically the product-sum composition (a2·a1, a2·b1 + b2).
    """
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    x0 = x.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, x0), axis=1)
    return h
