"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references with
``interpret=True`` across shape/dtype sweeps (see tests/test_kernels.py).
The references are also the *paper-faithful* computations: e.g.
``mach_decode_ref`` materializes the full N×K global score matrix G
exactly as Algorithm 2 does, while the Pallas kernel never does.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# MACH decode (Algorithm 2): meta-probs -> top-1 class.
# ---------------------------------------------------------------------------

def mach_scores_ref(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Global score matrix G[n, k] = sum_r P[n, r, h_r(k)].

    meta_probs: (N, R, B); table: (R, K) -> G: (N, K)  (float32)

    Computed with the same one-hot contraction the kernel uses
    (S_r[b,k] = 1[h_r(k) = b]; G = sum_r P_r @ S_r), which is exactly
    Algorithm 2's gather-sum.
    """
    n, r, b = meta_probs.shape
    onehot = jax.nn.one_hot(table, b, dtype=jnp.float32, axis=-1)  # (R, K, B)
    return jnp.einsum("nrb,rkb->nk", meta_probs.astype(jnp.float32), onehot)


def mach_decode_ref(meta_probs: jnp.ndarray, table: jnp.ndarray
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-1 (value, index) of the summed scores — argmax of the paper's
    unbiased estimator (the affine map of Eq. 2 is monotone in the sum).

    Returns (values (N,) float32, indices (N,) int32).
    """
    g = mach_scores_ref(meta_probs, table)
    idx = jnp.argmax(g, axis=-1)
    val = jnp.take_along_axis(g, idx[:, None], axis=-1)[:, 0]
    return val.astype(jnp.float32), idx.astype(jnp.int32)


def mach_estimator_scores_ref(meta_probs: jnp.ndarray, table: jnp.ndarray,
                              estimator: str = "unbiased") -> jnp.ndarray:
    """Estimator score matrix (N, K) — Eq. 2 / 7 / 8 via the explicit
    (R, N, K) gather.  The paper-faithful reference for the streaming
    top-k kernel, which never materializes any of these.

    meta_probs: (N, R, B); table: (R, K).  Delegates to the semantic
    definitions in ``core.estimators`` (single source of the paper's
    formulas); only the layout transpose lives here.
    """
    from repro.core.estimators import estimate_class_probs
    return estimate_class_probs(
        jnp.moveaxis(meta_probs.astype(jnp.float32), 1, 0), table, estimator)


def mach_topk_ref(meta_probs: jnp.ndarray, table: jnp.ndarray, k: int,
                  estimator: str = "unbiased"
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k (values, class ids) of the estimator scores — the oracle for
    ``mach_topk_pallas``.  Returns ((N, k) f32, (N, k) int32)."""
    scores = mach_estimator_scores_ref(meta_probs, table, estimator)
    val, idx = jax.lax.top_k(scores, k)
    return val.astype(jnp.float32), idx.astype(jnp.int32)


def mach_candidate_topk_ref(meta_probs: jnp.ndarray, table: jnp.ndarray,
                            k: int, m: int, t: int = 1,
                            estimator: str = "unbiased"
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Brute-force candidate-filtered top-k — the oracle for
    ``mach_topk_candidates``.

    Semantics: a class is a *candidate* iff its bucket value is >= the
    m-th largest bucket value (i.e. its bucket is in the top-m) in at
    least t of the R repetitions; the top-k ranks candidates by the
    estimator score.  Filtered slots come back as (-inf, -1); a row
    with no count>=t candidate backfills slot 0 with its best count>=1
    candidate.  Materializes the (N, K) membership and score matrices
    by design — the production paths never do.
    """
    n, r, b = meta_probs.shape
    meta = meta_probs.astype(jnp.float32)
    scores = mach_estimator_scores_ref(meta, table, estimator)    # (N, K)
    tau = jnp.min(jax.lax.top_k(meta, m)[0], axis=-1)             # (N, R)
    g = jnp.moveaxis(jnp.take_along_axis(
        jnp.moveaxis(meta, 1, 0), table[:, None, :], axis=-1), 0, -1)
    count = jnp.sum(g >= tau[:, None, :], axis=-1)                # (N, K)
    val, idx = jax.lax.top_k(jnp.where(count >= t, scores, -jnp.inf), k)
    idx = idx.astype(jnp.int32)
    if t > 1:
        s1 = jnp.where(count >= 1, scores, -jnp.inf)
        v1 = jnp.max(s1, axis=-1)
        i1 = jnp.argmax(s1, axis=-1).astype(jnp.int32)
        fill = (val[:, 0] == -jnp.inf) & (v1 > -jnp.inf)
        val = val.at[:, 0].set(jnp.where(fill, v1, val[:, 0]))
        idx = idx.at[:, 0].set(jnp.where(fill, i1, idx[:, 0]))
    idx = jnp.where(val == -jnp.inf, -1, idx)
    return val.astype(jnp.float32), idx


# ---------------------------------------------------------------------------
# MACH fused cross-entropy (training loss, Algorithm 1).
# ---------------------------------------------------------------------------

def mach_xent_ref(logits: jnp.ndarray, hashed_labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example summed R-head cross-entropy.

    logits: (N, R, B) — R independent B-way heads
    hashed_labels: (N, R) int32 bucket ids
    returns: (N,) float32,  loss_n = sum_r [ lse(logits[n,r]) - logits[n,r,y_nr] ]
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                      # (N, R)
    picked = jnp.take_along_axis(lg, hashed_labels[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]            # (N, R)
    return jnp.sum(lse - picked, axis=-1)


def mach_fused_xent_ref(h2: jnp.ndarray, w: jnp.ndarray,
                        hashed_labels: jnp.ndarray,
                        num_buckets: int,
                        bias: jnp.ndarray = None) -> jnp.ndarray:
    """Logit-materializing oracle for the fused projection+CE kernel.

    h2: (N, d); w: (d, R·B); hashed_labels: (N, R) int32; optional
    bias (R·B,) added to every logits row (the kernel's in-VMEM
    broadcast-add) -> (N,) f32.  Exactly the computation the fused
    kernel avoids: the full (N, R·B) logits tensor is formed (in f32,
    matching the kernel's accumulation dtype), then reduced by
    ``mach_xent_ref``.
    """
    n = h2.shape[0]
    r = hashed_labels.shape[-1]
    logits = jnp.dot(h2.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    return mach_xent_ref(logits.reshape(n, r, num_buckets), hashed_labels)


def csr_densify_ref(indptr: jnp.ndarray, indices: jnp.ndarray,
                    values: jnp.ndarray, num_features: int) -> jnp.ndarray:
    """CSR (indptr (N+1,), indices (nnz,), values (nnz,)) -> dense
    (N, d).  Duplicate indices within a row scatter-ADD, matching the
    one-hot densification the sparse kernel performs per tile."""
    n = indptr.shape[0] - 1
    nnz = indices.shape[0]
    if nnz == 0:
        return jnp.zeros((n, num_features), values.dtype)
    rows = jnp.repeat(jnp.arange(n), jnp.diff(indptr),
                      total_repeat_length=nnz)
    return jnp.zeros((n, num_features), values.dtype) \
        .at[rows, indices].add(values)


def mach_fused_xent_csr_ref(indptr: jnp.ndarray, indices: jnp.ndarray,
                            values: jnp.ndarray, w: jnp.ndarray,
                            hashed_labels: jnp.ndarray,
                            num_buckets: int,
                            bias: jnp.ndarray = None) -> jnp.ndarray:
    """Dense-densified oracle for the sparse fused projection+CE kernel.

    Exactly the computation the sparse kernel avoids: the CSR batch is
    scattered into a dense (N, d) activation (in f32 — the kernel's
    per-tile densification accumulates duplicate ids in f32, so the
    oracle must too, like ``mach_fused_xent_ref``'s f32 logits), then
    reduced through the materializing ``mach_fused_xent_ref``, whose
    ``bias`` (R·B,) broadcast-add matches the kernels' in-VMEM bias
    operand — d/d(bias) flows through the same path."""
    x = csr_densify_ref(indptr, indices, values.astype(jnp.float32),
                        w.shape[0])
    return mach_fused_xent_ref(x, w, hashed_labels, num_buckets,
                               bias=bias)


# ---------------------------------------------------------------------------
# Dynamic bucket selection (training-time C-axis cut; arxiv 1801.01687's
# dynamic class selection, hashed to MACH buckets).
# ---------------------------------------------------------------------------

def mach_bucket_proxy_ref(h2: jnp.ndarray, w: jnp.ndarray,
                          num_buckets: int,
                          bias: jnp.ndarray = None) -> jnp.ndarray:
    """Cheap per-repetition bucket proxy scores from a dense batch:
    the logits of the batch-mean activation, ``mean_n(h) @ W + bias``,
    reshaped (R, B).  One d·R·B matvec — 1/N of the full projection —
    and reusable across steps (the trainer refreshes it every
    ``refresh_every`` steps), so its amortized cost is negligible."""
    c = w.shape[1]
    scores = jnp.dot(jnp.mean(h2.astype(jnp.float32), axis=0),
                     w.astype(jnp.float32))
    if bias is not None:
        scores = scores + bias.astype(jnp.float32)
    return scores.reshape(c // num_buckets, num_buckets)


def mach_bucket_proxy_csr_ref(indptr: jnp.ndarray, indices: jnp.ndarray,
                              values: jnp.ndarray, w: jnp.ndarray,
                              num_buckets: int,
                              bias: jnp.ndarray = None) -> jnp.ndarray:
    """CSR counterpart of ``mach_bucket_proxy_ref``: the batch-mean
    activation is a scatter-add of values/N — no densified (N, d)
    batch, cost O(nnz + d·R·B)."""
    n = indptr.shape[0] - 1
    xbar = jnp.zeros((w.shape[0],), jnp.float32) \
        .at[indices].add(values.astype(jnp.float32)) / jnp.maximum(n, 1)
    c = w.shape[1]
    scores = jnp.dot(xbar, w.astype(jnp.float32))
    if bias is not None:
        scores = scores + bias.astype(jnp.float32)
    return scores.reshape(c // num_buckets, num_buckets)


def mach_select_buckets_ref(proxy_scores: jnp.ndarray,
                            hashed_labels: jnp.ndarray,
                            num_buckets: int, c_sel: int) -> jnp.ndarray:
    """Top-``c_sel`` bucket columns per repetition by proxy score, with
    every bucket hit by a batch label force-included.

    proxy_scores (R, B) f32; hashed_labels (N, R) int32 -> selected
    (R, c_sel) int32, sorted ascending per row.  Force-inclusion makes
    the positive CE term exact (the label's logit is always in the
    selected set), so the selection bias is one-sided: it can only
    shrink the logsumexp.  Exact whenever a repetition's distinct label
    buckets number <= c_sel (with c_sel >= N that always holds); among
    the forced buckets and among the rest, proxy order breaks ties."""
    r, b = proxy_scores.shape
    if not 1 <= c_sel <= b:
        raise ValueError(f"need 1 <= c_sel <= num_buckets, got "
                         f"c_sel={c_sel}, num_buckets={b}")
    proxy = proxy_scores.astype(jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(r)[None, :], hashed_labels.shape)
    present = jnp.zeros((r, b), jnp.float32) \
        .at[rows, hashed_labels.astype(jnp.int32)].max(1.0)
    # a finite boost > the proxy span lifts every label bucket above
    # every unforced one while preserving proxy order within each group
    span = jnp.max(proxy) - jnp.min(proxy) + 1.0
    _, idx = jax.lax.top_k(proxy + present * span, c_sel)
    return jnp.sort(idx.astype(jnp.int32), axis=-1)


def mach_fused_xent_selected_ref(h2: jnp.ndarray, w: jnp.ndarray,
                                 hashed_labels: jnp.ndarray,
                                 selected: jnp.ndarray,
                                 num_buckets: int,
                                 bias: jnp.ndarray = None) -> jnp.ndarray:
    """Materializing oracle for the selected-bucket fused loss: form
    the full (N, R, B) logits, gather the selected columns per head,
    remap each label to its position inside the selection, reduce with
    ``mach_xent_ref``.  Requires every label bucket to be selected
    (``mach_select_buckets_ref`` force-includes them) — a missing label
    would silently alias to position 0."""
    n = h2.shape[0]
    r = hashed_labels.shape[-1]
    logits = jnp.dot(h2.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    logits3 = logits.reshape(n, r, num_buckets)
    sel = jnp.take_along_axis(logits3, selected[None, :, :], axis=2)
    pos = jnp.argmax(selected[None, :, :]
                     == hashed_labels[:, :, None].astype(jnp.int32),
                     axis=-1).astype(jnp.int32)
    return mach_xent_ref(sel, pos)


def mach_fused_xent_csr_selected_ref(indptr: jnp.ndarray,
                                     indices: jnp.ndarray,
                                     values: jnp.ndarray, w: jnp.ndarray,
                                     hashed_labels: jnp.ndarray,
                                     selected: jnp.ndarray,
                                     num_buckets: int,
                                     bias: jnp.ndarray = None
                                     ) -> jnp.ndarray:
    """CSR oracle for the selected-bucket fused loss: densify, then
    ``mach_fused_xent_selected_ref``."""
    x = csr_densify_ref(indptr, indices, values.astype(jnp.float32),
                        w.shape[0])
    return mach_fused_xent_selected_ref(x, w, hashed_labels, selected,
                                        num_buckets, bias=bias)


def mach_selected_bias_bound_ref(h2: jnp.ndarray, w: jnp.ndarray,
                                 hashed_labels: jnp.ndarray,
                                 selected: jnp.ndarray,
                                 num_buckets: int,
                                 bias: jnp.ndarray = None) -> jnp.ndarray:
    """Per-example upper bound on the (one-sided) selection bias.

    With the label bucket always selected, ``full_loss − sel_loss =
    Σ_r (lse_full − lse_sel)`` and each head's gap lies in ``[0,
    log1p((B − c_sel)·exp(m_exc − lse_sel))]`` where ``m_exc`` is that
    head's largest *excluded* logit — the bound this returns, (N,) f32.
    A-priori: when the selection contains each example's per-head
    top-c_sel logits, ``m_exc <= lse_sel`` and the gap is at most
    ``R·log(B/c_sel)`` per example; it shrinks as the proxy gets
    better.  Materializes the full logits — a test/benchmark helper,
    not a production path."""
    n = h2.shape[0]
    r = hashed_labels.shape[-1]
    b = num_buckets
    c_sel = selected.shape[-1]
    logits = jnp.dot(h2.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)[None, :]
    logits3 = logits.reshape(n, r, b)
    sel_logits = jnp.take_along_axis(logits3, selected[None, :, :], axis=2)
    lse_sel = jax.nn.logsumexp(sel_logits, axis=-1)           # (N, R)
    rows = jnp.broadcast_to(jnp.arange(r)[:, None], selected.shape)
    sel_mask = jnp.zeros((r, b), bool).at[rows, selected].set(True)
    m_exc = jnp.max(jnp.where(sel_mask[None], -jnp.inf, logits3), axis=-1)
    gap = jnp.log1p((b - c_sel) * jnp.exp(m_exc - lse_sel))
    return jnp.sum(jnp.where(jnp.isfinite(m_exc), gap, 0.0), axis=-1)


def flash_attention_ref(q, k, v, causal: bool = True, window=None):
    """Materializing attention oracle for ``ops.flash_attention`` — the
    exact jnp computation (scores in HBM) the Pallas kernel avoids."""
    from repro.models import attention as attn_lib  # deferred: models import kernels
    b, t = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    return attn_lib.attend(q, k, v, pos, pos, causal=causal, window=window,
                           flash_threshold=1 << 62)


def mach_xent_grad_ref(logits: jnp.ndarray, hashed_labels: jnp.ndarray,
                       g: jnp.ndarray) -> jnp.ndarray:
    """d loss / d logits = g * (softmax(logits) - onehot(labels)); (N, R, B)."""
    lg = logits.astype(jnp.float32)
    p = jax.nn.softmax(lg, axis=-1)
    oh = jax.nn.one_hot(hashed_labels, lg.shape[-1], dtype=jnp.float32)
    return (g[:, None, None] * (p - oh)).astype(logits.dtype)


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence (recurrentgemma substrate).
# ---------------------------------------------------------------------------

def lru_scan_ref(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + x_t.

    a, x: (B, T, D); h0: (B, D) -> h: (B, T, D)

    Implemented with an associative scan (Blelloch) — O(log T) depth,
    numerically the product-sum composition (a2·a1, a2·b1 + b2).
    """
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    x0 = x.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, x0), axis=1)
    return h
