"""Fused flash attention kernel (TPU target; the §Perf memory-term fix).

The dry-run roofline shows every train/prefill cell memory-bound on
attention-score traffic: the jnp flash implementation materializes each
(cq × ck) f32 score chunk to HBM between XLA fusions — O(T·S·H) bytes
per layer.  This kernel keeps the online-softmax state (m, l, acc) and
the score tile entirely in VMEM: HBM traffic drops to the information
minimum O(q + k + v + out), shifting those cells toward the compute
roofline (see EXPERIMENTS.md §Perf for the before/after).

Supports causal masking, sliding windows, and GQA (kv-head block mapped
as qh // group).  Layout: q (BH, T, hd); k/v (BKV, S, hd).

Validated against models/attention.py's jnp paths with interpret=True
(tests/test_kernels.py::test_flash_attention_*).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_body(bq, bk, hd, scale, causal, window, q_ref, k_ref, v_ref,
                o_ref, m_scr, l_scr, acc_scr):
    """q_ref: (1, bq, hd); k/v_ref: (1, bk, hd); o_ref: (1, bq, hd)."""
    kblk = pl.program_id(2)
    nk = pl.num_programs(2)
    qblk = pl.program_id(1)

    @pl.when(kblk == 0)
    def _init():
        m_scr[...] = jnp.full((bq, 1), NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros((bq, 1), jnp.float32)
        acc_scr[...] = jnp.zeros((bq, hd), jnp.float32)

    q = q_ref[0].astype(jnp.float32) * scale                 # (bq, hd)
    k = k_ref[0]                                             # (bk, hd)
    s = jax.lax.dot_general(q.astype(k.dtype), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    rows = qblk * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = kblk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # rows with no valid cols yet keep m = -inf; guard the exp
    corr = jnp.where(m_prev > NEG_INF / 2,
                     jnp.exp(m_prev - m_new), 0.0)
    e = jnp.where(ok, jnp.exp(s - m_new), 0.0)               # (bq, bk)
    l_scr[...] = l_scr[...] * corr + jnp.sum(e, axis=-1, keepdims=True)
    v = v_ref[0]
    pv = jax.lax.dot_general(e.astype(v.dtype), v,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new

    @pl.when(kblk == nk - 1)
    def _flush():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-37)
        out = jnp.where(l > 0, out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, T, H, hd); k/v: (B, S, KV, hd) -> (B, T, H, hd).

    Assumes contiguous positions 0..T-1 / 0..S-1 with T aligned to the
    *end* of S (self-attention train/prefill case: T == S).
    """
    b, t, h, hd = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(block_q, t)
    bk = min(block_k, s_len)
    assert t % bq == 0 and s_len % bk == 0, (t, bq, s_len, bk)
    scale = 1.0 / math.sqrt(hd)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s_len, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s_len, hd)

    grid = (b * h, t // bq, s_len // bk)
    out = pl.pallas_call(
        functools.partial(_flash_body, bq, bk, hd, scale, causal, window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j, g=g: (bh // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, i, j, g=g: (bh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
