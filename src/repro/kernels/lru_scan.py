"""RG-LRU linear-recurrence scan kernel (recurrentgemma substrate).

Griffin's RG-LRU layer is a diagonal linear recurrence
``h_t = a_t ⊙ h_{t-1} + x_t``.  On GPU the DeepMind implementation is a
custom (Pallas!) kernel because the op is memory-bound: naive scans
re-read the running state from HBM every step.  Here the state lives in
VMEM scratch across sequence blocks; each (batch, dim) tile streams the
sequence through VMEM exactly once — HBM traffic is the information-
theoretic minimum 2·B·T·D reads + B·T·D writes.

Grid: (B/bb, D/bd, T/bt) with T minor so the state scratch carries
across the sequence sweep for a fixed (batch, dim) tile.  Inside a block
the bt steps run as a fori_loop over VMEM rows (VPU elementwise).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_body(bb, bt, bd, a_ref, x_ref, h0_ref, out_ref, state):
    """a/x/out: (bb, bt, bd) VMEM;  h0: (bb, bd);  state: (bb, bd) scratch."""
    tblk = pl.program_id(2)

    @pl.when(tblk == 0)
    def _init():
        state[...] = h0_ref[...].astype(jnp.float32)

    def step(t, carry):
        h = carry
        h = a_ref[:, t, :].astype(jnp.float32) * h \
            + x_ref[:, t, :].astype(jnp.float32)
        out_ref[:, t, :] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bt, step, state[...])
    state[...] = h


def lru_scan_pallas(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray,
                    *,
                    block_b: Optional[int] = None,
                    block_t: Optional[int] = None,
                    block_d: Optional[int] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """h_t = a_t ⊙ h_{t-1} + x_t.   a, x: (B, T, D); h0: (B, D) -> (B, T, D).

    T must be divisible by block_t (pad upstream); B, D are padded here.
    """
    bsz, t, d = a.shape
    bb = block_b or min(8, bsz)
    bd = block_d or min(512, max(128, d))
    bt = block_t or min(256, t)
    if t % bt:
        raise ValueError(f"T={t} not divisible by block_t={bt}")

    pb, pd = -bsz % bb, -d % bd
    if pb or pd:
        a = jnp.pad(a, ((0, pb), (0, 0), (0, pd)), constant_values=0)
        x = jnp.pad(x, ((0, pb), (0, 0), (0, pd)), constant_values=0)
        h0 = jnp.pad(h0, ((0, pb), (0, pd)), constant_values=0)
    bp, dp = bsz + pb, d + pd

    grid = (bp // bb, dp // bd, t // bt)
    spec3 = pl.BlockSpec((bb, bt, bd), lambda i, j, k: (i, k, j))
    out = pl.pallas_call(
        functools.partial(_lru_body, bb, bt, bd),
        grid=grid,
        in_specs=[spec3, spec3,
                  pl.BlockSpec((bb, bd), lambda i, j, k: (i, j))],
        out_specs=spec3,
        out_shape=jax.ShapeDtypeStruct((bp, t, dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
    return out[:bsz, :, :d]
