"""Fused MACH decode kernel (Algorithm 2 on the MXU).

The paper computes the global score matrix ``G[n, k] = Σ_r P_r[n, h_r(k)]``
with an OpenCL gather kernel, materializes G (N×K), then argmaxes.  On
TPU random gathers are VPU-bound, so we recast decode as a blocked
matmul against a multi-hot matrix that is *built on the fly in VMEM*:

    G_tile = P_tile (bn, R·B)  @  M_tile (R·B, bk)
    M[(r·B + b), k] = 1[h_r(k) = b]

and we keep a *running* top-1 (value, index) accumulator in VMEM scratch
across K blocks — the N×K score matrix never exists in HBM.  HBM traffic
drops from O(N·K) to O(N·R·B + N) and the contraction (depth R·B) runs
on the MXU.

Two hash sources:
  * table mode   — the (R, K) int32 bucket table is tiled in (works for
                   any 2-universal family),
  * inline mode  — multiply-shift hashes are computed in-register from
                   the class index (paper §2.1's trick), removing the
                   table load from HBM entirely.  Requires B = 2^k.

Grid: (N/bn, K/bk), K minor (innermost) so the scratch accumulator for a
fixed N block sees all K blocks in order; the P tile's index map is
K-invariant so Pallas keeps it resident in VMEM across the K sweep.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _update_top1(scores, kbase, bn, run_val, run_idx, kblk, nk,
                 val_out, idx_out):
    """Shared running-top-1 logic.  scores: (bn, bk) f32."""
    @pl.when(kblk == 0)
    def _init():
        run_val[...] = jnp.full((bn, 1), NEG_INF, jnp.float32)
        run_idx[...] = jnp.zeros((bn, 1), jnp.int32)

    blk_val = jnp.max(scores, axis=-1, keepdims=True)                 # (bn, 1)
    blk_idx = jnp.argmax(scores, axis=-1, keepdims=True).astype(jnp.int32)
    # strict > keeps the first global argmax (jnp.argmax tie-breaking)
    better = blk_val > run_val[...]
    run_val[...] = jnp.where(better, blk_val, run_val[...])
    run_idx[...] = jnp.where(better, kbase + blk_idx, run_idx[...])

    @pl.when(kblk == nk - 1)
    def _flush():
        val_out[...] = run_val[...]
        idx_out[...] = run_idx[...]


def _decode_body_table(num_classes, bn, bk, r, b,
                       probs_ref, table_ref, val_out, idx_out,
                       run_val, run_idx):
    """Table mode.  probs_ref: (bn, R*B) VMEM;  table_ref: (R, bk) int32."""
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)
    kbase = kblk * bk

    # Multi-hot M (R, B, bk): M[r, b, k] = 1[table[r, k] == b]; flattened
    # r-major to (R·B, bk) so one MXU matmul covers all R repetitions.
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (r, b, bk), 1)
    m = (iota_b == table_ref[...][:, None, :]).astype(jnp.float32)
    scores = jnp.dot(probs_ref[...].astype(jnp.float32),
                     m.reshape(r * b, bk),
                     preferred_element_type=jnp.float32)              # (bn, bk)

    # Mask the K padding tail (global class id >= K).
    gidx = kbase + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
    scores = jnp.where(gidx < num_classes, scores, NEG_INF)
    _update_top1(scores, kbase, bn, run_val, run_idx, kblk, nk,
                 val_out, idx_out)


def _decode_body_inline(num_classes, bn, bk, r, b, shift,
                        probs_ref, coeff_ref, val_out, idx_out,
                        run_val, run_idx):
    """Inline multiply-shift mode — no hash table in HBM.

    coeff_ref: (R, 1) uint32 VMEM; bucket = (a_r · k mod 2^32) >> shift.
    """
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)
    kbase = kblk * bk

    kk = (kbase + jax.lax.broadcasted_iota(jnp.int32, (r, bk), 1)
          ).astype(jnp.uint32)
    a = coeff_ref[...]                                                # (R, 1)
    buckets = jax.lax.shift_right_logical(a * kk, jnp.uint32(shift)
                                          ).astype(jnp.int32)         # (R, bk)

    iota_b = jax.lax.broadcasted_iota(jnp.int32, (r, b, bk), 1)
    m = (iota_b == buckets[:, None, :]).astype(jnp.float32)
    scores = jnp.dot(probs_ref[...].astype(jnp.float32),
                     m.reshape(r * b, bk),
                     preferred_element_type=jnp.float32)

    gidx = kbase + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
    scores = jnp.where(gidx < num_classes, scores, NEG_INF)
    _update_top1(scores, kbase, bn, run_val, run_idx, kblk, nk,
                 val_out, idx_out)


def choose_decode_blocks(n: int, rb: int,
                         block_n: Optional[int] = None,
                         block_k: Optional[int] = None,
                         vmem_budget: int = 6 * 2**20) -> tuple[int, int]:
    """Pick (bn, bk): P tile (bn·RB·4 B) + M tile (RB·bk·4 B) within budget,
    bk a multiple of 128 (lane width) for MXU alignment."""
    bn = block_n or min(128, max(8, n))
    if block_k is None:
        bk = (vmem_budget // (4 * rb)) // 128 * 128
        bk = int(min(max(bk, 128), 2048))
    else:
        bk = block_k
    return bn, bk


def mach_decode_pallas(meta_probs: jnp.ndarray,
                       table: Optional[jnp.ndarray] = None,
                       *,
                       num_classes: int,
                       inline_coeffs: Optional[jnp.ndarray] = None,
                       inline_shift: Optional[int] = None,
                       block_n: Optional[int] = None,
                       block_k: Optional[int] = None,
                       interpret: bool = False
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused top-1 decode.  meta_probs (N, R, B) -> (val (N,), idx (N,)).

    Exactly one of ``table`` ((R, K) int32) or
    (``inline_coeffs`` ((R,) uint32), ``inline_shift``) must be given.
    """
    n, r, b = meta_probs.shape
    rb = r * b
    bn, bk = choose_decode_blocks(n, rb, block_n, block_k)
    n_pad = -n % bn
    k_grid = pl.cdiv(num_classes, bk)

    probs2d = meta_probs.reshape(n, rb)
    if n_pad:
        probs2d = jnp.pad(probs2d, ((0, n_pad), (0, 0)))
    npad = n + n_pad

    grid = (npad // bn, k_grid)
    out_shape = (jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                 jax.ShapeDtypeStruct((npad, 1), jnp.int32))
    scratch = [pltpu.VMEM((bn, 1), jnp.float32),
               pltpu.VMEM((bn, 1), jnp.int32)]
    out_specs = (pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
                 pl.BlockSpec((bn, 1), lambda i, j: (i, 0)))
    probs_spec = pl.BlockSpec((bn, rb), lambda i, j: (i, 0))

    if table is not None:
        k_pad = k_grid * bk - num_classes
        tab = jnp.pad(table, ((0, 0), (0, k_pad)), constant_values=b)
        body = functools.partial(_decode_body_table, num_classes, bn, bk, r, b)
        val, idx = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[probs_spec,
                      pl.BlockSpec((r, bk), lambda i, j: (0, j))],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(probs2d, tab)
    else:
        if inline_coeffs is None or inline_shift is None:
            raise ValueError("need table or (inline_coeffs, inline_shift)")
        if b & (b - 1):
            raise ValueError("inline mode requires power-of-two B")
        body = functools.partial(_decode_body_inline, num_classes, bn, bk,
                                 r, b, inline_shift)
        val, idx = pl.pallas_call(
            body,
            grid=grid,
            in_specs=[probs_spec,
                      pl.BlockSpec((r, 1), lambda i, j: (0, 0))],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(probs2d, inline_coeffs.reshape(r, 1))

    return val[:n, 0], idx[:n, 0]
