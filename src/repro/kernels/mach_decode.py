"""Fused MACH decode kernel (Algorithm 2 on the MXU).

The paper computes the global score matrix ``G[n, k] = Σ_r P_r[n, h_r(k)]``
with an OpenCL gather kernel, materializes G (N×K), then argmaxes.  On
TPU random gathers are VPU-bound, so we recast decode as a blocked
matmul against a multi-hot matrix that is *built on the fly in VMEM*:

    G_tile = P_tile (bn, R·B)  @  M_tile (R·B, bk)
    M[(r·B + b), k] = 1[h_r(k) = b]

and we keep a *running* top-1 (value, index) accumulator in VMEM scratch
across K blocks — the N×K score matrix never exists in HBM.  HBM traffic
drops from O(N·K) to O(N·R·B + N) and the contraction (depth R·B) runs
on the MXU.

Two hash sources:
  * table mode   — the (R, K) int32 bucket table is tiled in (works for
                   any 2-universal family),
  * inline mode  — multiply-shift hashes are computed in-register from
                   the class index (paper §2.1's trick), removing the
                   table load from HBM entirely.  Requires B = 2^k.

Grid: (N/bn, K/bk), K minor (innermost) so the scratch accumulator for a
fixed N block sees all K blocks in order; the P tile's index map is
K-invariant so Pallas keeps it resident in VMEM across the K sweep.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m >= x (shared by the MACH kernels' block
    and padding arithmetic)."""
    return -(-x // m) * m


def multihot_block(hash_ref, inline_shift, kbase, r, b, bk):
    """(R, B, bk) one-hot bucket matrix built on the fly in VMEM.

    M[r, b, k] = 1[h_r(kbase + k) = b], from either a tiled table slice
    (hash_ref (r, bk) int32; ``inline_shift`` None) or inline
    multiply-shift coefficients (hash_ref (r, 1) uint32).  Shared by the
    top-1 and streaming top-k decode kernels.
    """
    if inline_shift is None:
        buckets = hash_ref[...]                               # (r, bk)
    else:
        kk = (kbase + jax.lax.broadcasted_iota(jnp.int32, (r, bk), 1)
              ).astype(jnp.uint32)
        buckets = jax.lax.shift_right_logical(
            hash_ref[...] * kk, jnp.uint32(inline_shift)).astype(jnp.int32)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (r, b, bk), 1)
    return (iota_b == buckets[:, None, :]).astype(jnp.float32)


def mask_k_tail(scores, kbase, num_classes, bn, bk):
    """NEG_INF for the K padding tail (global class id >= K)."""
    gidx = kbase + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
    return jnp.where(gidx < num_classes, scores, NEG_INF)


def _update_top1(scores, kbase, bn, run_val, run_idx, kblk, nk,
                 val_out, idx_out):
    """Shared running-top-1 logic.  scores: (bn, bk) f32."""
    @pl.when(kblk == 0)
    def _init():
        run_val[...] = jnp.full((bn, 1), NEG_INF, jnp.float32)
        run_idx[...] = jnp.zeros((bn, 1), jnp.int32)

    blk_val = jnp.max(scores, axis=-1, keepdims=True)                 # (bn, 1)
    blk_idx = jnp.argmax(scores, axis=-1, keepdims=True).astype(jnp.int32)
    # strict > keeps the first global argmax (jnp.argmax tie-breaking)
    better = blk_val > run_val[...]
    run_val[...] = jnp.where(better, blk_val, run_val[...])
    run_idx[...] = jnp.where(better, kbase + blk_idx, run_idx[...])

    @pl.when(kblk == nk - 1)
    def _flush():
        val_out[...] = run_val[...]
        idx_out[...] = run_idx[...]


def _decode_body(num_classes, bn, bk, r, b, shift,
                 probs_ref, hash_ref, val_out, idx_out,
                 run_val, run_idx):
    """One (n-block, k-block) step.  probs_ref: (bn, R·B) VMEM;
    hash_ref: (R, bk) int32 table tile (``shift`` None) or (R, 1) uint32
    multiply-shift coefficients (bucket = (a_r · k mod 2^32) >> shift —
    no hash table in HBM)."""
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)
    kbase = kblk * bk

    # Multi-hot flattened r-major to (R·B, bk) so one MXU matmul covers
    # all R repetitions.
    m = multihot_block(hash_ref, shift, kbase, r, b, bk)
    scores = jnp.dot(probs_ref[...].astype(jnp.float32),
                     m.reshape(r * b, bk),
                     preferred_element_type=jnp.float32)              # (bn, bk)
    scores = mask_k_tail(scores, kbase, num_classes, bn, bk)
    _update_top1(scores, kbase, bn, run_val, run_idx, kblk, nk,
                 val_out, idx_out)


def decode_tile_bytes(bn: int, bk: int, rb: int, *, r: int = 0,
                      estimator: str = "unbiased", kcap: int = 0) -> int:
    """VMEM bytes one (bn, bk) decode tile needs, per estimator.

    Always: P tile (bn, R·B) f32 + on-the-fly multi-hot M (R·B, bk) f32.
    min/median additionally keep the per-repetition score cube
    (R, bn, bk) f32 alive until the reduce (one matmul per repetition
    instead of one over the flattened R·B axis).  ``kcap`` > 0 accounts
    for the streaming top-k merge state: running (val, idx) pairs of
    width kcap plus the sorted (bn, 2·kcap) concat temporaries.
    """
    nbytes = 4 * (bn * rb + rb * bk)
    if estimator in ("min", "median"):
        nbytes += 4 * r * bn * bk
    if kcap:
        nbytes += 4 * 2 * bn * (kcap + 2 * kcap)
    return nbytes


def choose_decode_blocks(n: int, rb: int,
                         block_n: Optional[int] = None,
                         block_k: Optional[int] = None,
                         vmem_budget: int = 6 * 2**20,
                         *, r: int = 0, estimator: str = "unbiased",
                         kcap: int = 0) -> tuple[int, int]:
    """Pick (bn, bk) so ``decode_tile_bytes`` fits in ``vmem_budget``,
    bk a multiple of 128 (lane width) for MXU alignment, first-fit
    descending from 2048.

    bn is rounded up to a multiple of 8 (the fp32 sublane tile) whatever
    the caller passes — an odd ``block_n`` would otherwise produce a
    padded N that bn does not tile cleanly on TPU.  The kernels pad N up
    to the returned bn, so any bn/bk combination stays correct.

    Raises ValueError when even the (bn, 128) floor tile overflows the
    budget — the caller should shrink bn/kcap or raise the budget
    explicitly rather than silently overflow VMEM (an explicit
    ``block_k`` skips the accounting entirely).
    """
    bn = block_n or min(128, max(8, n))
    bn = max(8, round_up(bn, 8))
    if block_k is not None:
        return bn, block_k
    bk = 2048
    while bk > 128 and decode_tile_bytes(
            bn, bk, rb, r=r, estimator=estimator, kcap=kcap) > vmem_budget:
        bk -= 128
    bk = max(bk, kcap and round_up(kcap, 128))
    if decode_tile_bytes(bn, bk, rb, r=r, estimator=estimator,
                         kcap=kcap) > vmem_budget:
        raise ValueError(
            f"decode tile does not fit: bn={bn} bk={bk} rb={rb} r={r} "
            f"estimator={estimator!r} kcap={kcap} needs "
            f"{decode_tile_bytes(bn, bk, rb, r=r, estimator=estimator, kcap=kcap)}"
            f" bytes > vmem_budget={vmem_budget}; pass block_k to override")
    return bn, bk


def prepare_decode_operands(meta_probs, table, num_classes, inline_coeffs,
                            inline_shift, bn, bk, k_grid):
    """Shared host-side setup for the top-1 and streaming top-k kernels.

    Validates the hash source, pads N up to bn and the table's K up to
    the grid (pad bucket = B: all-zero one-hot columns), and returns
    (probs2d (npad, R·B), npad, hash_arg, hash_spec, inline_shift) —
    ``inline_shift`` is None in table mode.
    """
    n, r, b = meta_probs.shape
    probs2d = meta_probs.reshape(n, r * b)
    n_pad = -n % bn
    if n_pad:
        probs2d = jnp.pad(probs2d, ((0, n_pad), (0, 0)))
    if table is not None:
        k_pad = k_grid * bk - num_classes
        hash_arg = jnp.pad(table, ((0, 0), (0, k_pad)), constant_values=b)
        hash_spec = pl.BlockSpec((r, bk), lambda i, j: (0, j))
        inline_shift = None
    else:
        if inline_coeffs is None or inline_shift is None:
            raise ValueError("need table or (inline_coeffs, inline_shift)")
        if b & (b - 1):
            raise ValueError("inline mode requires power-of-two B")
        hash_arg = inline_coeffs.reshape(r, 1)
        hash_spec = pl.BlockSpec((r, 1), lambda i, j: (0, 0))
    return probs2d, n + n_pad, hash_arg, hash_spec, inline_shift


def mach_decode_pallas(meta_probs: jnp.ndarray,
                       table: Optional[jnp.ndarray] = None,
                       *,
                       num_classes: int,
                       inline_coeffs: Optional[jnp.ndarray] = None,
                       inline_shift: Optional[int] = None,
                       block_n: Optional[int] = None,
                       block_k: Optional[int] = None,
                       interpret: bool = False
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused top-1 decode.  meta_probs (N, R, B) -> (val (N,), idx (N,)).

    Exactly one of ``table`` ((R, K) int32) or
    (``inline_coeffs`` ((R,) uint32), ``inline_shift``) must be given.
    """
    n, r, b = meta_probs.shape
    rb = r * b
    bn, bk = choose_decode_blocks(n, rb, block_n, block_k)
    k_grid = pl.cdiv(num_classes, bk)
    probs2d, npad, hash_arg, hash_spec, shift = prepare_decode_operands(
        meta_probs, table, num_classes, inline_coeffs, inline_shift, bn, bk,
        k_grid)

    val, idx = pl.pallas_call(
        functools.partial(_decode_body, num_classes, bn, bk, r, b, shift),
        grid=(npad // bn, k_grid),
        in_specs=[pl.BlockSpec((bn, rb), lambda i, j: (i, 0)), hash_spec],
        out_specs=(pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((bn, 1), lambda i, j: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                   jax.ShapeDtypeStruct((npad, 1), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((bn, 1), jnp.int32)],
        interpret=interpret,
    )(probs2d, hash_arg)

    return val[:n, 0], idx[:n, 0]
