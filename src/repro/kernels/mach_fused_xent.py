"""Fused projection + MACH cross-entropy (the logit-free training loss).

``mach_xent.py`` fuses the R-head cross-entropy *given* the logits — but
the trainer still materializes the full (N, R·B) logits tensor in HBM
via the head matmul, so train-time activation memory is O(N·R·B) and
the paper's O(d log K) story holds only for parameters.  This kernel
fuses the hidden→bucket projection into the loss itself.

Both the dense-h and the sparse-h (padded-ELL) families share one
d-blocked structure:

    forward grid (N/bn, C/bc, D/bd), C = R·B columns, d minor.  W
    streams through (bd, bc) VMEM tiles and h through (bn, bd) slices
    (for sparse h the slice is densified in VMEM from ELL cols/vals via
    a one-hot contraction); the logits tile accumulates across d blocks
    in (bn, bc) scratch.  At the last d block the optional bias (1, bc)
    is broadcast-added and the tile is reduced: an online per-head
    max / sum-exp (flash-attention-style, so heads may span several
    column blocks) and a gather-free label pick accumulate into (bn, R)
    scratch.  Neither the (N, R·B) logits tensor nor a full-d operand
    tile ever exists — per-step VMEM is O(bn·bd + bd·bc + bn·bc), so
    LM-scale d (mistral-large d=12288) fits the same budget as d=128.

Column blocks are head-aligned: when B fits the VMEM budget a block
covers ``nh`` whole heads (no online rescaling ever fires — each head's
logsumexp completes in its block); when B is larger than the budget a
block is a bucket-slice of a single head and the online update streams
the head's logsumexp across blocks.  Both cases run the same body.

The custom VJP recomputes each logits tile ONCE (the standard fused-CE
trade) from the saved per-head logsumexp:

    dlogits[n, rB+b] = g_n · (softmax(logits)[n, r, b] − 1[b = y_nr])

in a single kernel, grid (C/bc, N/bn, 2·D/bd): per (column, row) cell
the d axis is swept twice.  Phase 1 (k2 < nkd) rebuilds the logits tile
once — accumulating activation-slice @ W-tile products across d blocks
— and at its last step forms dlogits into (bn, bc) scratch, reducing
dbias into the revisited (1, bc) output row.  Phase 2 (k2 >= nkd)
revisits the d blocks: ``dW_blk += a_kᵀ @ dlogits`` accumulates through
the revisited (bd, bc) output window (initialized at the first row
block, read-modify-written on later revisits) and — dense h only —
``dh_k += dlogits @ W_kᵀ`` through a revisited (bn, bd) output block
(initialized at the first column block).  Activation residuals are the
inputs and the (N, R) logsumexp — O(N·d) dense / O(N·J) sparse,
independent of R·B.

Sparse features (the paper's ODP d=422k workload): the ``*_sparse``
entry points take the batch in padded-ELL form — ``cols/vals (N, J)``,
row n's features at ``cols[n, :]`` with weights ``vals[n, :]`` (padding
carries val 0) — as produced from CSR by ``ops.mach_fused_xent_csr``.
Per d block the active slice of the activation is densified *in VMEM*
via a one-hot contraction (``A[n, p] = Σ_j vals[n, j]·1[cols[n, j] =
d0+p]``, MXU/Mosaic-friendly, duplicate ids sum like a CSR scatter-add);
the dense (N, d) activation never exists in HBM.  ``vals`` is treated
as non-differentiable data (zero cotangent): features are inputs, not
parameters.

Scalar-prefetch gather (the high-nnz sparse path): the one-hot
densification pays O(bn·jp·bd) VMEM and compute per step, which makes
bag-of-words nnz >= 1k non-viable — ``choose_sparse_blocks`` runs out
of budget.  The ``*_gather`` family instead prefetches the ELL
cols/vals into SMEM (``PrefetchScalarGridSpec``, the pattern from
``mach_candidates.py``) and lets the W BlockSpec index map DMA the
cols[i, j]-th W row directly: forward grid (N, C/bc, jp), one example
row per grid step, the logits tile accumulating rank-1 updates
``v_ij · W[cols_ij, blk]`` in (1, bc) scratch.  Per-step VMEM is O(bc)
— independent of nnz AND of d, so any nnz fits the same budget.  The
backward, grid (N, C/bc, 2·jp), rebuilds the tile in phase 1 (forming
dlogits at its last step, reducing dbias into a zero-aliased revisited
(1, bc) row) and in phase 2 scatter-adds ``dW[cols_ij] += v_ij ·
dlogits`` through gather-indexed output blocks; both grad outputs are
``input_output_aliases``-pinned to zero-filled operands so unvisited W
rows stay zero and every visit is a pure accumulate (duplicate col ids
sum, matching the CSR scatter-add).  The densifying family remains the
low-nnz fast path and, via ``ref.mach_fused_xent_csr_ref``, the parity
oracle; ``ops.mach_fused_xent_csr`` picks between them (``sparse_impl``
knob, auto at ``GATHER_NNZ_THRESHOLD``).

Block choosing: ``choose_fused_blocks`` / ``choose_sparse_blocks``
enumerate candidate tilings in preference order (dense: keep bn large
first — it divides the dominant W stream — then bc, then bd; sparse:
keep bc large first — each column block pays a full densify d-sweep —
then bd, shrinking bn before bd as the one-hot tile grows) and return
the first whose accounted tile bytes (``dense_tile_bytes`` /
``sparse_tile_bytes`` — the superset of either pass's resident VMEM
tiles) fit ``vmem_budget``.  A ``ValueError`` is raised only when even
the minimum tiling (bn=8, bc=128, bd at its floor) overflows; explicit
``block_*`` overrides pin their dimension and the rest shrink around
them.

Padding: N pads to bn (padded rows get zero cotangent so contribute
nothing), d pads to a multiple of bd (zero h columns / zero W rows
contribute nothing; dh/dW slices drop them), heads pad to a multiple of
the per-block head count, buckets pad to a multiple of the block width;
padded columns are masked to NEG_INF before the reduction and zeroed in
the backward (so dbias's padded columns are zero too).  Sparse operands
additionally pad J to a lane multiple (padded slots carry val 0).

Interpret-mode caveat (see ROADMAP): the revisited accumulators rely on
output blocks being re-fetched on non-consecutive revisits.  Every grid
here is declared ``dimension_semantics=("arbitrary", ...)`` so Mosaic
must execute steps sequentially (no parallel reordering across the
revisited windows); interpret mode executes the re-fetch faithfully but
cannot vet the native pipelining — validate on real TPU before flipping
defaults.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mach_decode import NEG_INF, round_up

_LANE = 128

# Scratch logsumexp state and the revisited dh/dW/dbias output
# accumulators all require grid steps to run in order — declare every
# grid axis "arbitrary" (sequential) so Mosaic may not parallelize or
# reorder them.
_SEQUENTIAL3 = pltpu.TPUCompilerParams(
    dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))

DEFAULT_VMEM_BUDGET = 6 * 2**20


def _align_columns(bc_cap: int, r: int, b: int) -> tuple[int, int, int]:
    """Head-align a column-block budget: (bc, rp, bp).  Either whole
    heads per block (bc = nh·b, rp padded to a multiple of nh) or
    bucket-slices of one head (bc | bp, bp the padded per-head width)."""
    if b <= bc_cap:
        nh = max(1, min(bc_cap // b, r))
        return nh * b, round_up(r, nh), b
    return bc_cap, r, round_up(b, bc_cap)


def dense_tile_bytes(bn: int, bc: int, bd: int, rp: int) -> int:
    """Accounted VMEM bytes of the dense kernels' resident tiles (f32),
    the max over the forward and backward pass:

    fwd:  h (bn,bd) + W (bd,bc) + bias (1,bc) + y (bn,rp) + loss (bn,1)
          + lse (bn,rp) + acc scratch (bn,bc) + 3 stats (bn,rp)
    bwd:  h + W + bias + y + lse + g (bn,1) + dh (bn,bd) + dW (bd,bc)
          + dbias (1,bc) + acc/dlog scratch 2·(bn,bc)
    """
    fwd = bn * bd + bd * bc + bc + 5 * bn * rp + bn + bn * bc
    bwd = 2 * bn * bd + 2 * bd * bc + 2 * bc + 2 * bn * rp + 2 * bn \
        + 2 * bn * bc
    return 4 * max(fwd, bwd)


def sparse_tile_bytes(bn: int, bc: int, bd: int, rp: int, jp: int) -> int:
    """Accounted VMEM bytes of the sparse kernels' resident tiles (f32).
    The per-step densify holds ~two (bn, jp, bd) one-hot intermediates
    on top of the ELL tiles; otherwise as ``dense_tile_bytes`` minus the
    dense dh output."""
    densify = 2 * bn * jp * bd + 2 * bn * jp
    fwd = densify + bd * bc + bc + 5 * bn * rp + bn + bn * bc
    bwd = densify + 2 * bd * bc + 2 * bc + 2 * bn * rp + 2 * bn \
        + 2 * bn * bc
    return 4 * max(fwd, bwd)


def _candidates(override: Optional[int], pool: tuple[int, ...], pref: int,
                granule: int) -> list[int]:
    """Descending candidate sizes: the pinned override alone, or pref
    followed by every smaller pool entry."""
    if override is not None:
        return [max(granule, round_up(override, granule))]
    return [pref] + [x for x in pool if x < pref]


def choose_fused_blocks(n: int, d: int, r: int, b: int,
                        block_n: Optional[int] = None,
                        block_c: Optional[int] = None,
                        block_d: Optional[int] = None,
                        vmem_budget: int = DEFAULT_VMEM_BUDGET
                        ) -> tuple[int, int, int, int, int]:
    """Pick (bn, bc, bd, rp, bp): N block, column block, d block, padded
    head count, padded bucket count — the first candidate tiling whose
    ``dense_tile_bytes`` fit ``vmem_budget``.

    Preference order (first kept large): bn — the W stream is read
    N/bn times, the dominant HBM traffic at LM-scale d; then bc — h is
    re-fetched C/bc times; then bd, which only sets the pipelining
    granularity.  Default bd/bc are lane multiples (each is some tile's
    minor dim); d pads up to a bd multiple.  Explicit ``block_*``
    overrides are honored at sublane (8) granularity — sub-lane minor
    blocks are a test/bench knob for exercising the streaming paths on
    small shapes in interpret mode; pin lane multiples on real TPU
    (Mosaic requires minor block dims of 128·k or the full array dim).
    Raises ``ValueError`` when even the minimum tiling overflows the
    budget."""
    bn_cands = _candidates(block_n, (64, 32, 16, 8),
                           min(128, max(8, round_up(n, 8))), 8)
    bd_full = min(512, round_up(max(d, 1), _LANE))
    bd_cands = _candidates(block_d, (384, 256, 128), bd_full, 8)
    bc_caps = ([max(1, block_c)] if block_c is not None
               else [2048, 1024, 512, 256, 128])
    for bn in bn_cands:
        for bc_cap in bc_caps:
            bc, rp, bp = _align_columns(bc_cap, r, b)
            for bd in bd_cands:
                if dense_tile_bytes(bn, bc, bd, rp) <= vmem_budget:
                    return bn, bc, bd, rp, bp
    bc_min, rp_min, _ = _align_columns(bc_caps[-1], r, b)
    raise ValueError(
        f"no dense fused-xent tiling fits vmem_budget={vmem_budget}: "
        f"minimum candidate (bn={bn_cands[-1]}, bc={bc_min}, "
        f"bd={bd_cands[-1]}) needs "
        f"{dense_tile_bytes(bn_cands[-1], bc_min, bd_cands[-1], rp_min)} "
        f"bytes (n={n}, d={d}, r={r}, b={b})")


def choose_sparse_blocks(n: int, d: int, r: int, b: int, j: int,
                         block_n: Optional[int] = None,
                         block_c: Optional[int] = None,
                         block_d: Optional[int] = None,
                         vmem_budget: int = DEFAULT_VMEM_BUDGET
                         ) -> tuple[int, int, int, int, int, int]:
    """Pick (bn, bc, bd, rp, bp, jp) for the sparse kernels — the first
    candidate tiling whose ``sparse_tile_bytes`` fit ``vmem_budget``.

    The densified (bn, jp, bd) one-hot tile is the VMEM driver.
    Preference order: bc first (every column block pays a full densify
    d-sweep, so fewer blocks = less recompute); then bd, with bn
    shrinking before bd drops (bn is capped at 16 anyway — sublane
    granularity, not W traffic, is the constraint); bd may fall below a
    lane block to the 8-sublane floor at bag-of-words nnz (bd is only
    ever a sublane dim here — the W tile's minor dim is bc).  A
    sub-lane ``block_c`` override is an interpret-mode test knob, as in
    ``choose_fused_blocks``.  Raises ``ValueError`` when even the
    minimum tiling overflows."""
    jp = round_up(max(j, 1), _LANE)
    bn_cands = _candidates(block_n, (8,),
                           min(16, max(8, round_up(n, 8))), 8)
    bd_full = min(512, round_up(max(d, 1), 8))
    bd_cands = _candidates(block_d, (256, 128, 64, 32, 16, 8), bd_full, 8)
    bc_caps = ([max(1, block_c)] if block_c is not None
               else [2048, 1024, 512, 256, 128])
    for bc_cap in bc_caps:
        bc, rp, bp = _align_columns(bc_cap, r, b)
        for bd in bd_cands:
            for bn in bn_cands:
                if sparse_tile_bytes(bn, bc, bd, rp, jp) <= vmem_budget:
                    return bn, bc, bd, rp, bp, jp
    raise ValueError(
        f"no sparse fused-xent tiling fits vmem_budget={vmem_budget} "
        f"(n={n}, d={d}, r={r}, b={b}, nnz_max={j} -> jp={jp})")


# nnz at/above which ops.mach_fused_xent_csr auto-routes to the gather
# family: the densify tile's 2·bn·jp·bd term crosses the default budget
# around here, and the gather path's per-step cost (one (1, bc) FMA per
# slot) beats the one-hot contraction well before that.
GATHER_NNZ_THRESHOLD = 512


def gather_tile_bytes(bc: int, rp: int) -> int:
    """Accounted VMEM bytes of the gather kernels' resident tiles (f32),
    the max over the forward and backward pass.  One example row per
    grid step; W streams as a double-buffered (1, bc) row gather — no
    (bn, jp, bd) one-hot tile and no (bd, bc) W tile, so the per-step
    VMEM driver collapses from O(bn·jp·bd) to O(bc), independent of
    both nnz and d:

    fwd:  W row 2·(1,bc) + bias (1,bc) + acc (1,bc) + y (1,rp) + loss
          (1,1) + lse (1,rp) + 3 stats (1,rp)
    bwd:  W row + dW row 2·2·(1,bc) + dbias (1,bc) + bias (1,bc) +
          acc/dlog scratch 2·(1,bc) + y/lse 2·(1,rp) + g (1,1)

    The ELL cols/vals are scalar-prefetch operands and live in SMEM
    (2·4·N·jp bytes), not VMEM — callers account them separately."""
    fwd = 2 * bc + bc + bc + 5 * rp + 1
    bwd = 4 * bc + bc + bc + 2 * bc + 2 * rp + 1
    return 4 * max(fwd, bwd)


def choose_gather_blocks(n: int, d: int, r: int, b: int, j: int,
                         block_c: Optional[int] = None,
                         vmem_budget: int = DEFAULT_VMEM_BUDGET
                         ) -> tuple[int, int, int, int]:
    """Pick (bc, rp, bp, jp) for the gather kernels — the first
    head-aligned column-block candidate whose ``gather_tile_bytes`` fit
    ``vmem_budget``.  nnz never enters the accounting (the ELL operands
    are SMEM scalars; W streams one row at a time), so bag-of-words
    nnz >= 1k fits the same budget as nnz = 8; ``jp`` is only the
    padded grid extent of the nnz axis."""
    jp = max(j, 1)
    bc_caps = ([max(1, block_c)] if block_c is not None
               else [2048, 1024, 512, 256, 128])
    for bc_cap in bc_caps:
        bc, rp, bp = _align_columns(bc_cap, r, b)
        if gather_tile_bytes(bc, rp) <= vmem_budget:
            return bc, rp, bp, jp
    bc, rp, bp = _align_columns(bc_caps[-1], r, b)
    raise ValueError(
        f"no gather fused-xent tiling fits vmem_budget={vmem_budget}: "
        f"minimum candidate bc={bc} needs {gather_tile_bytes(bc, rp)} "
        f"bytes (n={n}, d={d}, r={r}, b={b}, nnz_max={j})")


def _pad_bias(bias, r, b, rp, bp):
    """bias (R·B,) or None -> (1, rp·bp) f32 (zeros when absent — the
    kernels take the operand unconditionally; the add is free)."""
    if bias is None:
        return jnp.zeros((1, rp * bp), jnp.float32)
    b2 = jnp.pad(bias.astype(jnp.float32).reshape(r, b),
                 ((0, rp - r), (0, bp - b)))
    return b2.reshape(1, rp * bp)


def _pad_operands(h2, w, bias, labels, r, b, bn, rp, bp, bd):
    """(h (N,d), w (d,R·B), bias (R·B,)|None, y (N,R)) -> padded
    (h (Np,dp), w (dp,rp·bp), bias (1,rp·bp), y (Np,rp) int32, dp).
    W pads with zero heads/buckets/rows (masked or inert in-kernel),
    labels pad with bucket 0 (their heads are masked)."""
    n, d = h2.shape
    dp = round_up(d, bd)
    npad = -n % bn
    if npad or dp != d:
        h2 = jnp.pad(h2, ((0, npad), (0, dp - d)))
    if npad:
        labels = jnp.pad(labels, ((0, npad), (0, 0)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, 0), (0, rp - r)))
    w3 = w.reshape(d, r, b)
    w3 = jnp.pad(w3, ((0, dp - d), (0, rp - r), (0, bp - b)))
    return h2, w3.reshape(dp, rp * bp), _pad_bias(bias, r, b, rp, bp), \
        labels, dp


def _pad_sparse_operands(cols, vals, w, bias, labels, r, b, bn, rp, bp,
                         bd, jp):
    """ELL (cols/vals (N,J)), w (d,R·B), bias, y (N,R) -> padded
    (cols/vals (Np,jp), w (dp,rp·bp), bias (1,rp·bp), y (Np,rp), dp).
    Padded slots carry val 0 so they contribute nothing regardless of
    their col id."""
    n, j = cols.shape
    d = w.shape[0]
    dp = round_up(d, bd)
    npad = -n % bn
    cols = jnp.pad(cols.astype(jnp.int32), ((0, npad), (0, jp - j)))
    vals = jnp.pad(vals, ((0, npad), (0, jp - j)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, npad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, rp - r)))
    w3 = w.reshape(d, r, b)
    w3 = jnp.pad(w3, ((0, dp - d), (0, rp - r), (0, bp - b)))
    return cols, vals, w3.reshape(dp, rp * bp), \
        _pad_bias(bias, r, b, rp, bp), labels, dp


def _pad_gather_operands(cols, vals, w, bias, labels, r, b, rp, bp, jp):
    """ELL (cols/vals (N, J)), w (d, R·B), bias, y (N, R) -> scalar-
    prefetch operands (cols (N, jp) int32 clamped to [0, d-1], vals
    (N, jp) f32) + padded (w (d, rp·bp), bias (1, rp·bp), y (N, rp)).
    No d or N padding: the gather reads whole W rows one at a time and
    the grid runs one step per example row.  Out-of-range col ids (the
    CSR sentinel ``d``) clamp to d-1 — their val is 0, so the gathered
    row contributes nothing; clamping keeps every prefetched index a
    valid W block id."""
    n, j = cols.shape
    d = w.shape[0]
    cols = jnp.clip(cols.astype(jnp.int32), 0, d - 1)
    cols = jnp.pad(cols, ((0, 0), (0, jp - j)))
    vals = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, jp - j)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, 0), (0, rp - r)))
    w3 = jnp.pad(w.reshape(d, r, b), ((0, 0), (0, rp - r), (0, bp - b)))
    return cols, vals, w3.reshape(d, rp * bp), \
        _pad_bias(bias, r, b, rp, bp), labels


def _tile_geometry(bc, bp, kblk):
    """Static (nh, width) + traced (h0, boff) for the current column
    block.  nh heads of ``width`` buckets each; h0 the first head id,
    boff the bucket offset inside it (0 unless a head spans blocks)."""
    nh = max(1, bc // bp)
    width = bp if bc >= bp else bc
    kbase = kblk * bc
    h0 = kbase // bp
    boff = kbase - h0 * bp
    return nh, width, h0, boff


def _mask_tile3(tile, bn, nh, width, boff, b):
    """(bn, nh·width) f32 logits tile -> ((bn, nh, width) with padded
    buckets at NEG_INF, per-position bucket ids)."""
    tile3 = tile.reshape(bn, nh, width)
    bidx = boff + jax.lax.broadcasted_iota(jnp.int32, (bn, nh, width), 2)
    return jnp.where(bidx < b, tile3, NEG_INF), bidx


def _finalize_tile(acc, bias_ref, bn, nh, width, boff, b):
    """d-accumulated logits tile + broadcast bias row -> masked (bn,
    nh, width) tile and bucket ids (the bias lands once, at the last d
    block, where this is called)."""
    tile = acc + bias_ref[...].astype(jnp.float32)      # (bn,bc)+(1,bc)
    return _mask_tile3(tile, bn, nh, width, boff, b)


def _densify_tile(cols_ref, vals_ref, d0, bn, jp, bd):
    """In-VMEM densified activation slice A (bn, bd) for feature range
    [d0, d0+bd): A[n, p] = Σ_j vals[n, j]·1[cols[n, j] = d0+p].  A
    one-hot contraction (no gather — Mosaic-friendly); duplicate ids
    within a row sum, matching a CSR scatter-add; padded slots carry
    val 0 so their col id is irrelevant."""
    local = cols_ref[...].astype(jnp.int32) - d0                # (bn, jp)
    oh = (local[:, :, None] ==
          jax.lax.broadcasted_iota(jnp.int32, (bn, jp, bd), 2))
    weighted = oh.astype(jnp.float32) \
        * vals_ref[...].astype(jnp.float32)[:, :, None]
    return jnp.sum(weighted, axis=1)                            # (bn, bd)


def _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh):
    """Online per-head (max, sumexp, picked) accumulation on the nh
    heads this column block touches."""
    y_blk = y_ref[:, pl.ds(h0, nh)]                           # (bn, nh)
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    picked = jnp.sum(tile3 * onehot, axis=2)                  # (bn, nh)
    m_old = m_scr[:, pl.ds(h0, nh)]
    s_old = s_scr[:, pl.ds(h0, nh)]
    m_new = jnp.maximum(m_old, jnp.max(tile3, axis=2))
    s_new = s_old * jnp.exp(m_old - m_new) \
        + jnp.sum(jnp.exp(tile3 - m_new[:, :, None]), axis=2)
    m_scr[:, pl.ds(h0, nh)] = m_new
    s_scr[:, pl.ds(h0, nh)] = s_new
    p_scr[:, pl.ds(h0, nh)] = p_scr[:, pl.ds(h0, nh)] + picked


def _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr):
    """Final reduction: per-head logsumexp -> summed CE + saved lse."""
    lse = m_scr[...] + jnp.log(s_scr[...])                    # (bn, rp)
    head_ok = jax.lax.broadcasted_iota(jnp.int32, lse.shape, 1) < r
    loss_ref[...] = jnp.sum(
        jnp.where(head_ok, lse - p_scr[...], 0.0),
        axis=1, keepdims=True)
    lse_ref[...] = jnp.where(head_ok, lse, 0.0)


def _dlogits_from_tile(tile3, bidx, y_ref, lse_ref, g_ref, r, b, h0, nh,
                       width):
    """g·(softmax − onehot) from a masked logits tile, zeroed at padded
    heads/buckets.  Returns (bn, nh·width) f32."""
    bn = tile3.shape[0]
    y_blk = y_ref[:, pl.ds(h0, nh)]
    lse_blk = lse_ref[:, pl.ds(h0, nh)]                       # (bn, nh)
    p = jnp.exp(tile3 - lse_blk[:, :, None])                  # softmax
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    head_ok = (h0 + jax.lax.broadcasted_iota(
        jnp.int32, (bn, nh, width), 1)) < r
    dtile3 = jnp.where((bidx < b) & head_ok,
                       g_ref[...][:, :, None] * (p - onehot), 0.0)
    return dtile3.reshape(bn, nh * width)


# ---------------------------------------------------------------------------
# Shared d-blocked kernel steps (dense and sparse differ only in how
# the (bn, bd) activation slice ``a`` is produced: a block load vs an
# in-VMEM ELL densification).
# ---------------------------------------------------------------------------

def _dblocked_fwd_step(a, bn, bc, r, rp, b, bp,
                       w_ref, bias_ref, y_ref, loss_ref, lse_ref,
                       acc_scr, m_scr, s_scr, p_scr):
    """Forward step;  grid (N/bn, C/bc, D/bd), d minor.  The logits
    tile accumulates over d blocks in (bn, bc) scratch; the bias add
    and the online reduction fire once per column block at the last d
    block."""
    jblk = pl.program_id(1)
    kd = pl.program_id(2)
    njb = pl.num_programs(1)
    nkd = pl.num_programs(2)

    @pl.when((jblk == 0) & (kd == 0))
    def _init_stats():
        m_scr[...] = jnp.full((bn, rp), NEG_INF, jnp.float32)
        s_scr[...] = jnp.zeros((bn, rp), jnp.float32)
        p_scr[...] = jnp.zeros((bn, rp), jnp.float32)

    @pl.when(kd == 0)
    def _init_acc():
        acc_scr[...] = jnp.zeros((bn, bc), jnp.float32)

    acc_scr[...] += jnp.dot(a, w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kd == nkd - 1)
    def _reduce():
        nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
        tile3, bidx = _finalize_tile(acc_scr[...], bias_ref, bn, nh,
                                     width, boff, b)
        _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh)

        @pl.when(jblk == njb - 1)
        def _flush():
            _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr)


def _dblocked_bwd_step(a, nkd, bn, bc, r, rp, b, bp,
                       w_ref, bias_ref, y_ref, lse_ref, g_ref,
                       dw_ref, db_ref, acc_scr, dlog_scr, dh_ref=None):
    """Single-recompute backward step;  grid (C/bc, N/bn, 2·D/bd).

    ``a`` is the activation slice for d block ``k2 mod nkd`` (the
    callers' index maps / densify offsets already fold the two-phase
    k2 -> d-block mapping).  Phase 1 (k2 < nkd) rebuilds the logits
    tile once, then at its last step forms dlogits into scratch and
    reduces dbias into the revisited (1, bc) output row; phase 2
    scatter-adds dW_blk += aᵀ @ dlogits through the revisited (bd, bc)
    output window (initialized at the first row block,
    read-modify-written on later revisits — phase-1 steps map the same
    block but leave it untouched) and, when ``dh_ref`` is given (dense
    h), dh_blk += dlogits @ Wᵀ through the revisited (bn, bd) output
    block (initialized at the first column block)."""
    jblk = pl.program_id(0)
    iblk = pl.program_id(1)
    k2 = pl.program_id(2)

    @pl.when(k2 < nkd)
    def _logits_phase():
        @pl.when(k2 == 0)
        def _init():
            acc_scr[...] = jnp.zeros((bn, bc), jnp.float32)

        acc_scr[...] += jnp.dot(a, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

        @pl.when(k2 == nkd - 1)
        def _dlog():
            nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
            tile3, bidx = _finalize_tile(acc_scr[...], bias_ref, bn, nh,
                                         width, boff, b)
            dlog_scr[...] = _dlogits_from_tile(
                tile3, bidx, y_ref, lse_ref, g_ref, r, b, h0, nh, width)
            db_contrib = jnp.sum(dlog_scr[...], axis=0, keepdims=True)

            @pl.when(iblk == 0)
            def _db_first():
                db_ref[...] = db_contrib

            @pl.when(iblk > 0)
            def _db_acc():
                db_ref[...] += db_contrib

    @pl.when(k2 >= nkd)
    def _grad_phase():
        dlog = dlog_scr[...]
        dw_contrib = jax.lax.dot_general(
            a, dlog,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bd, bc)

        @pl.when(iblk == 0)
        def _dw_first():
            dw_ref[...] = dw_contrib

        @pl.when(iblk > 0)
        def _dw_acc():
            dw_ref[...] += dw_contrib

        if dh_ref is not None:
            dh_contrib = jax.lax.dot_general(
                dlog, w_ref[...].astype(jnp.float32),
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)           # (bn, bd)

            @pl.when(jblk == 0)
            def _dh_first():
                dh_ref[...] = dh_contrib

            @pl.when(jblk > 0)
            def _dh_acc():
                dh_ref[...] += dh_contrib


# ---------------------------------------------------------------------------
# Dense-h kernel bodies
# ---------------------------------------------------------------------------

def _fwd_body(bn, bc, r, rp, b, bp,
              h_ref, w_ref, bias_ref, y_ref, loss_ref, lse_ref,
              acc_scr, m_scr, s_scr, p_scr):
    """h_ref (bn, bd); w_ref (bd, bc); bias_ref (1, bc); y_ref (bn, rp);
    scratch acc (bn, bc) + stats (bn, rp)."""
    _dblocked_fwd_step(h_ref[...].astype(jnp.float32), bn, bc, r, rp, b,
                       bp, w_ref, bias_ref, y_ref, loss_ref, lse_ref,
                       acc_scr, m_scr, s_scr, p_scr)


def _bwd_body(bn, bc, nkd, r, rp, b, bp,
              h_ref, w_ref, bias_ref, y_ref, lse_ref, g_ref,
              dh_ref, dw_ref, db_ref, acc_scr, dlog_scr):
    """The h/W/dh/dW index maps fold k2 -> k2 mod nkd, so ``h_ref`` is
    the right (bn, bd) slice in both phases."""
    _dblocked_bwd_step(h_ref[...].astype(jnp.float32), nkd, bn, bc, r,
                       rp, b, bp, w_ref, bias_ref, y_ref, lse_ref, g_ref,
                       dw_ref, db_ref, acc_scr, dlog_scr, dh_ref=dh_ref)


# ---------------------------------------------------------------------------
# Sparse-h (padded-ELL) kernel bodies
# ---------------------------------------------------------------------------

def _sparse_fwd_body(bn, bc, bd, r, rp, b, bp, jp,
                     cols_ref, vals_ref, w_ref, bias_ref, y_ref,
                     loss_ref, lse_ref, acc_scr, m_scr, s_scr, p_scr):
    a = _densify_tile(cols_ref, vals_ref, pl.program_id(2) * bd, bn, jp,
                      bd)
    _dblocked_fwd_step(a, bn, bc, r, rp, b, bp, w_ref, bias_ref, y_ref,
                       loss_ref, lse_ref, acc_scr, m_scr, s_scr, p_scr)


def _sparse_bwd_body(bn, bc, bd, nkd, r, rp, b, bp, jp,
                     cols_ref, vals_ref, w_ref, bias_ref, y_ref, lse_ref,
                     g_ref, dw_ref, db_ref, acc_scr, dlog_scr):
    """No dh: ``vals`` is data (zero cotangent).  The densify offset
    folds the two-phase k2 -> d-block mapping itself."""
    k2 = pl.program_id(2)
    kd = jnp.where(k2 >= nkd, k2 - nkd, k2)
    a = _densify_tile(cols_ref, vals_ref, kd * bd, bn, jp, bd)
    _dblocked_bwd_step(a, nkd, bn, bc, r, rp, b, bp, w_ref, bias_ref,
                       y_ref, lse_ref, g_ref, dw_ref, db_ref, acc_scr,
                       dlog_scr)


# ---------------------------------------------------------------------------
# Scalar-prefetch gather kernel bodies (high-nnz sparse path: no
# densification — W rows are DMA'd by ELL column id via the
# scalar-prefetched index maps in _gather_call).
# ---------------------------------------------------------------------------

def _gather_fwd_body(r, rp, b, bp, bc,
                     cols_sref, vals_sref, w_ref, bias_ref, y_ref,
                     loss_ref, lse_ref, acc_scr, m_scr, s_scr, p_scr):
    """Grid (N, C/bc, jp), nnz minor; one example row per step.  w_ref
    is the (1, bc) slice of the cols[i, jj]-th W row (gathered by the
    BlockSpec index map); the logits tile accumulates rank-1 updates
    ``v·w_row`` across the jp axis in (1, bc) scratch — padded slots
    carry val 0 so their (clamped) col id is irrelevant."""
    i = pl.program_id(0)
    jblk = pl.program_id(1)
    jj = pl.program_id(2)
    njb = pl.num_programs(1)
    nj = pl.num_programs(2)

    @pl.when((jblk == 0) & (jj == 0))
    def _init_stats():
        m_scr[...] = jnp.full((1, rp), NEG_INF, jnp.float32)
        s_scr[...] = jnp.zeros((1, rp), jnp.float32)
        p_scr[...] = jnp.zeros((1, rp), jnp.float32)

    @pl.when(jj == 0)
    def _init_acc():
        acc_scr[...] = jnp.zeros((1, bc), jnp.float32)

    acc_scr[...] += vals_sref[i, jj] * w_ref[...].astype(jnp.float32)

    @pl.when(jj == nj - 1)
    def _reduce():
        nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
        tile3, bidx = _finalize_tile(acc_scr[...], bias_ref, 1, nh,
                                     width, boff, b)
        _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh)

        @pl.when(jblk == njb - 1)
        def _flush():
            _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr)


def _gather_bwd_body(r, rp, b, bp, bc,
                     cols_sref, vals_sref, w_ref, bias_ref, y_ref,
                     lse_ref, g_ref, dwz_ref, dbz_ref, dw_ref, db_ref,
                     acc_scr, dlog_scr):
    """Grid (N, C/bc, 2·jp).  Phase 1 (k2 < jp) rebuilds the logits
    tile from the gathered rows once; at its last step it forms dlogits
    into (1, bc) scratch and accumulates dbias into the revisited
    (1, bc) output row.  Phase 2 scatter-adds ``dW_row += v·dlogits``
    through the gather-indexed (1, bc) output block — the same
    cols[i, ·]-th row the forward read.  Both grad outputs are
    ``input_output_aliases``-pinned to zero-filled operands
    (``dwz_ref``/``dbz_ref``, never read in-kernel), so unvisited W
    rows stay zero and every visit — duplicate col ids included — is a
    pure accumulate; phase-1 steps map the same dW blocks but leave
    them untouched."""
    del dwz_ref, dbz_ref
    i = pl.program_id(0)
    jblk = pl.program_id(1)
    k2 = pl.program_id(2)
    nj = pl.num_programs(2) // 2

    @pl.when(k2 < nj)
    def _logits_phase():
        @pl.when(k2 == 0)
        def _init():
            acc_scr[...] = jnp.zeros((1, bc), jnp.float32)

        acc_scr[...] += vals_sref[i, k2] * w_ref[...].astype(jnp.float32)

        @pl.when(k2 == nj - 1)
        def _dlog():
            nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
            tile3, bidx = _finalize_tile(acc_scr[...], bias_ref, 1, nh,
                                         width, boff, b)
            dlog_scr[...] = _dlogits_from_tile(
                tile3, bidx, y_ref, lse_ref, g_ref, r, b, h0, nh, width)
            db_ref[...] += dlog_scr[...]

    @pl.when(k2 >= nj)
    def _grad_phase():
        dw_ref[...] += vals_sref[i, k2 - nj] * dlog_scr[...]


# ---------------------------------------------------------------------------
# Dense-h entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def mach_fused_xent_pallas(h2: jnp.ndarray, w: jnp.ndarray,
                           bias: Optional[jnp.ndarray],
                           hashed_labels: jnp.ndarray,
                           num_buckets: int,
                           block_n: Optional[int] = None,
                           block_c: Optional[int] = None,
                           block_d: Optional[int] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE, straight from hidden states.

    h2 (N, d); w (d, R·B); bias (R·B,) or None (broadcast-added to the
    logits tile in-kernel); hashed_labels (N, R) int32 -> (N,) f32.
    Differentiable: the VJP yields (dh, dW, dbias) without ever forming
    the (N, R·B) logits tensor or a full-d operand tile."""
    out, _ = _fused_fwd(h2, w, bias, hashed_labels, num_buckets, block_n,
                        block_c, block_d, interpret)
    return out


def _fused_call(kind, h2p, wp, biasp, yp, lsep, gp, dims, bn, bc, bd,
                interpret):
    """Shared pallas_call builder for the dense forward/backward."""
    npad, dp, r, rp, b, bp, c = dims
    nkd = dp // bd
    if kind == "fwd":
        h_spec = pl.BlockSpec((bn, bd), lambda i, j, k: (i, k))
        w_spec = pl.BlockSpec((bd, bc), lambda i, j, k: (k, j))
        b_spec = pl.BlockSpec((1, bc), lambda i, j, k: (0, j))
        row_spec = lambda width: pl.BlockSpec((bn, width),
                                              lambda i, j, k: (i, 0))
        return pl.pallas_call(
            functools.partial(_fwd_body, bn, bc, r, rp, b, bp),
            grid=(npad // bn, c // bc, nkd),
            in_specs=[h_spec, w_spec, b_spec, row_spec(rp)],
            out_specs=(row_spec(1), row_spec(rp)),
            out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                       jax.ShapeDtypeStruct((npad, rp), jnp.float32)),
            scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)]
            + [pltpu.VMEM((bn, rp), jnp.float32)] * 3,
            compiler_params=_SEQUENTIAL3,
            interpret=interpret,
        )(h2p, wp, biasp, yp)
    # bwd: column blocks outer, 2·D/bd minor; both phases of a (j, i)
    # cell map the same h/W/dh/dW d-block
    kmap = lambda k2: jnp.where(k2 >= nkd, k2 - nkd, k2)
    h_spec = pl.BlockSpec((bn, bd), lambda j, i, k2: (i, kmap(k2)))
    w_spec = pl.BlockSpec((bd, bc), lambda j, i, k2: (kmap(k2), j))
    b_spec = pl.BlockSpec((1, bc), lambda j, i, k2: (0, j))
    row_spec = lambda width: pl.BlockSpec((bn, width),
                                          lambda j, i, k2: (i, 0))
    return pl.pallas_call(
        functools.partial(_bwd_body, bn, bc, nkd, r, rp, b, bp),
        grid=(c // bc, npad // bn, 2 * nkd),
        in_specs=[h_spec, w_spec, b_spec, row_spec(rp), row_spec(rp),
                  row_spec(1)],
        out_specs=(h_spec, w_spec, b_spec),
        out_shape=(jax.ShapeDtypeStruct((npad, dp), jnp.float32),
                   jax.ShapeDtypeStruct((dp, c), jnp.float32),
                   jax.ShapeDtypeStruct((1, c), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)] * 2,
        compiler_params=_SEQUENTIAL3,
        interpret=interpret,
    )(h2p, wp, biasp, yp, lsep, gp)


def _check_shapes(h2, w, bias, hashed_labels, num_buckets):
    n, d = h2.shape
    r = hashed_labels.shape[-1]
    if hashed_labels.shape != (n, r):
        raise ValueError(f"labels {hashed_labels.shape} vs h {h2.shape}")
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    if bias is not None and bias.shape != (r * num_buckets,):
        raise ValueError(f"bias {bias.shape} != ({r}*{num_buckets},)")
    return n, d, r


def _fused_fwd(h2, w, bias, hashed_labels, num_buckets, block_n, block_c,
               block_d, interpret):
    n, d, r = _check_shapes(h2, w, bias, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c,
                                             block_d)
    h2p, wp, biasp, yp, dp = _pad_operands(h2, w, bias, hashed_labels, r,
                                           b, bn, rp, bp, bd)
    dims = (h2p.shape[0], dp, r, rp, b, bp, rp * bp)
    loss, lse = _fused_call("fwd", h2p, wp, biasp, yp, None, None, dims,
                            bn, bc, bd, interpret)
    return loss[:n, 0], (h2, w, bias, hashed_labels, lse[:n])


def _fused_bwd(num_buckets, block_n, block_c, block_d, interpret, res, g):
    h2, w, bias, hashed_labels, lse = res
    n, d, r = _check_shapes(h2, w, bias, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c,
                                             block_d)
    h2p, wp, biasp, yp, dp = _pad_operands(h2, w, bias, hashed_labels, r,
                                           b, bn, rp, bp, bd)
    npad = h2p.shape[0]
    dims = (npad, dp, r, rp, b, bp, rp * bp)
    # padded rows/heads carry zero cotangent -> zero dlogits
    gp = jnp.pad(g.astype(jnp.float32).reshape(n, 1),
                 ((0, npad - n), (0, 0)))
    lsep = jnp.pad(lse, ((0, npad - n), (0, 0)))
    dhp, dwp, dbp = _fused_call("bwd", h2p, wp, biasp, yp, lsep, gp,
                                dims, bn, bc, bd, interpret)
    dh = dhp[:n, :d].astype(h2.dtype)
    dw = dwp.reshape(dp, rp, bp)[:d, :r, :b].reshape(d, r * b) \
        .astype(w.dtype)
    if bias is None:
        return dh, dw, None, None
    db = dbp.reshape(rp, bp)[:r, :b].reshape(r * b).astype(bias.dtype)
    return dh, dw, db, None


mach_fused_xent_pallas.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# Sparse-h (padded-ELL) entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def mach_fused_xent_sparse_pallas(cols: jnp.ndarray, vals: jnp.ndarray,
                                  w: jnp.ndarray,
                                  bias: Optional[jnp.ndarray],
                                  hashed_labels: jnp.ndarray,
                                  num_buckets: int,
                                  block_n: Optional[int] = None,
                                  block_c: Optional[int] = None,
                                  block_d: Optional[int] = None,
                                  interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE from a padded-ELL sparse batch.

    cols/vals (N, J) — row n's active feature ids and weights (padding
    carries val 0; duplicate ids sum); w (d, R·B); bias (R·B,) or None
    (an in-kernel operand — the ELL width stays J, no unit-feature
    column); hashed_labels (N, R) int32 -> (N,) f32.  Neither the
    (N, R·B) logits tensor nor a dense (N, d) activation ever exists in
    HBM in either pass.  Differentiable wrt w and bias only — ``vals``
    is data, not a parameter, and receives a zero cotangent (use the
    densified reference if you need feature grads)."""
    out, _ = _sparse_fwd(cols, vals, w, bias, hashed_labels, num_buckets,
                         block_n, block_c, block_d, interpret)
    return out


def _sparse_call(kind, colsp, valsp, wp, biasp, yp, lsep, gp, dims, bn,
                 bc, bd, jp, interpret):
    """Shared pallas_call builder for the sparse forward/backward."""
    npad, dp, r, rp, b, bp, c = dims
    nkd = dp // bd
    if kind == "fwd":
        ell_spec = pl.BlockSpec((bn, jp), lambda i, j, k: (i, 0))
        w_spec = pl.BlockSpec((bd, bc), lambda i, j, k: (k, j))
        b_spec = pl.BlockSpec((1, bc), lambda i, j, k: (0, j))
        row_spec = lambda width: pl.BlockSpec((bn, width),
                                              lambda i, j, k: (i, 0))
        return pl.pallas_call(
            functools.partial(_sparse_fwd_body, bn, bc, bd, r, rp, b, bp,
                              jp),
            grid=(npad // bn, c // bc, nkd),
            in_specs=[ell_spec, ell_spec, w_spec, b_spec, row_spec(rp)],
            out_specs=(row_spec(1), row_spec(rp)),
            out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                       jax.ShapeDtypeStruct((npad, rp), jnp.float32)),
            scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)]
            + [pltpu.VMEM((bn, rp), jnp.float32)] * 3,
            compiler_params=_SEQUENTIAL3,
            interpret=interpret,
        )(colsp, valsp, wp, biasp, yp)
    # bwd: both phases of a (j, i) cell map the same dW/W d-block
    kmap = lambda k2: jnp.where(k2 >= nkd, k2 - nkd, k2)
    dw_spec = pl.BlockSpec((bd, bc), lambda j, i, k2: (kmap(k2), j))
    b_spec = pl.BlockSpec((1, bc), lambda j, i, k2: (0, j))
    ell_spec = pl.BlockSpec((bn, jp), lambda j, i, k2: (i, 0))
    row_spec = lambda width: pl.BlockSpec((bn, width),
                                          lambda j, i, k2: (i, 0))
    return pl.pallas_call(
        functools.partial(_sparse_bwd_body, bn, bc, bd, nkd, r, rp, b,
                          bp, jp),
        grid=(c // bc, npad // bn, 2 * nkd),
        in_specs=[ell_spec, ell_spec, dw_spec, b_spec, row_spec(rp),
                  row_spec(rp), row_spec(1)],
        out_specs=(dw_spec, b_spec),
        out_shape=(jax.ShapeDtypeStruct((dp, c), jnp.float32),
                   jax.ShapeDtypeStruct((1, c), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32),
                        pltpu.VMEM((bn, bc), jnp.float32)],
        compiler_params=_SEQUENTIAL3,
        interpret=interpret,
    )(colsp, valsp, wp, biasp, yp, lsep, gp)


def _check_sparse_shapes(cols, vals, w, bias, hashed_labels, num_buckets):
    n, j = cols.shape
    d = w.shape[0]
    r = hashed_labels.shape[-1]
    if vals.shape != (n, j):
        raise ValueError(f"vals {vals.shape} vs cols {cols.shape}")
    if hashed_labels.shape != (n, r):
        raise ValueError(f"labels {hashed_labels.shape} vs cols "
                         f"{cols.shape}")
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    if bias is not None and bias.shape != (r * num_buckets,):
        raise ValueError(f"bias {bias.shape} != ({r}*{num_buckets},)")
    return n, d, r, j


def _sparse_fwd(cols, vals, w, bias, hashed_labels, num_buckets, block_n,
                block_c, block_d, interpret):
    n, d, r, j = _check_sparse_shapes(cols, vals, w, bias, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(n, d, r, b, j, block_n,
                                                  block_c, block_d)
    colsp, valsp, wp, biasp, yp, dp = _pad_sparse_operands(
        cols, vals, w, bias, hashed_labels, r, b, bn, rp, bp, bd, jp)
    dims = (colsp.shape[0], dp, r, rp, b, bp, rp * bp)
    loss, lse = _sparse_call("fwd", colsp, valsp, wp, biasp, yp, None,
                             None, dims, bn, bc, bd, jp, interpret)
    return loss[:n, 0], (cols, vals, w, bias, hashed_labels, lse[:n])


def _sparse_bwd(num_buckets, block_n, block_c, block_d, interpret, res, g):
    cols, vals, w, bias, hashed_labels, lse = res
    n, d, r, j = _check_sparse_shapes(cols, vals, w, bias, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(n, d, r, b, j, block_n,
                                                  block_c, block_d)
    colsp, valsp, wp, biasp, yp, dp = _pad_sparse_operands(
        cols, vals, w, bias, hashed_labels, r, b, bn, rp, bp, bd, jp)
    npad = colsp.shape[0]
    dims = (npad, dp, r, rp, b, bp, rp * bp)
    gp = jnp.pad(g.astype(jnp.float32).reshape(n, 1),
                 ((0, npad - n), (0, 0)))
    lsep = jnp.pad(lse, ((0, npad - n), (0, 0)))
    dwp, dbp = _sparse_call("bwd", colsp, valsp, wp, biasp, yp, lsep, gp,
                            dims, bn, bc, bd, jp, interpret)
    dw = dwp.reshape(dp, rp, bp)[:d, :r, :b].reshape(d, r * b) \
        .astype(w.dtype)
    # features are data: zero cotangent for vals, none for int cols/labels
    db = (None if bias is None
          else dbp.reshape(rp, bp)[:r, :b].reshape(r * b)
          .astype(bias.dtype))
    return None, jnp.zeros_like(vals), dw, db, None


mach_fused_xent_sparse_pallas.defvjp(_sparse_fwd, _sparse_bwd)


# ---------------------------------------------------------------------------
# Scalar-prefetch gather entry point (high-nnz sparse path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def mach_fused_xent_gather_pallas(cols: jnp.ndarray, vals: jnp.ndarray,
                                  w: jnp.ndarray,
                                  bias: Optional[jnp.ndarray],
                                  hashed_labels: jnp.ndarray,
                                  num_buckets: int,
                                  block_c: Optional[int] = None,
                                  interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE from a padded-ELL sparse batch —
    the scalar-prefetch gather family (no densification, no one-hot).

    Same contract as ``mach_fused_xent_sparse_pallas`` (cols/vals
    (N, J); w (d, R·B); optional bias (R·B,); hashed_labels (N, R) ->
    (N,) f32; differentiable wrt w and bias, ``vals`` gets a zero
    cotangent) but the active W rows are DMA'd by ELL column id via
    ``PrefetchScalarGridSpec`` instead of densified in VMEM: per-step
    VMEM is O(bc) — independent of nnz and of d — so high-nnz (>= 1k)
    bag-of-words shapes are first-class.  The ELL cols/vals ride in
    SMEM (2·4·N·J bytes); only ``block_c`` tiles (there is no bn or bd
    here — one example row per grid step, whole W rows per gather).
    Interpret-mode caveat as the module docstring: the zero-aliased
    gather-indexed dW accumulation needs sequential grid order; native
    Mosaic lowering is unvalidated (ROADMAP item 3)."""
    out, _ = _gather_fwd(cols, vals, w, bias, hashed_labels, num_buckets,
                         block_c, interpret)
    return out


def _gather_call(kind, colsp, valsp, wp, biasp, yp, lsep, gp, dims, bc,
                 jp, interpret):
    """Shared pallas_call builder for the gather forward/backward.  The
    scalar-prefetched ``cols`` feed every W/dW BlockSpec index map —
    the DMA gather itself."""
    n, d, r, rp, b, bp, c = dims
    if kind == "fwd":
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n, c // bc, jp),
            in_specs=[
                pl.BlockSpec((1, bc),
                             lambda i, j, k, cols, vals: (cols[i, k], j)),
                pl.BlockSpec((1, bc), lambda i, j, k, cols, vals: (0, j)),
                pl.BlockSpec((1, rp), lambda i, j, k, cols, vals: (i, 0)),
            ],
            out_specs=(
                pl.BlockSpec((1, 1), lambda i, j, k, cols, vals: (i, 0)),
                pl.BlockSpec((1, rp), lambda i, j, k, cols, vals: (i, 0)),
            ),
            scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)]
            + [pltpu.VMEM((1, rp), jnp.float32)] * 3,
        )
        return pl.pallas_call(
            functools.partial(_gather_fwd_body, r, rp, b, bp, bc),
            grid_spec=grid_spec,
            out_shape=(jax.ShapeDtypeStruct((n, 1), jnp.float32),
                       jax.ShapeDtypeStruct((n, rp), jnp.float32)),
            compiler_params=_SEQUENTIAL3,
            interpret=interpret,
        )(colsp, valsp, wp, biasp, yp)
    # bwd: both phases of an (i, j) cell map the same gathered dW/W row
    kmap = lambda k2: jnp.where(k2 >= jp, k2 - jp, k2)
    dw_spec = pl.BlockSpec(
        (1, bc), lambda i, j, k2, cols, vals: (cols[i, kmap(k2)], j))
    db_spec = pl.BlockSpec((1, bc), lambda i, j, k2, cols, vals: (0, j))
    row_spec = lambda width: pl.BlockSpec(
        (1, width), lambda i, j, k2, cols, vals: (i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, c // bc, 2 * jp),
        in_specs=[dw_spec, db_spec, row_spec(rp), row_spec(rp),
                  row_spec(1), dw_spec, db_spec],
        out_specs=(dw_spec, db_spec),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)] * 2,
    )
    return pl.pallas_call(
        functools.partial(_gather_bwd_body, r, rp, b, bp, bc),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((d, c), jnp.float32),
                   jax.ShapeDtypeStruct((1, c), jnp.float32)),
        # absolute input indices (scalar-prefetch operands included):
        # 7/8 are the zero-filled dW/dbias init operands
        input_output_aliases={7: 0, 8: 1},
        compiler_params=_SEQUENTIAL3,
        interpret=interpret,
    )(colsp, valsp, wp, biasp, yp, lsep, gp,
      jnp.zeros((d, c), jnp.float32), jnp.zeros((1, c), jnp.float32))


def _gather_fwd(cols, vals, w, bias, hashed_labels, num_buckets, block_c,
                interpret):
    n, d, r, j = _check_sparse_shapes(cols, vals, w, bias, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bc, rp, bp, jp = choose_gather_blocks(n, d, r, b, j, block_c)
    colsp, valsp, wp, biasp, yp = _pad_gather_operands(
        cols, vals, w, bias, hashed_labels, r, b, rp, bp, jp)
    dims = (n, d, r, rp, b, bp, rp * bp)
    loss, lse = _gather_call("fwd", colsp, valsp, wp, biasp, yp, None,
                             None, dims, bc, jp, interpret)
    return loss[:, 0], (cols, vals, w, bias, hashed_labels, lse)


def _gather_bwd(num_buckets, block_c, interpret, res, g):
    cols, vals, w, bias, hashed_labels, lse = res
    n, d, r, j = _check_sparse_shapes(cols, vals, w, bias, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bc, rp, bp, jp = choose_gather_blocks(n, d, r, b, j, block_c)
    colsp, valsp, wp, biasp, yp = _pad_gather_operands(
        cols, vals, w, bias, hashed_labels, r, b, rp, bp, jp)
    dims = (n, d, r, rp, b, bp, rp * bp)
    gp = g.astype(jnp.float32).reshape(n, 1)
    dwp, dbp = _gather_call("bwd", colsp, valsp, wp, biasp, yp, lse, gp,
                            dims, bc, jp, interpret)
    dw = dwp.reshape(d, rp, bp)[:, :r, :b].reshape(d, r * b) \
        .astype(w.dtype)
    # features are data: zero cotangent for vals, none for int cols/labels
    db = (None if bias is None
          else dbp.reshape(rp, bp)[:r, :b].reshape(r * b)
          .astype(bias.dtype))
    return None, jnp.zeros_like(vals), dw, db, None


mach_fused_xent_gather_pallas.defvjp(_gather_fwd, _gather_bwd)
