"""Fused projection + MACH cross-entropy (the logit-free training loss).

``mach_xent.py`` fuses the R-head cross-entropy *given* the logits — but
the trainer still materializes the full (N, R·B) logits tensor in HBM
via the head matmul, so train-time activation memory is O(N·R·B) and
the paper's O(d log K) story holds only for parameters.  This kernel
fuses the hidden→bucket projection into the loss itself:

    grid (N/bn, C/bc), C = R·B columns, C minor.  Per step the logits
    tile ``h_blk (bn, d) @ W_blk (d, bc)`` is computed in VMEM and
    immediately reduced: an online per-head max / sum-exp (flash-
    attention-style, so heads may span several column blocks) and a
    gather-free label pick (one-hot contraction against the in-VMEM
    tile) accumulate into (bn, R) scratch.  The (N, R·B) logits tensor
    never exists in HBM in either pass.

Column blocks are head-aligned: when B fits the VMEM budget a block
covers ``nh`` whole heads (no online rescaling ever fires — each head's
logsumexp completes in its block); when B is larger than the budget a
block is a bucket-slice of a single head and the online update streams
the head's logsumexp across blocks.  Both cases run the same body.

The custom VJP recomputes logits tiles (two extra matmuls, the standard
fused-CE trade) from the saved per-head logsumexp:

    dlogits[n, rB+b] = g_n · (softmax(logits)[n, r, b] − 1[b = y_nr])

and accumulates ``dh = dlogits @ Wᵀ`` (N-blocks outer, scratch (bn, d))
and ``dW = hᵀ @ dlogits`` (column-blocks outer, scratch (d, bc)) in two
kernels whose grids match their reduction direction.  Activation
residuals are h and the (N, R) logsumexp — O(N·d), independent of R·B.

Padding: N pads to bn (padded rows get zero cotangent so contribute
nothing), heads pad to a multiple of the per-block head count, buckets
pad to a multiple of the block width; padded columns are masked to
NEG_INF before the reduction and zeroed in the backward.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mach_decode import NEG_INF, round_up

_LANE = 128


def choose_fused_blocks(n: int, d: int, r: int, b: int,
                        block_n: Optional[int] = None,
                        block_c: Optional[int] = None,
                        vmem_budget: int = 6 * 2**20
                        ) -> tuple[int, int, int, int]:
    """Pick (bn, bc, rp, bp): N block, column block, padded head count,
    padded bucket count.  Column blocks are head-aligned — either
    ``bc = nh·b`` (nh whole heads per block, ``rp`` padded to a multiple
    of nh) or ``bc | bp`` (bucket-slices of one head, ``bp`` the padded
    per-head width).  Budget covers the W tile, the logits tile and the
    backward accumulators, all f32."""
    bn = block_n or min(128, max(8, n))
    bn = max(8, round_up(bn, 8))
    if block_c is not None:
        bc_cap = max(1, block_c)
    else:
        bc_cap = vmem_budget // (4 * (2 * d + 2 * bn))
        bc_cap = int(min(max(bc_cap // _LANE * _LANE, _LANE), 2048))
    if b <= bc_cap:
        nh = max(1, min(bc_cap // b, r))
        bc, bp = nh * b, b
        rp = round_up(r, nh)
    else:
        bc, rp = bc_cap, r
        bp = round_up(b, bc)
    return bn, bc, rp, bp


def _pad_operands(h2, w, labels, r, b, bn, rp, bp):
    """(h (N,d), w (d,R·B), y (N,R)) -> padded (h (Np,d), w (d,rp·bp),
    y (Np,rp) int32).  W pads with zero heads/buckets (masked in-kernel),
    labels pad with bucket 0 (their heads are masked)."""
    n, d = h2.shape
    npad = -n % bn
    if npad:
        h2 = jnp.pad(h2, ((0, npad), (0, 0)))
        labels = jnp.pad(labels, ((0, npad), (0, 0)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, 0), (0, rp - r)))
    w3 = w.reshape(d, r, b)
    w3 = jnp.pad(w3, ((0, 0), (0, rp - r), (0, bp - b)))
    return h2, w3.reshape(d, rp * bp), labels


def _tile_geometry(bc, bp, kblk):
    """Static (nh, width) + traced (h0, boff) for the current column
    block.  nh heads of ``width`` buckets each; h0 the first head id,
    boff the bucket offset inside it (0 unless a head spans blocks)."""
    nh = max(1, bc // bp)
    width = bp if bc >= bp else bc
    kbase = kblk * bc
    h0 = kbase // bp
    boff = kbase - h0 * bp
    return nh, width, h0, boff


def _masked_tile(h_ref, w_ref, bn, nh, width, boff, b):
    """Logits tile (bn, nh, width) in f32, padded buckets at NEG_INF.
    Returns (tile3, bidx) — bidx the per-position bucket id."""
    tile = jnp.dot(h_ref[...].astype(jnp.float32),
                   w_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    tile3 = tile.reshape(bn, nh, width)
    bidx = boff + jax.lax.broadcasted_iota(jnp.int32, (bn, nh, width), 2)
    return jnp.where(bidx < b, tile3, NEG_INF), bidx


def _fwd_body(bn, bc, r, rp, b, bp,
              h_ref, w_ref, y_ref, loss_ref, lse_ref,
              m_scr, s_scr, p_scr):
    """Forward step: online per-head (max, sumexp, picked) accumulation.
    h_ref (bn, d); w_ref (d, bc); y_ref (bn, rp); scratch (bn, rp)."""
    kblk = pl.program_id(1)
    nkb = pl.num_programs(1)
    nh, width, h0, boff = _tile_geometry(bc, bp, kblk)

    @pl.when(kblk == 0)
    def _init():
        m_scr[...] = jnp.full((bn, rp), NEG_INF, jnp.float32)
        s_scr[...] = jnp.zeros((bn, rp), jnp.float32)
        p_scr[...] = jnp.zeros((bn, rp), jnp.float32)

    tile3, bidx = _masked_tile(h_ref, w_ref, bn, nh, width, boff, b)
    y_blk = y_ref[:, pl.ds(h0, nh)]                           # (bn, nh)
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    picked = jnp.sum(tile3 * onehot, axis=2)                  # (bn, nh)

    # online logsumexp update on the nh heads this block touches
    m_old = m_scr[:, pl.ds(h0, nh)]
    s_old = s_scr[:, pl.ds(h0, nh)]
    m_new = jnp.maximum(m_old, jnp.max(tile3, axis=2))
    s_new = s_old * jnp.exp(m_old - m_new) \
        + jnp.sum(jnp.exp(tile3 - m_new[:, :, None]), axis=2)
    m_scr[:, pl.ds(h0, nh)] = m_new
    s_scr[:, pl.ds(h0, nh)] = s_new
    p_scr[:, pl.ds(h0, nh)] = p_scr[:, pl.ds(h0, nh)] + picked

    @pl.when(kblk == nkb - 1)
    def _flush():
        lse = m_scr[...] + jnp.log(s_scr[...])                # (bn, rp)
        head_ok = jax.lax.broadcasted_iota(jnp.int32, (bn, rp), 1) < r
        loss_ref[...] = jnp.sum(
            jnp.where(head_ok, lse - p_scr[...], 0.0),
            axis=1, keepdims=True)
        lse_ref[...] = jnp.where(head_ok, lse, 0.0)


def _dlogits_tile(h_ref, w_ref, y_ref, lse_ref, g_ref,
                  bn, bc, r, b, bp, kblk):
    """Recompute the logits tile and form g·(softmax − onehot),
    zeroed at padded heads/buckets.  Returns (bn, bc) f32."""
    nh, width, h0, boff = _tile_geometry(bc, bp, kblk)
    tile3, bidx = _masked_tile(h_ref, w_ref, bn, nh, width, boff, b)
    y_blk = y_ref[:, pl.ds(h0, nh)]
    lse_blk = lse_ref[:, pl.ds(h0, nh)]                       # (bn, nh)
    p = jnp.exp(tile3 - lse_blk[:, :, None])                  # softmax
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    head_ok = (h0 + jax.lax.broadcasted_iota(
        jnp.int32, (bn, nh, width), 1)) < r
    dtile3 = jnp.where((bidx < b) & head_ok,
                       g_ref[...][:, :, None] * (p - onehot), 0.0)
    return dtile3.reshape(bn, bc)


def _bwd_dh_body(bn, bc, d, r, rp, b, bp,
                 h_ref, w_ref, y_ref, lse_ref, g_ref, dh_ref, acc):
    """dh = Σ_colblocks dlogits_tile @ W_blkᵀ;  grid (N/bn, C/bc)."""
    kblk = pl.program_id(1)
    nkb = pl.num_programs(1)

    @pl.when(kblk == 0)
    def _init():
        acc[...] = jnp.zeros((bn, d), jnp.float32)

    dtile = _dlogits_tile(h_ref, w_ref, y_ref, lse_ref, g_ref,
                          bn, bc, r, b, bp, kblk)
    acc[...] += jax.lax.dot_general(
        dtile, w_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (bn, d)

    @pl.when(kblk == nkb - 1)
    def _flush():
        dh_ref[...] = acc[...].astype(dh_ref.dtype)


def _bwd_dw_body(bn, bc, d, r, rp, b, bp,
                 h_ref, w_ref, y_ref, lse_ref, g_ref, dw_ref, acc):
    """dW_blk = Σ_nblocks h_blkᵀ @ dlogits_tile;  grid (C/bc, N/bn) —
    N minor so the (d, bc) accumulator sees all N blocks in order."""
    kblk = pl.program_id(0)
    iblk = pl.program_id(1)
    nib = pl.num_programs(1)

    @pl.when(iblk == 0)
    def _init():
        acc[...] = jnp.zeros((d, bc), jnp.float32)

    dtile = _dlogits_tile(h_ref, w_ref, y_ref, lse_ref, g_ref,
                          bn, bc, r, b, bp, kblk)
    acc[...] += jax.lax.dot_general(
        h_ref[...].astype(jnp.float32), dtile,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (d, bc)

    @pl.when(iblk == nib - 1)
    def _flush():
        dw_ref[...] = acc[...].astype(dw_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def mach_fused_xent_pallas(h2: jnp.ndarray, w: jnp.ndarray,
                           hashed_labels: jnp.ndarray,
                           num_buckets: int,
                           block_n: Optional[int] = None,
                           block_c: Optional[int] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE, straight from hidden states.

    h2 (N, d); w (d, R·B); hashed_labels (N, R) int32 -> (N,) f32.
    Differentiable: the VJP yields (dh, dW) without ever forming the
    (N, R·B) logits tensor."""
    out, _ = _fused_fwd(h2, w, hashed_labels, num_buckets, block_n,
                        block_c, interpret)
    return out


def _fused_call(kind, h2p, wp, yp, lsep, gp, dims, bn, bc, interpret):
    """Shared pallas_call builder for the three passes."""
    npad, d, r, rp, b, bp, c = dims
    n_spec = pl.BlockSpec((bn, d), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((d, bc), lambda i, j: (0, j))
    row_spec = lambda width: pl.BlockSpec((bn, width), lambda i, j: (i, 0))
    if kind == "fwd":
        return pl.pallas_call(
            functools.partial(_fwd_body, bn, bc, r, rp, b, bp),
            grid=(npad // bn, c // bc),
            in_specs=[n_spec, w_spec, row_spec(rp)],
            out_specs=(row_spec(1), row_spec(rp)),
            out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                       jax.ShapeDtypeStruct((npad, rp), jnp.float32)),
            scratch_shapes=[pltpu.VMEM((bn, rp), jnp.float32)] * 3,
            interpret=interpret,
        )(h2p, wp, yp)
    if kind == "dh":
        return pl.pallas_call(
            functools.partial(_bwd_dh_body, bn, bc, d, r, rp, b, bp),
            grid=(npad // bn, c // bc),
            in_specs=[n_spec, w_spec, row_spec(rp), row_spec(rp),
                      row_spec(1)],
            out_specs=n_spec,
            out_shape=jax.ShapeDtypeStruct((npad, d), h2p.dtype),
            scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
            interpret=interpret,
        )(h2p, wp, yp, lsep, gp)
    # dW: column blocks outer, N minor
    cw_spec = pl.BlockSpec((d, bc), lambda j, i: (0, j))
    return pl.pallas_call(
        functools.partial(_bwd_dw_body, bn, bc, d, r, rp, b, bp),
        grid=(c // bc, npad // bn),
        in_specs=[pl.BlockSpec((bn, d), lambda j, i: (i, 0)), cw_spec,
                  pl.BlockSpec((bn, rp), lambda j, i: (i, 0)),
                  pl.BlockSpec((bn, rp), lambda j, i: (i, 0)),
                  pl.BlockSpec((bn, 1), lambda j, i: (i, 0))],
        out_specs=cw_spec,
        out_shape=jax.ShapeDtypeStruct((d, c), wp.dtype),
        scratch_shapes=[pltpu.VMEM((d, bc), jnp.float32)],
        interpret=interpret,
    )(h2p, wp, yp, lsep, gp)


def _check_shapes(h2, w, hashed_labels, num_buckets):
    n, d = h2.shape
    r = hashed_labels.shape[-1]
    if hashed_labels.shape != (n, r):
        raise ValueError(f"labels {hashed_labels.shape} vs h {h2.shape}")
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    return n, d, r


def _fused_fwd(h2, w, hashed_labels, num_buckets, block_n, block_c,
               interpret):
    n, d, r = _check_shapes(h2, w, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c)
    h2p, wp, yp = _pad_operands(h2, w, hashed_labels, r, b, bn, rp, bp)
    dims = (h2p.shape[0], d, r, rp, b, bp, rp * bp)
    loss, lse = _fused_call("fwd", h2p, wp, yp, None, None, dims, bn, bc,
                            interpret)
    return loss[:n, 0], (h2, w, hashed_labels, lse[:n])


def _fused_bwd(num_buckets, block_n, block_c, interpret, res, g):
    h2, w, hashed_labels, lse = res
    n, d, r = _check_shapes(h2, w, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c)
    h2p, wp, yp = _pad_operands(h2, w, hashed_labels, r, b, bn, rp, bp)
    npad = h2p.shape[0]
    dims = (npad, d, r, rp, b, bp, rp * bp)
    # padded rows/heads carry zero cotangent -> zero dlogits
    gp = jnp.pad(g.astype(jnp.float32).reshape(n, 1),
                 ((0, npad - n), (0, 0)))
    lsep = jnp.pad(lse, ((0, npad - n), (0, 0)))
    dh = _fused_call("dh", h2p, wp, yp, lsep, gp, dims, bn, bc,
                     interpret)[:n]
    dwp = _fused_call("dw", h2p, wp, yp, lsep, gp, dims, bn, bc,
                      interpret)
    dw = dwp.reshape(d, rp, bp)[:, :r, :b].reshape(d, r * b)
    return dh, dw, None


mach_fused_xent_pallas.defvjp(_fused_fwd, _fused_bwd)
