"""Fused projection + MACH cross-entropy (the logit-free training loss).

``mach_xent.py`` fuses the R-head cross-entropy *given* the logits — but
the trainer still materializes the full (N, R·B) logits tensor in HBM
via the head matmul, so train-time activation memory is O(N·R·B) and
the paper's O(d log K) story holds only for parameters.  This kernel
fuses the hidden→bucket projection into the loss itself:

    grid (N/bn, C/bc), C = R·B columns, C minor.  Per step the logits
    tile ``h_blk (bn, d) @ W_blk (d, bc)`` is computed in VMEM and
    immediately reduced: an online per-head max / sum-exp (flash-
    attention-style, so heads may span several column blocks) and a
    gather-free label pick (one-hot contraction against the in-VMEM
    tile) accumulate into (bn, R) scratch.  The (N, R·B) logits tensor
    never exists in HBM in either pass.

Column blocks are head-aligned: when B fits the VMEM budget a block
covers ``nh`` whole heads (no online rescaling ever fires — each head's
logsumexp completes in its block); when B is larger than the budget a
block is a bucket-slice of a single head and the online update streams
the head's logsumexp across blocks.  Both cases run the same body.

The custom VJP recomputes each logits tile ONCE (the standard fused-CE
trade) from the saved per-head logsumexp:

    dlogits[n, rB+b] = g_n · (softmax(logits)[n, r, b] − 1[b = y_nr])

in a single kernel, grid (C/bc, N/bn) with N minor: ``dW_blk = Σ_i
h_iᵀ @ dlogits`` accumulates in a (d, bc) scratch (N blocks are
consecutive, flushed at the last), while ``dh_i += dlogits @ W_blkᵀ``
accumulates into a *revisited* (bn, d) output block — the dh row block
is visited once per column block, initialized at the first and
read-modify-written on each revisit, so the running sum rides the
output windowing.  Activation residuals are h and the (N, R)
logsumexp — O(N·d), independent of R·B.

Sparse features (the paper's ODP d=422k workload): the ``*_sparse``
entry points take the batch in padded-ELL form — ``cols/vals (N, J)``,
row n's features at ``cols[n, :]`` with weights ``vals[n, :]`` (padding
carries val 0) — as produced from CSR by ``ops.mach_fused_xent_csr``.
A third grid axis blocks the feature dim: per (row block, column block,
d block) the active slice of the activation is densified *in VMEM* via
a one-hot contraction (``A[n, p] = Σ_j vals[n, j]·1[cols[n, j] = d0+p]``,
MXU/Mosaic-friendly, duplicate ids sum like a CSR scatter-add) and
``A @ W_blk`` accumulates the logits tile across d blocks; the dense
(N, d) activation never exists in HBM, and W streams through VMEM
(bd, bc) tiles — full-d rows are never resident, so d=422k heads fit
the budget.  The backward runs one fused kernel per the dense design:
for each tile, a first d-sweep recomputes the logits tile once and
forms dlogits in scratch, then a second d-sweep scatter-adds
``dW_blk += A_kᵀ @ dlogits`` into a revisited (dp, C) f32 output
accumulator — only the rows touched by active features receive nonzero
updates.  ``vals`` is treated as non-differentiable data (zero
cotangent): features are inputs, not parameters.

Padding: N pads to bn (padded rows get zero cotangent so contribute
nothing), heads pad to a multiple of the per-block head count, buckets
pad to a multiple of the block width; padded columns are masked to
NEG_INF before the reduction and zeroed in the backward.  Sparse
operands additionally pad J to a lane multiple and d to a multiple of
the d block (padded slots carry val 0, padded W rows are zero).

Interpret-mode caveat (see ROADMAP): the revisited accumulators rely on
output blocks being re-fetched on non-consecutive revisits.  Every grid
here is declared ``dimension_semantics=("arbitrary", ...)`` so Mosaic
must execute steps sequentially (no parallel reordering across the
revisited windows); interpret mode executes the re-fetch faithfully but
cannot vet the native pipelining — validate on real TPU before flipping
defaults.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mach_decode import NEG_INF, round_up

_LANE = 128

# Scratch logsumexp state and the revisited dh/dW output accumulators
# both require grid steps to run in order — declare every grid axis
# "arbitrary" (sequential) so Mosaic may not parallelize/reorder them.
_SEQUENTIAL2 = pltpu.TPUCompilerParams(
    dimension_semantics=("arbitrary", "arbitrary"))
_SEQUENTIAL3 = pltpu.TPUCompilerParams(
    dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))


def _align_columns(bc_cap: int, r: int, b: int) -> tuple[int, int, int]:
    """Head-align a column-block budget: (bc, rp, bp).  Either whole
    heads per block (bc = nh·b, rp padded to a multiple of nh) or
    bucket-slices of one head (bc | bp, bp the padded per-head width)."""
    if b <= bc_cap:
        nh = max(1, min(bc_cap // b, r))
        return nh * b, round_up(r, nh), b
    return bc_cap, r, round_up(b, bc_cap)


def choose_fused_blocks(n: int, d: int, r: int, b: int,
                        block_n: Optional[int] = None,
                        block_c: Optional[int] = None,
                        vmem_budget: int = 6 * 2**20
                        ) -> tuple[int, int, int, int]:
    """Pick (bn, bc, rp, bp): N block, column block, padded head count,
    padded bucket count.  Budget covers the W tile, the logits tile and
    the backward accumulators, all f32."""
    bn = block_n or min(128, max(8, n))
    bn = max(8, round_up(bn, 8))
    if block_c is not None:
        bc_cap = max(1, block_c)
    else:
        bc_cap = vmem_budget // (4 * (2 * d + 2 * bn))
        bc_cap = int(min(max(bc_cap // _LANE * _LANE, _LANE), 2048))
    bc, rp, bp = _align_columns(bc_cap, r, b)
    return bn, bc, rp, bp


def choose_sparse_blocks(n: int, d: int, r: int, b: int, j: int,
                         block_n: Optional[int] = None,
                         block_c: Optional[int] = None,
                         block_d: Optional[int] = None,
                         vmem_budget: int = 6 * 2**20
                         ) -> tuple[int, int, int, int, int, int]:
    """Pick (bn, bc, bd, rp, bp, jp) for the sparse kernels.  The
    densified (bn, jp, bd) one-hot tile is the VMEM driver: bn shrinks
    first as jp (the padded nnz) grows, then bd drops below a full lane
    block (to the 8-sublane floor) so the tile stays under half the
    budget even at bag-of-words nnz (~1k)."""
    jp = round_up(max(j, 1), _LANE)
    # the densify body holds ~two f32 (bn, jp, bd) intermediates, so
    # size them to half the budget together: 2·4·bn·jp·bd <= budget/2
    if block_n is not None:
        bn = max(8, round_up(block_n, 8))
    else:
        bn_cap = vmem_budget // (4 * 4 * jp * _LANE)   # bd >= one lane
        bn = min(16, max(8, n), max(8, bn_cap // 8 * 8))
    if block_d is not None:
        bd = max(8, round_up(block_d, 8))
    else:
        bd = vmem_budget // (4 * 4 * bn * jp)
        if bd >= _LANE:
            bd = int(min(bd // _LANE * _LANE, 512))
        else:
            # one-hot tile can't afford a full lane block: sublane floor
            bd = int(max(bd // 8 * 8, 8))
    if block_c is not None:
        bc_cap = max(1, block_c)
    else:
        bc_cap = vmem_budget // (4 * (bd + 4 * bn))
        bc_cap = int(min(max(bc_cap // _LANE * _LANE, _LANE), 2048))
    bc, rp, bp = _align_columns(bc_cap, r, b)
    return bn, bc, bd, rp, bp, jp


def _pad_operands(h2, w, labels, r, b, bn, rp, bp):
    """(h (N,d), w (d,R·B), y (N,R)) -> padded (h (Np,d), w (d,rp·bp),
    y (Np,rp) int32).  W pads with zero heads/buckets (masked in-kernel),
    labels pad with bucket 0 (their heads are masked)."""
    n, d = h2.shape
    npad = -n % bn
    if npad:
        h2 = jnp.pad(h2, ((0, npad), (0, 0)))
        labels = jnp.pad(labels, ((0, npad), (0, 0)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, 0), (0, rp - r)))
    w3 = w.reshape(d, r, b)
    w3 = jnp.pad(w3, ((0, 0), (0, rp - r), (0, bp - b)))
    return h2, w3.reshape(d, rp * bp), labels


def _pad_sparse_operands(cols, vals, w, labels, r, b, bn, rp, bp, bd, jp):
    """ELL (cols/vals (N,J)), w (d,R·B), y (N,R) -> padded (cols/vals
    (Np,jp), w (dp,rp·bp), y (Np,rp), dp).  Padded slots carry val 0 so
    they contribute nothing regardless of their col id."""
    n, j = cols.shape
    d = w.shape[0]
    dp = round_up(d, bd)
    npad = -n % bn
    cols = jnp.pad(cols.astype(jnp.int32), ((0, npad), (0, jp - j)))
    vals = jnp.pad(vals, ((0, npad), (0, jp - j)))
    labels = jnp.pad(labels.astype(jnp.int32), ((0, npad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, rp - r)))
    w3 = w.reshape(d, r, b)
    w3 = jnp.pad(w3, ((0, dp - d), (0, rp - r), (0, bp - b)))
    return cols, vals, w3.reshape(dp, rp * bp), labels, dp


def _tile_geometry(bc, bp, kblk):
    """Static (nh, width) + traced (h0, boff) for the current column
    block.  nh heads of ``width`` buckets each; h0 the first head id,
    boff the bucket offset inside it (0 unless a head spans blocks)."""
    nh = max(1, bc // bp)
    width = bp if bc >= bp else bc
    kbase = kblk * bc
    h0 = kbase // bp
    boff = kbase - h0 * bp
    return nh, width, h0, boff


def _mask_tile3(tile, bn, nh, width, boff, b):
    """(bn, nh·width) f32 logits tile -> ((bn, nh, width) with padded
    buckets at NEG_INF, per-position bucket ids)."""
    tile3 = tile.reshape(bn, nh, width)
    bidx = boff + jax.lax.broadcasted_iota(jnp.int32, (bn, nh, width), 2)
    return jnp.where(bidx < b, tile3, NEG_INF), bidx


def _masked_tile(h_ref, w_ref, bn, nh, width, boff, b):
    """Dense logits tile (bn, nh, width) in f32 via h @ W."""
    tile = jnp.dot(h_ref[...].astype(jnp.float32),
                   w_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return _mask_tile3(tile, bn, nh, width, boff, b)


def _densify_tile(cols_ref, vals_ref, d0, bn, jp, bd):
    """In-VMEM densified activation slice A (bn, bd) for feature range
    [d0, d0+bd): A[n, p] = Σ_j vals[n, j]·1[cols[n, j] = d0+p].  A
    one-hot contraction (no gather — Mosaic-friendly); duplicate ids
    within a row sum, matching a CSR scatter-add; padded slots carry
    val 0 so their col id is irrelevant."""
    local = cols_ref[...].astype(jnp.int32) - d0                # (bn, jp)
    oh = (local[:, :, None] ==
          jax.lax.broadcasted_iota(jnp.int32, (bn, jp, bd), 2))
    weighted = oh.astype(jnp.float32) \
        * vals_ref[...].astype(jnp.float32)[:, :, None]
    return jnp.sum(weighted, axis=1)                            # (bn, bd)


def _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh):
    """Online per-head (max, sumexp, picked) accumulation on the nh
    heads this column block touches."""
    y_blk = y_ref[:, pl.ds(h0, nh)]                           # (bn, nh)
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    picked = jnp.sum(tile3 * onehot, axis=2)                  # (bn, nh)
    m_old = m_scr[:, pl.ds(h0, nh)]
    s_old = s_scr[:, pl.ds(h0, nh)]
    m_new = jnp.maximum(m_old, jnp.max(tile3, axis=2))
    s_new = s_old * jnp.exp(m_old - m_new) \
        + jnp.sum(jnp.exp(tile3 - m_new[:, :, None]), axis=2)
    m_scr[:, pl.ds(h0, nh)] = m_new
    s_scr[:, pl.ds(h0, nh)] = s_new
    p_scr[:, pl.ds(h0, nh)] = p_scr[:, pl.ds(h0, nh)] + picked


def _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr):
    """Final reduction: per-head logsumexp -> summed CE + saved lse."""
    lse = m_scr[...] + jnp.log(s_scr[...])                    # (bn, rp)
    head_ok = jax.lax.broadcasted_iota(jnp.int32, lse.shape, 1) < r
    loss_ref[...] = jnp.sum(
        jnp.where(head_ok, lse - p_scr[...], 0.0),
        axis=1, keepdims=True)
    lse_ref[...] = jnp.where(head_ok, lse, 0.0)


def _dlogits_from_tile(tile3, bidx, y_ref, lse_ref, g_ref, r, b, h0, nh,
                       width):
    """g·(softmax − onehot) from a masked logits tile, zeroed at padded
    heads/buckets.  Returns (bn, nh·width) f32."""
    bn = tile3.shape[0]
    y_blk = y_ref[:, pl.ds(h0, nh)]
    lse_blk = lse_ref[:, pl.ds(h0, nh)]                       # (bn, nh)
    p = jnp.exp(tile3 - lse_blk[:, :, None])                  # softmax
    onehot = (bidx == y_blk[:, :, None]).astype(jnp.float32)
    head_ok = (h0 + jax.lax.broadcasted_iota(
        jnp.int32, (bn, nh, width), 1)) < r
    dtile3 = jnp.where((bidx < b) & head_ok,
                       g_ref[...][:, :, None] * (p - onehot), 0.0)
    return dtile3.reshape(bn, nh * width)


# ---------------------------------------------------------------------------
# Dense-h kernel bodies
# ---------------------------------------------------------------------------

def _fwd_body(bn, bc, r, rp, b, bp,
              h_ref, w_ref, y_ref, loss_ref, lse_ref,
              m_scr, s_scr, p_scr):
    """Forward step: online per-head (max, sumexp, picked) accumulation.
    h_ref (bn, d); w_ref (d, bc); y_ref (bn, rp); scratch (bn, rp)."""
    kblk = pl.program_id(1)
    nkb = pl.num_programs(1)
    nh, width, h0, boff = _tile_geometry(bc, bp, kblk)

    @pl.when(kblk == 0)
    def _init():
        m_scr[...] = jnp.full((bn, rp), NEG_INF, jnp.float32)
        s_scr[...] = jnp.zeros((bn, rp), jnp.float32)
        p_scr[...] = jnp.zeros((bn, rp), jnp.float32)

    tile3, bidx = _masked_tile(h_ref, w_ref, bn, nh, width, boff, b)
    _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh)

    @pl.when(kblk == nkb - 1)
    def _flush():
        _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr)


def _dlogits_tile(h_ref, w_ref, y_ref, lse_ref, g_ref,
                  bn, bc, r, b, bp, kblk):
    """Recompute the dense logits tile and form g·(softmax − onehot)."""
    nh, width, h0, boff = _tile_geometry(bc, bp, kblk)
    tile3, bidx = _masked_tile(h_ref, w_ref, bn, nh, width, boff, b)
    return _dlogits_from_tile(tile3, bidx, y_ref, lse_ref, g_ref, r, b,
                              h0, nh, width)


def _bwd_body(bn, bc, d, r, rp, b, bp,
              h_ref, w_ref, y_ref, lse_ref, g_ref,
              dh_ref, dw_ref, dw_acc):
    """Single-recompute backward;  grid (C/bc, N/bn), N minor.

    Per step the dlogits tile is formed ONCE and feeds both grads:
    dW_blk = Σ_i h_iᵀ @ dlogits accumulates in (d, bc) scratch (the N
    blocks are consecutive, flushed at the last); dh_i += dlogits @
    W_blkᵀ accumulates through the revisited (bn, d) output block —
    initialized at the first column block, read-modify-written on each
    revisit (f32; cast to h's dtype happens outside)."""
    kblk = pl.program_id(0)
    iblk = pl.program_id(1)
    nib = pl.num_programs(1)

    @pl.when(iblk == 0)
    def _init():
        dw_acc[...] = jnp.zeros((d, bc), jnp.float32)

    dtile = _dlogits_tile(h_ref, w_ref, y_ref, lse_ref, g_ref,
                          bn, bc, r, b, bp, kblk)
    dw_acc[...] += jax.lax.dot_general(
        h_ref[...].astype(jnp.float32), dtile,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (d, bc)
    dh_contrib = jax.lax.dot_general(
        dtile, w_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # (bn, d)

    @pl.when(kblk == 0)
    def _dh_first():
        dh_ref[...] = dh_contrib

    @pl.when(kblk > 0)
    def _dh_acc():
        dh_ref[...] += dh_contrib

    @pl.when(iblk == nib - 1)
    def _flush():
        dw_ref[...] = dw_acc[...].astype(dw_ref.dtype)


# ---------------------------------------------------------------------------
# Sparse-h (padded-ELL) kernel bodies
# ---------------------------------------------------------------------------

def _sparse_fwd_body(bn, bc, bd, r, rp, b, bp, jp,
                     cols_ref, vals_ref, w_ref, y_ref, loss_ref, lse_ref,
                     acc_scr, m_scr, s_scr, p_scr):
    """Forward;  grid (N/bn, C/bc, D/bd), d minor.  The logits tile
    accumulates over d blocks in (bn, bc) scratch from in-VMEM densified
    activation slices; the online reduction fires once per column block
    at the last d block."""
    jblk = pl.program_id(1)
    kd = pl.program_id(2)
    njb = pl.num_programs(1)
    nkd = pl.num_programs(2)

    @pl.when((jblk == 0) & (kd == 0))
    def _init_stats():
        m_scr[...] = jnp.full((bn, rp), NEG_INF, jnp.float32)
        s_scr[...] = jnp.zeros((bn, rp), jnp.float32)
        p_scr[...] = jnp.zeros((bn, rp), jnp.float32)

    @pl.when(kd == 0)
    def _init_acc():
        acc_scr[...] = jnp.zeros((bn, bc), jnp.float32)

    a = _densify_tile(cols_ref, vals_ref, kd * bd, bn, jp, bd)
    acc_scr[...] += jnp.dot(a, w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kd == nkd - 1)
    def _reduce():
        nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
        tile3, bidx = _mask_tile3(acc_scr[...], bn, nh, width, boff, b)
        _online_update(tile3, bidx, y_ref, m_scr, s_scr, p_scr, h0, nh)

        @pl.when(jblk == njb - 1)
        def _flush():
            _flush_stats(r, loss_ref, lse_ref, m_scr, s_scr, p_scr)


def _sparse_bwd_body(bn, bc, bd, nkd, r, rp, b, bp, jp,
                     cols_ref, vals_ref, w_ref, y_ref, lse_ref,
                     g_ref, dw_ref, acc_scr, dlog_scr):
    """Single-recompute backward;  grid (C/bc, N/bn, 2·D/bd).

    Per (column block, row block) the d axis is swept twice: phase 1
    (k2 < nkd) rebuilds the logits tile once and forms dlogits into
    scratch at its last step; phase 2 scatter-adds dW_blk += A_kᵀ @
    dlogits through the revisited output block — initialized at the
    first row block, read-modify-written on later revisits (phase-1
    steps map the same block but leave it untouched).  Only W rows hit
    by active features receive nonzero updates — a sparse scatter-add
    at (bd, bc) granularity."""
    jblk = pl.program_id(0)
    iblk = pl.program_id(1)
    k2 = pl.program_id(2)

    @pl.when(k2 < nkd)
    def _logits_phase():
        @pl.when(k2 == 0)
        def _init():
            acc_scr[...] = jnp.zeros((bn, bc), jnp.float32)

        a = _densify_tile(cols_ref, vals_ref, k2 * bd, bn, jp, bd)
        acc_scr[...] += jnp.dot(a, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

        @pl.when(k2 == nkd - 1)
        def _dlog():
            nh, width, h0, boff = _tile_geometry(bc, bp, jblk)
            tile3, bidx = _mask_tile3(acc_scr[...], bn, nh, width, boff, b)
            dlog_scr[...] = _dlogits_from_tile(
                tile3, bidx, y_ref, lse_ref, g_ref, r, b, h0, nh, width)

    @pl.when(k2 >= nkd)
    def _dw_phase():
        a = _densify_tile(cols_ref, vals_ref, (k2 - nkd) * bd, bn, jp, bd)
        contrib = jax.lax.dot_general(
            a, dlog_scr[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bd, bc)

        @pl.when(iblk == 0)
        def _dw_first():
            dw_ref[...] = contrib

        @pl.when(iblk > 0)
        def _dw_acc():
            dw_ref[...] += contrib


# ---------------------------------------------------------------------------
# Dense-h entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def mach_fused_xent_pallas(h2: jnp.ndarray, w: jnp.ndarray,
                           hashed_labels: jnp.ndarray,
                           num_buckets: int,
                           block_n: Optional[int] = None,
                           block_c: Optional[int] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE, straight from hidden states.

    h2 (N, d); w (d, R·B); hashed_labels (N, R) int32 -> (N,) f32.
    Differentiable: the VJP yields (dh, dW) without ever forming the
    (N, R·B) logits tensor."""
    out, _ = _fused_fwd(h2, w, hashed_labels, num_buckets, block_n,
                        block_c, interpret)
    return out


def _fused_call(kind, h2p, wp, yp, lsep, gp, dims, bn, bc, interpret):
    """Shared pallas_call builder for the dense forward/backward."""
    npad, d, r, rp, b, bp, c = dims
    n_spec = pl.BlockSpec((bn, d), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((d, bc), lambda i, j: (0, j))
    row_spec = lambda width: pl.BlockSpec((bn, width), lambda i, j: (i, 0))
    if kind == "fwd":
        return pl.pallas_call(
            functools.partial(_fwd_body, bn, bc, r, rp, b, bp),
            grid=(npad // bn, c // bc),
            in_specs=[n_spec, w_spec, row_spec(rp)],
            out_specs=(row_spec(1), row_spec(rp)),
            out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                       jax.ShapeDtypeStruct((npad, rp), jnp.float32)),
            scratch_shapes=[pltpu.VMEM((bn, rp), jnp.float32)] * 3,
            compiler_params=_SEQUENTIAL2,
            interpret=interpret,
        )(h2p, wp, yp)
    # bwd: column blocks outer, N minor; dh a revisited accumulator
    cn_spec = pl.BlockSpec((bn, d), lambda j, i: (i, 0))
    cw_spec = pl.BlockSpec((d, bc), lambda j, i: (0, j))
    crow_spec = lambda width: pl.BlockSpec((bn, width), lambda j, i: (i, 0))
    return pl.pallas_call(
        functools.partial(_bwd_body, bn, bc, d, r, rp, b, bp),
        grid=(c // bc, npad // bn),
        in_specs=[cn_spec, cw_spec, crow_spec(rp), crow_spec(rp),
                  crow_spec(1)],
        out_specs=(cn_spec, cw_spec),
        out_shape=(jax.ShapeDtypeStruct((npad, d), jnp.float32),
                   jax.ShapeDtypeStruct((d, c), wp.dtype)),
        scratch_shapes=[pltpu.VMEM((d, bc), jnp.float32)],
        compiler_params=_SEQUENTIAL2,
        interpret=interpret,
    )(h2p, wp, yp, lsep, gp)


def _check_shapes(h2, w, hashed_labels, num_buckets):
    n, d = h2.shape
    r = hashed_labels.shape[-1]
    if hashed_labels.shape != (n, r):
        raise ValueError(f"labels {hashed_labels.shape} vs h {h2.shape}")
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    return n, d, r


def _fused_fwd(h2, w, hashed_labels, num_buckets, block_n, block_c,
               interpret):
    n, d, r = _check_shapes(h2, w, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c)
    h2p, wp, yp = _pad_operands(h2, w, hashed_labels, r, b, bn, rp, bp)
    dims = (h2p.shape[0], d, r, rp, b, bp, rp * bp)
    loss, lse = _fused_call("fwd", h2p, wp, yp, None, None, dims, bn, bc,
                            interpret)
    return loss[:n, 0], (h2, w, hashed_labels, lse[:n])


def _fused_bwd(num_buckets, block_n, block_c, interpret, res, g):
    h2, w, hashed_labels, lse = res
    n, d, r = _check_shapes(h2, w, hashed_labels, num_buckets)
    b = num_buckets
    bn, bc, rp, bp = choose_fused_blocks(n, d, r, b, block_n, block_c)
    h2p, wp, yp = _pad_operands(h2, w, hashed_labels, r, b, bn, rp, bp)
    npad = h2p.shape[0]
    dims = (npad, d, r, rp, b, bp, rp * bp)
    # padded rows/heads carry zero cotangent -> zero dlogits
    gp = jnp.pad(g.astype(jnp.float32).reshape(n, 1),
                 ((0, npad - n), (0, 0)))
    lsep = jnp.pad(lse, ((0, npad - n), (0, 0)))
    dhp, dwp = _fused_call("bwd", h2p, wp, yp, lsep, gp, dims, bn, bc,
                           interpret)
    dh = dhp[:n].astype(h2.dtype)
    dw = dwp.reshape(d, rp, bp)[:, :r, :b].reshape(d, r * b)
    return dh, dw, None


mach_fused_xent_pallas.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# Sparse-h (padded-ELL) entry point
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def mach_fused_xent_sparse_pallas(cols: jnp.ndarray, vals: jnp.ndarray,
                                  w: jnp.ndarray,
                                  hashed_labels: jnp.ndarray,
                                  num_buckets: int,
                                  block_n: Optional[int] = None,
                                  block_c: Optional[int] = None,
                                  block_d: Optional[int] = None,
                                  interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE from a padded-ELL sparse batch.

    cols/vals (N, J) — row n's active feature ids and weights (padding
    carries val 0; duplicate ids sum); w (d, R·B); hashed_labels (N, R)
    int32 -> (N,) f32.  Neither the (N, R·B) logits tensor nor a dense
    (N, d) activation ever exists in HBM in either pass.  Differentiable
    wrt w only — ``vals`` is data, not a parameter, and receives a zero
    cotangent (use the densified reference if you need feature grads)."""
    out, _ = _sparse_fwd(cols, vals, w, hashed_labels, num_buckets,
                         block_n, block_c, block_d, interpret)
    return out


def _sparse_call(kind, colsp, valsp, wp, yp, lsep, gp, dims, bn, bc, bd,
                 jp, interpret):
    """Shared pallas_call builder for the sparse forward/backward."""
    npad, dp, r, rp, b, bp, c = dims
    nkd = dp // bd
    if kind == "fwd":
        ell_spec = pl.BlockSpec((bn, jp), lambda i, j, k: (i, 0))
        w_spec = pl.BlockSpec((bd, bc), lambda i, j, k: (k, j))
        row_spec = lambda width: pl.BlockSpec((bn, width),
                                              lambda i, j, k: (i, 0))
        return pl.pallas_call(
            functools.partial(_sparse_fwd_body, bn, bc, bd, r, rp, b, bp,
                              jp),
            grid=(npad // bn, c // bc, nkd),
            in_specs=[ell_spec, ell_spec, w_spec, row_spec(rp)],
            out_specs=(row_spec(1), row_spec(rp)),
            out_shape=(jax.ShapeDtypeStruct((npad, 1), jnp.float32),
                       jax.ShapeDtypeStruct((npad, rp), jnp.float32)),
            scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32)]
            + [pltpu.VMEM((bn, rp), jnp.float32)] * 3,
            compiler_params=_SEQUENTIAL3,
            interpret=interpret,
        )(colsp, valsp, wp, yp)
    # bwd: both phases of a (j, i) cell map the same dW/W d-block
    kmap = lambda k2: jnp.where(k2 >= nkd, k2 - nkd, k2)
    dw_spec = pl.BlockSpec((bd, bc), lambda j, i, k2: (kmap(k2), j))
    ell_spec = pl.BlockSpec((bn, jp), lambda j, i, k2: (i, 0))
    row_spec = lambda width: pl.BlockSpec((bn, width),
                                          lambda j, i, k2: (i, 0))
    return pl.pallas_call(
        functools.partial(_sparse_bwd_body, bn, bc, bd, nkd, r, rp, b, bp,
                          jp),
        grid=(c // bc, npad // bn, 2 * nkd),
        in_specs=[ell_spec, ell_spec, dw_spec, row_spec(rp),
                  row_spec(rp), row_spec(1)],
        out_specs=dw_spec,
        out_shape=jax.ShapeDtypeStruct((dp, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bc), jnp.float32),
                        pltpu.VMEM((bn, bc), jnp.float32)],
        compiler_params=_SEQUENTIAL3,
        interpret=interpret,
    )(colsp, valsp, wp, yp, lsep, gp)


def _check_sparse_shapes(cols, vals, w, hashed_labels, num_buckets):
    n, j = cols.shape
    d = w.shape[0]
    r = hashed_labels.shape[-1]
    if vals.shape != (n, j):
        raise ValueError(f"vals {vals.shape} vs cols {cols.shape}")
    if hashed_labels.shape != (n, r):
        raise ValueError(f"labels {hashed_labels.shape} vs cols "
                         f"{cols.shape}")
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    return n, d, r, j


def _sparse_fwd(cols, vals, w, hashed_labels, num_buckets, block_n,
                block_c, block_d, interpret):
    n, d, r, j = _check_sparse_shapes(cols, vals, w, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(n, d, r, b, j, block_n,
                                                  block_c, block_d)
    colsp, valsp, wp, yp, dp = _pad_sparse_operands(
        cols, vals, w, hashed_labels, r, b, bn, rp, bp, bd, jp)
    dims = (colsp.shape[0], dp, r, rp, b, bp, rp * bp)
    loss, lse = _sparse_call("fwd", colsp, valsp, wp, yp, None, None,
                             dims, bn, bc, bd, jp, interpret)
    return loss[:n, 0], (cols, vals, w, hashed_labels, lse[:n])


def _sparse_bwd(num_buckets, block_n, block_c, block_d, interpret, res, g):
    cols, vals, w, hashed_labels, lse = res
    n, d, r, j = _check_sparse_shapes(cols, vals, w, hashed_labels,
                                      num_buckets)
    b = num_buckets
    bn, bc, bd, rp, bp, jp = choose_sparse_blocks(n, d, r, b, j, block_n,
                                                  block_c, block_d)
    colsp, valsp, wp, yp, dp = _pad_sparse_operands(
        cols, vals, w, hashed_labels, r, b, bn, rp, bp, bd, jp)
    npad = colsp.shape[0]
    dims = (npad, dp, r, rp, b, bp, rp * bp)
    gp = jnp.pad(g.astype(jnp.float32).reshape(n, 1),
                 ((0, npad - n), (0, 0)))
    lsep = jnp.pad(lse, ((0, npad - n), (0, 0)))
    dwp = _sparse_call("bwd", colsp, valsp, wp, yp, lsep, gp, dims, bn,
                       bc, bd, jp, interpret)
    dw = dwp.reshape(dp, rp, bp)[:d, :r, :b].reshape(d, r * b)
    # features are data: zero cotangent for vals, none for int cols/labels
    return None, jnp.zeros_like(vals), dw.astype(w.dtype), None


mach_fused_xent_sparse_pallas.defvjp(_sparse_fwd, _sparse_bwd)
