"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the Pallas kernels run natively; elsewhere
(this CPU container) they run with ``interpret=True`` when
``use_pallas=True`` is forced (tests) and otherwise fall back to the
pure-jnp reference, which is semantically identical.  Call sites never
branch on platform themselves.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.mach_candidates import (mach_candidate_topk,
                                           mach_candidate_topk_pallas)
from repro.kernels.mach_decode import mach_decode_pallas
from repro.kernels.mach_fused_xent import (GATHER_NNZ_THRESHOLD,
                                           choose_sparse_blocks,
                                           mach_fused_xent_gather_pallas,
                                           mach_fused_xent_pallas,
                                           mach_fused_xent_sparse_pallas)
from repro.kernels.mach_topk import mach_topk_pallas
from repro.kernels.mach_xent import mach_xent_pallas
from repro.kernels.lru_scan import lru_scan_pallas

# candidate_mode values accepted by mach_topk: None (streaming), the
# string "exact" (streaming, spelled as a knob setting), or an (m, t)
# tuple routing through the count-min candidate filter.
CANDIDATE_EXACT = "exact"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _table_from_inline(inline_coeffs: jnp.ndarray, inline_shift: int,
                       num_classes: int) -> jnp.ndarray:
    """Rebuild the (R, K) bucket table from multiply-shift coefficients
    (reference paths only — the kernels hash in-register)."""
    k = jnp.arange(num_classes, dtype=jnp.uint32)
    prod = inline_coeffs[:, None] * k[None, :]       # wraps mod 2^32
    return jax.lax.shift_right_logical(
        prod, jnp.uint32(inline_shift)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# MACH decode
# ---------------------------------------------------------------------------

def mach_top1(meta_probs: jnp.ndarray,
              table: Optional[jnp.ndarray] = None,
              *,
              num_classes: int,
              inline_coeffs: Optional[jnp.ndarray] = None,
              inline_shift: Optional[int] = None,
              use_pallas: Optional[bool] = None,
              interpret: Optional[bool] = None
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-1 class under the summed-score rule (≡ unbiased-estimator argmax).

    meta_probs: (..., R, B) — leading dims flattened internally.
    Returns (values (...,) f32, indices (...,) int32).
    """
    lead = meta_probs.shape[:-2]
    r, b = meta_probs.shape[-2:]
    flat = meta_probs.reshape((-1, r, b))
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        val, idx = mach_decode_pallas(
            flat, table, num_classes=num_classes,
            inline_coeffs=inline_coeffs, inline_shift=inline_shift,
            interpret=interp)
    else:
        if table is None:
            table = _table_from_inline(inline_coeffs, inline_shift,
                                       num_classes)
        # gather-based scores (O(N·K·R) bytes) — the right CPU algorithm;
        # the one-hot-matmul form (ref.mach_decode_ref, the TPU kernel's
        # oracle) builds an O(K·R·B) one-hot regardless of N
        meta = jnp.moveaxis(flat.astype(jnp.float32), 1, 0)   # (R, N, B)
        g = jnp.take_along_axis(
            meta, table[:, None, :].astype(jnp.int32), axis=-1)  # (R, N, K)
        scores = jnp.sum(g, axis=0)
        idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
        val = jnp.max(scores, axis=-1)
    return val.reshape(lead), idx.reshape(lead)


def _blocked_topk_fallback(flat: jnp.ndarray, table: jnp.ndarray, k: int,
                           estimator: str, block_k: int = 8192
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming CPU top-k: scan K in blocks, gather (R, N, bk) per
    block, reduce, merge into a running top-k with a stable run-first
    sort (ties keep the lowest class id, matching lax.top_k on the full
    matrix — and the kernel's merge).  Replaces the full-matrix
    reference fallback whose one (R, N, K) gather + (N, K) top_k was
    the K=50k benchmark cliff; memory stays O(N·(R·bk + k)).
    """
    n, r, b = flat.shape
    num_classes = table.shape[1]
    bk = max(block_k, k)
    nb = -(-num_classes // bk)
    tpad = jnp.pad(table, ((0, 0), (0, nb * bk - num_classes)))
    meta = jnp.moveaxis(flat.astype(jnp.float32), 1, 0)        # (R, N, B)
    blocks = tpad.reshape(r, nb, bk).transpose(1, 0, 2)        # (nb, R, bk)

    def body(carry, blk):
        rv, ri, base = carry
        tb, kbase = blk
        g = jnp.take_along_axis(meta, tb[:, None, :].astype(jnp.int32),
                                axis=-1)                       # (R, N, bk)
        if estimator == "unbiased":
            s = jnp.mean(g, axis=0)      # affine Eq. 2 map applied at the end
        elif estimator == "min":
            s = jnp.min(g, axis=0)
        else:
            s = jnp.median(g, axis=0)
        gidx = kbase + jnp.arange(bk, dtype=jnp.int32)
        s = jnp.where(gidx[None, :] < num_classes, s, -jnp.inf)
        bv, bp = jax.lax.top_k(s, k)
        cv = jnp.concatenate([rv, bv], axis=-1)
        ci = jnp.concatenate([ri, kbase + bp.astype(jnp.int32)], axis=-1)
        nv, ni = jax.lax.sort((-cv, ci), dimension=-1, is_stable=True,
                              num_keys=1)
        return (-nv[:, :k], ni[:, :k], base), None

    init = (jnp.full((n, k), -jnp.inf, jnp.float32),
            jnp.zeros((n, k), jnp.int32), 0)
    kbases = jnp.arange(nb, dtype=jnp.int32) * bk
    (val, idx, _), _ = jax.lax.scan(body, init, (blocks, kbases))
    if estimator == "unbiased":
        val = (b / (b - 1.0)) * (val - 1.0 / b)
    return val, idx


def mach_topk(meta_probs: jnp.ndarray,
              table: Optional[jnp.ndarray] = None,
              *,
              num_classes: int,
              k: int,
              estimator: str = "unbiased",
              inline_coeffs: Optional[jnp.ndarray] = None,
              inline_shift: Optional[int] = None,
              candidate_mode=None,
              inverted: Optional[jnp.ndarray] = None,
              use_pallas: Optional[bool] = None,
              interpret: Optional[bool] = None
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k classes under any paper estimator (unbiased | min | median).

    meta_probs: (..., R, B) — leading dims flattened internally.
    Returns (values (..., k) f32, indices (..., k) int32) on the
    estimator's scale, matching ``estimate_class_probs`` + ``lax.top_k``
    up to tie order.  The Pallas path streams a running top-k across K
    blocks in VMEM and never materializes the (batch, K) score matrix;
    the fallback streams K in blocked gathers under a lax.scan (same
    semantics, bounded memory).

    ``candidate_mode`` selects the decode algorithm: ``None`` or
    ``"exact"`` stream all K classes; an ``(m, t)`` tuple routes
    through the count-min candidate filter (``mach_topk_candidates`` —
    requires ``inverted``, the table from ``hashing.inverted_table``),
    whose cost is independent of K but whose top-k is approximate
    (filtered slots come back as (-inf, -1); recall is measured by
    ``benchmarks/bench_decode_topk.py``).
    """
    if candidate_mode is not None and candidate_mode != CANDIDATE_EXACT:
        m, t = candidate_mode
        return mach_topk_candidates(
            meta_probs, table, inverted=inverted, num_classes=num_classes,
            k=k, m=m, t=t, estimator=estimator, inline_coeffs=inline_coeffs,
            inline_shift=inline_shift, use_pallas=use_pallas,
            interpret=interpret)
    if not 1 <= k <= num_classes:
        raise ValueError(f"need 1 <= k <= num_classes, got k={k}, "
                         f"num_classes={num_classes}")
    lead = meta_probs.shape[:-2]
    r, b = meta_probs.shape[-2:]
    flat = meta_probs.reshape((-1, r, b))
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        val, idx = mach_topk_pallas(
            flat, table, num_classes=num_classes, k=k, estimator=estimator,
            inline_coeffs=inline_coeffs, inline_shift=inline_shift,
            interpret=interp)
    else:
        if table is None:
            table = _table_from_inline(inline_coeffs, inline_shift,
                                       num_classes)
        # Small problems: one fused (R, N, K) gather + full top_k beats
        # the scan's per-block dispatch overhead (measured: n=8, K=50k
        # runs 1.4x slower blocked).  Large ones: blocking is what
        # removed the K=50k n=32 cliff and bounds memory at K >= 1M.
        if flat.shape[0] * num_classes * r <= 2**24:
            val, idx = ref.mach_topk_ref(flat, table, k, estimator)
        else:
            val, idx = _blocked_topk_fallback(flat, table, k, estimator)
    return val.reshape(lead + (k,)), idx.reshape(lead + (k,))


def mach_topk_candidates(meta_probs: jnp.ndarray,
                         table: Optional[jnp.ndarray] = None,
                         *,
                         inverted: jnp.ndarray,
                         num_classes: int,
                         k: int,
                         m: int,
                         t: int = 1,
                         estimator: str = "unbiased",
                         inline_coeffs: Optional[jnp.ndarray] = None,
                         inline_shift: Optional[int] = None,
                         compact_cap: int = 2048,
                         use_pallas: Optional[bool] = None,
                         interpret: Optional[bool] = None
                         ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate-filtered top-k: count-min filter over the per-repetition
    bucket top-m, then gather+score only the candidates.

    meta_probs: (..., R, B) — leading dims flattened internally;
    ``inverted`` is the (R·B, L) bucket->class table from
    ``hashing.inverted_table`` (built once per model).  Returns
    (values, indices) shaped (..., k); slots beyond the surviving
    candidates are (-inf, -1), and a row with no count>=t candidate
    backfills slot 0 with its best count>=1 candidate so serving never
    sees an empty row.  With m = B, t = R the result is exact (equal to
    the streaming path up to tie order).  Cost is O(R·B·log m +
    R·m·L·R) — independent of K.

    The fused Pallas pipeline needs inline multiply-shift hashing (it
    recomputes buckets in-register); in table mode the pure-jnp path
    runs regardless of ``use_pallas``.
    """
    lead = meta_probs.shape[:-2]
    r, b = meta_probs.shape[-2:]
    flat = meta_probs.reshape((-1, r, b))
    use = _on_tpu() if use_pallas is None else use_pallas
    if use and inline_coeffs is not None and inline_shift is not None:
        interp = (not _on_tpu()) if interpret is None else interpret
        val, idx = mach_candidate_topk_pallas(
            flat, inverted, num_classes=num_classes, k=k, m=m, t=t,
            estimator=estimator, inline_coeffs=inline_coeffs,
            inline_shift=inline_shift, interpret=interp)
    else:
        val, idx = mach_candidate_topk(
            flat, inverted, table, num_classes=num_classes, k=k, m=m, t=t,
            estimator=estimator, inline_coeffs=inline_coeffs,
            inline_shift=inline_shift, compact_cap=compact_cap)
    return val.reshape(lead + (k,)), idx.reshape(lead + (k,))


def mach_scores(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Full (…, K) score matrix — reference path (used by sampling/top-k)."""
    lead = meta_probs.shape[:-2]
    r, b = meta_probs.shape[-2:]
    g = ref.mach_scores_ref(meta_probs.reshape((-1, r, b)), table)
    return g.reshape(lead + (table.shape[1],))


# ---------------------------------------------------------------------------
# MACH fused cross entropy
# ---------------------------------------------------------------------------

def mach_xent(logits: jnp.ndarray, hashed_labels: jnp.ndarray,
              *, use_pallas: Optional[bool] = None,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-example summed R-head CE with fused fwd/bwd.

    logits: (..., R, B); hashed_labels: (..., R) -> (...,) f32.
    """
    lead = logits.shape[:-2]
    r, b = logits.shape[-2:]
    lg = logits.reshape((-1, r, b))
    lbl = hashed_labels.reshape((-1, r))
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        out = mach_xent_pallas(lg, lbl, None, interp)
    else:
        out = ref.mach_xent_ref(lg, lbl)
    return out.reshape(lead)


def csr_to_ell(indptr: jnp.ndarray, indices: jnp.ndarray,
               values: jnp.ndarray, nnz_max: int, num_features: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """CSR -> padded ELL (cols (N, nnz_max) int32, vals (N, nnz_max)).

    Row n's entries land in slots [0, len_n); padded slots carry col id
    ``num_features`` (an always-out-of-range sentinel) and val 0, so
    they contribute nothing however the kernel tiles the feature dim.
    ``nnz_max`` must be static and >= the longest row — it sets the
    kernel's J extent, and rows longer than it would be silently
    truncated (diverging from the densifying reference), so an
    undersized ``nnz_max`` is rejected whenever ``indptr`` is concrete
    (traced indptr — e.g. inside a jitted train step — relies on the
    producer honoring the contract, as ``SparseExtremeDataset`` does).
    Differentiable wrt ``values`` (a pure gather)."""
    n = indptr.shape[0] - 1
    nnz = indices.shape[0]
    if n and not isinstance(indptr, jax.core.Tracer):
        longest = int(np.max(np.diff(np.asarray(indptr))))
        if longest > nnz_max:
            raise ValueError(
                f"nnz_max={nnz_max} < longest CSR row ({longest}): the "
                f"kernel would silently truncate it")
    if nnz == 0:
        return (jnp.full((n, nnz_max), num_features, jnp.int32),
                jnp.zeros((n, nnz_max), values.dtype))
    slot = jnp.arange(nnz_max, dtype=indptr.dtype)
    pos = indptr[:-1, None] + slot[None, :]               # (N, nnz_max)
    valid = pos < indptr[1:, None]
    posc = jnp.minimum(pos, nnz - 1)
    cols = jnp.where(valid, indices[posc].astype(jnp.int32), num_features)
    vals = jnp.where(valid, values[posc], 0)
    return cols, vals


def mach_fused_xent_csr(indptr: jnp.ndarray, indices: jnp.ndarray,
                        values: jnp.ndarray, w: jnp.ndarray,
                        hashed_labels: jnp.ndarray,
                        *, num_buckets: int, nnz_max: int,
                        bias: Optional[jnp.ndarray] = None,
                        block_n: Optional[int] = None,
                        block_c: Optional[int] = None,
                        block_d: Optional[int] = None,
                        sparse_impl: Optional[str] = None,
                        bucket_select: Optional[tuple] = None,
                        bucket_proxy: Optional[jnp.ndarray] = None,
                        use_pallas: Optional[bool] = None,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sparse-feature fused projection + R-head CE (the ODP d=422k
    training path).

    indptr (N+1,), indices (nnz,), values (nnz,) — a CSR batch over d
    features; w (d, R·B) head kernel; hashed_labels (N, R) bucket ids;
    optional bias (R·B,) — a native kernel operand, broadcast-added to
    the logits tile at the last d block, so the ELL width stays exactly
    nnz_max (no unit-feature column) -> (N,) f32 per-example loss.
    ``block_n/block_c/block_d`` pin the kernel tiling (benchmarks and
    tests); None lets ``choose_sparse_blocks`` fit the VMEM budget.

    On the Pallas path neither the (N, R·B) logits tensor nor a dense
    (N, d) activation ever exists in HBM in either pass — the batch is
    re-laid-out as padded ELL (O(N·nnz_max)), and the VJP scatter-adds
    dW (and reduces dbias) without a logits round-trip.  ``sparse_impl``
    picks the kernel family: ``"densify"`` (per-tile one-hot
    densification — the low-nnz fast path), ``"gather"`` (scalar-
    prefetch DMA of the active W rows — per-step VMEM independent of
    nnz, the only viable family at bag-of-words nnz), or ``None``
    (auto: gather at nnz_max >= GATHER_NNZ_THRESHOLD or whenever the
    densify chooser cannot fit the VMEM budget).  The fallback is the
    densifying reference — the right CPU algorithm, and the parity
    oracle for both families.  Differentiable wrt w and bias;
    ``values`` gets a ZERO cotangent on the kernel path (features are
    data — use the reference if you need feature grads).

    ``bucket_select=(c_sel, refresh_every)`` routes through dynamic
    bucket selection (see ``mach_fused_xent``): the loss runs over the
    top-``c_sel`` proxy-scored bucket columns per repetition with the
    batch's label buckets force-included.  ``bucket_proxy`` optionally
    supplies cached (R, B) proxy scores (the trainer recomputes them
    every ``refresh_every`` steps); otherwise they are computed in-graph
    from the batch mean activation (a scatter-add — never a densified
    batch).
    """
    d = w.shape[0]
    r = hashed_labels.shape[-1]
    if w.shape != (d, r * num_buckets):
        raise ValueError(f"w {w.shape} != ({d}, {r}*{num_buckets})")
    if bucket_select is not None:
        c_sel = bucket_select[0]
        if c_sel < num_buckets:
            proxy = bucket_proxy if bucket_proxy is not None else \
                mach_bucket_proxy(w=w, num_buckets=num_buckets, bias=bias,
                                  csr=(indptr, indices, values))
            selected = mach_select_buckets(
                proxy, hashed_labels, num_buckets=num_buckets, c_sel=c_sel)
            return mach_fused_xent_csr_selected(
                indptr, indices, values, w, hashed_labels, selected,
                num_buckets=num_buckets, nnz_max=nnz_max, bias=bias,
                block_n=block_n, block_c=block_c, block_d=block_d,
                sparse_impl=sparse_impl, use_pallas=use_pallas,
                interpret=interpret)
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        # stop_gradient matches the kernel path's zero cotangent for
        # values (features are data, not parameters) — without it the
        # two backends would silently disagree on d/d(values)
        return ref.mach_fused_xent_csr_ref(
            indptr, indices, jax.lax.stop_gradient(values), w,
            hashed_labels.astype(jnp.int32), num_buckets, bias=bias)
    cols, vals = csr_to_ell(indptr, indices, values, nnz_max, d)
    interp = (not _on_tpu()) if interpret is None else interpret
    impl = sparse_impl
    if impl is None:
        if nnz_max >= GATHER_NNZ_THRESHOLD:
            impl = "gather"
        else:
            try:
                choose_sparse_blocks(indptr.shape[0] - 1, d, r,
                                     num_buckets, nnz_max, block_n,
                                     block_c, block_d)
                impl = "densify"
            except ValueError:
                impl = "gather"
    if impl == "gather":
        return mach_fused_xent_gather_pallas(
            cols, vals, w, bias, hashed_labels.astype(jnp.int32),
            num_buckets, block_c, interp)
    if impl != "densify":
        raise ValueError(f"sparse_impl must be 'densify', 'gather' or "
                         f"None, got {sparse_impl!r}")
    return mach_fused_xent_sparse_pallas(
        cols, vals, w, bias, hashed_labels.astype(jnp.int32),
        num_buckets, block_n, block_c, block_d, interp)


def mach_fused_xent(h: jnp.ndarray, w: jnp.ndarray,
                    hashed_labels: jnp.ndarray,
                    *, num_buckets: int,
                    bias: Optional[jnp.ndarray] = None,
                    block_n: Optional[int] = None,
                    block_c: Optional[int] = None,
                    block_d: Optional[int] = None,
                    bucket_select: Optional[tuple] = None,
                    bucket_proxy: Optional[jnp.ndarray] = None,
                    use_pallas: Optional[bool] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Logit-free fused projection + R-head CE (training fast path).

    h: (..., d) hidden states; w: (d, R·B) head kernel;
    hashed_labels: (..., R) bucket ids; optional bias (R·B,) — a native
    kernel operand (no (d+1, R·B) W-concat) -> (...,) f32 per-example
    loss.  ``block_n/block_c/block_d`` pin the kernel tiling
    (benchmarks and tests); None lets ``choose_fused_blocks`` fit the
    VMEM budget.

    On the Pallas path the (…, R·B) logits tensor never exists in HBM
    in either the forward or the backward pass, and W/h stream through
    d-blocked VMEM tiles (activation memory is O(N·d + N·R), per-step
    VMEM independent of d); the fallback is the materializing reference
    — the right CPU algorithm, and the parity oracle.  Differentiable
    wrt h, w and bias (custom VJP with recomputing backward kernels).

    ``bucket_select=(c_sel, refresh_every)`` enables dynamic bucket
    selection (arxiv 1801.01687's dynamic class selection, hashed to
    MACH buckets): a cheap proxy scores all R·B bucket columns, the
    top-``c_sel`` per repetition are kept — the batch's label buckets
    force-included, so the positive CE term is exact and the bias is
    one-sided and bounded (``ref.mach_selected_bias_bound_ref``) — and
    the fused loss runs over the selected C-subset, cutting the
    kernel's C-axis ``num_buckets/c_sel``-fold.  ``bucket_proxy``
    optionally supplies cached (R, B) proxy scores; ``refresh_every``
    is the producer-side cadence for that cache (``train.Trainer``
    honors it) — selection itself is recomputed every call, so label
    force-inclusion always reflects the current batch.  With
    ``bucket_select=None`` this is bit-identical to the unselected
    path.
    """
    lead = h.shape[:-1]
    d = h.shape[-1]
    r = hashed_labels.shape[-1]
    if bucket_select is not None:
        c_sel = bucket_select[0]
        if c_sel < num_buckets:
            proxy = bucket_proxy if bucket_proxy is not None else \
                mach_bucket_proxy(h, w, num_buckets=num_buckets, bias=bias)
            selected = mach_select_buckets(
                proxy, hashed_labels, num_buckets=num_buckets, c_sel=c_sel)
            return mach_fused_xent_selected(
                h, w, hashed_labels, selected, num_buckets=num_buckets,
                bias=bias, block_n=block_n, block_c=block_c,
                block_d=block_d, use_pallas=use_pallas,
                interpret=interpret)
    h2 = h.reshape((-1, d))
    lbl = hashed_labels.reshape((-1, r)).astype(jnp.int32)
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        out = mach_fused_xent_pallas(h2, w, bias, lbl, num_buckets,
                                     block_n, block_c, block_d, interp)
    else:
        out = ref.mach_fused_xent_ref(h2, w, lbl, num_buckets, bias=bias)
    return out.reshape(lead)


# ---------------------------------------------------------------------------
# Dynamic bucket selection (training-time C-axis cut)
# ---------------------------------------------------------------------------

def mach_bucket_proxy(h: Optional[jnp.ndarray] = None,
                      w: Optional[jnp.ndarray] = None,
                      *, num_buckets: int,
                      bias: Optional[jnp.ndarray] = None,
                      csr: Optional[tuple] = None) -> jnp.ndarray:
    """Cheap (R, B) bucket proxy scores: the logits of the batch-mean
    activation.  Dense: ``h (..., d)``; sparse: pass
    ``csr=(indptr, indices, values)`` instead of ``h`` (the mean is a
    scatter-add — no densified batch).  One d·R·B matvec, 1/N of the
    full projection, and cacheable across steps — ``ref.py`` holds the
    math (pure jnp on every backend); gradients are stopped (the proxy
    only *ranks* buckets; it must not add a loss term)."""
    if csr is not None:
        out = ref.mach_bucket_proxy_csr_ref(*csr, w, num_buckets,
                                            bias=bias)
    else:
        out = ref.mach_bucket_proxy_ref(h.reshape((-1, h.shape[-1])), w,
                                        num_buckets, bias=bias)
    return jax.lax.stop_gradient(out)


def mach_select_buckets(proxy_scores: jnp.ndarray,
                        hashed_labels: jnp.ndarray,
                        *, num_buckets: int, c_sel: int) -> jnp.ndarray:
    """Top-``c_sel`` bucket columns per repetition by proxy score with
    the batch's label buckets force-included -> (R, c_sel) int32,
    sorted ascending.  Pure jnp on every backend (a (R, B) top_k —
    negligible next to the loss); ``ref.py`` holds the math."""
    lbl = hashed_labels.reshape((-1, hashed_labels.shape[-1]))
    return ref.mach_select_buckets_ref(proxy_scores,
                                       lbl.astype(jnp.int32),
                                       num_buckets, c_sel)


def _apply_bucket_selection(w, bias, lbl, selected, num_buckets):
    """Gather the selected W/bias columns and remap labels to their
    position inside the selection.  The gather is indexing (an axis-1
    gather of whole (d,) column slices — one gather op, not a
    per-repetition ``take_along_axis`` over the minor axis), so the
    VJP scatter-adds dW back into the selected columns and every
    unselected column receives exactly zero gradient.  Gather and
    scatter are O(d·R·c_sel) *per step*, independent of the batch,
    while the fused-loss saving is per example — selection pays off
    once N amortizes the column traffic (any realistic batch)."""
    r, c_sel = selected.shape
    d = w.shape[0]
    flat = (jnp.arange(r, dtype=selected.dtype)[:, None] * num_buckets
            + selected).reshape(-1)                      # (R·c_sel,)
    wsel = w[:, flat]
    bsel = None if bias is None else bias[flat]
    pos = jnp.argmax(selected[None, :, :] == lbl[:, :, None],
                     axis=-1).astype(jnp.int32)
    return wsel, bsel, pos


def mach_fused_xent_selected(h: jnp.ndarray, w: jnp.ndarray,
                             hashed_labels: jnp.ndarray,
                             selected: jnp.ndarray,
                             *, num_buckets: int,
                             bias: Optional[jnp.ndarray] = None,
                             block_n: Optional[int] = None,
                             block_c: Optional[int] = None,
                             block_d: Optional[int] = None,
                             use_pallas: Optional[bool] = None,
                             interpret: Optional[bool] = None
                             ) -> jnp.ndarray:
    """Fused projection+CE over a selected bucket subset.

    ``selected`` (R, c_sel) int32 — from ``mach_select_buckets``, which
    force-includes every label bucket (required: a label outside its
    head's selection would silently remap to position 0).  The W/bias
    columns are gathered and the ordinary fused op runs at B′ = c_sel,
    so the kernel C-axis shrinks ``num_buckets/c_sel``-fold; unselected
    W columns get exactly zero gradient (take_along_axis VJP).  The
    loss is a lower bound on the full loss: exact positive term,
    logsumexp over a subset — one-sided bias, bounded per example by
    ``ref.mach_selected_bias_bound_ref``."""
    r, c_sel = selected.shape
    lbl = hashed_labels.reshape((-1, r)).astype(jnp.int32)
    wsel, bsel, pos = _apply_bucket_selection(w, bias, lbl, selected,
                                              num_buckets)
    return mach_fused_xent(
        h, wsel, pos.reshape(hashed_labels.shape), num_buckets=c_sel,
        bias=bsel, block_n=block_n, block_c=block_c, block_d=block_d,
        use_pallas=use_pallas, interpret=interpret)


def mach_fused_xent_csr_selected(indptr: jnp.ndarray,
                                 indices: jnp.ndarray,
                                 values: jnp.ndarray, w: jnp.ndarray,
                                 hashed_labels: jnp.ndarray,
                                 selected: jnp.ndarray,
                                 *, num_buckets: int, nnz_max: int,
                                 bias: Optional[jnp.ndarray] = None,
                                 block_n: Optional[int] = None,
                                 block_c: Optional[int] = None,
                                 block_d: Optional[int] = None,
                                 sparse_impl: Optional[str] = None,
                                 use_pallas: Optional[bool] = None,
                                 interpret: Optional[bool] = None
                                 ) -> jnp.ndarray:
    """CSR counterpart of ``mach_fused_xent_selected`` — gathers the
    selected W/bias columns and runs ``mach_fused_xent_csr`` at
    B′ = c_sel (same one-sided, bounded bias; same zero gradient on
    unselected columns)."""
    r, c_sel = selected.shape
    lbl = hashed_labels.reshape((-1, r)).astype(jnp.int32)
    wsel, bsel, pos = _apply_bucket_selection(w, bias, lbl, selected,
                                              num_buckets)
    return mach_fused_xent_csr(
        indptr, indices, values, wsel, pos, num_buckets=c_sel,
        nnz_max=nnz_max, bias=bsel, block_n=block_n, block_c=block_c,
        block_d=block_d, sparse_impl=sparse_impl, use_pallas=use_pallas,
        interpret=interpret)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

def lru_scan(a: jnp.ndarray, x: jnp.ndarray, h0: jnp.ndarray,
             *, use_pallas: Optional[bool] = None,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Diagonal linear recurrence h_t = a_t·h_{t-1} + x_t;  (B, T, D)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        return lru_scan_pallas(a, x, h0, interpret=interp)
    return ref.lru_scan_ref(a, x, h0)


# ---------------------------------------------------------------------------
# Flash attention (fused softmax attention — the §Perf memory-term fix)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    use_pallas=None, interpret=None):
    """q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H,hd).  On TPU: the Pallas
    kernel (scores never leave VMEM); elsewhere: the exact jnp flash."""
    from repro.kernels.flash_attention import flash_attention_pallas
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=interp)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Oracle registry: every public op names its pure-jnp reference in
# kernels/ref.py.  CI lints this table (tools/lint_kernel_oracles.py) so
# the dispatch surface and the oracle set cannot drift — adding an op
# without a reference is a build failure, not a review nit.
# ---------------------------------------------------------------------------

ORACLES: dict = {
    "mach_top1": "mach_decode_ref",
    "mach_topk": "mach_topk_ref",
    "mach_topk_candidates": "mach_candidate_topk_ref",
    "mach_scores": "mach_scores_ref",
    "mach_xent": "mach_xent_ref",
    "mach_fused_xent": "mach_fused_xent_ref",
    "mach_fused_xent_csr": "mach_fused_xent_csr_ref",
    "mach_bucket_proxy": "mach_bucket_proxy_ref",
    "mach_select_buckets": "mach_select_buckets_ref",
    "mach_fused_xent_selected": "mach_fused_xent_selected_ref",
    "mach_fused_xent_csr_selected": "mach_fused_xent_csr_selected_ref",
    "csr_to_ell": "csr_densify_ref",
    "lru_scan": "lru_scan_ref",
    "flash_attention": "flash_attention_ref",
}
