"""Candidate-filtered MACH top-k decode (count-min filtering).

The streaming top-k kernel (``mach_topk.py``) is O(K log k): every one
of K classes is scored.  At retrieval scale (K = 10M+) the K-axis sweep
dominates decode even though almost no class can make the top-k.  This
module implements the sub-O(K log K) path used by the logarithmic-time
one-against-some line of work and Amazon's count-min-sketch deployment:

  1. per repetition, take the top-m buckets of the (n, R, B) meta
     probabilities (``bucket_topm`` / ``bucket_topm_pallas``);
  2. a class is a *candidate* iff it hashes into a top-m bucket in
     >= t of the R repetitions (count-min filtering);
  3. only candidates are gathered and merged through the estimator math
     (unbiased Eq. 2 / min Eq. 7 / median Eq. 8).

Candidates are enumerated through an inverted bucket -> class table
(``hashing.inverted_table``): row j·B + b lists the classes hashing to
bucket b under repetition j, padded to L (the max bucket occupancy,
lane-aligned) with the sentinel K.  The candidate pool is the
concatenation of the R·m top-bucket rows — P = R·m·L entries.  Each
class can appear up to R times in the pool; it is *claimed* exactly
once, by the first repetition whose top-m contains it, so the top-k
never returns duplicates.

Cost: O(R·B log m) for the bucket top-m + O(P·R) for the filtered
gather+score, with P = R·m·L independent of K — vs the streaming
path's O(K·R/B · ...) sweep.  No (n, K) tensor exists anywhere on this
path (tested by a jaxpr gate).

Exactness: with m = B and t = R every class is claimed by repetition 0
and has count R, so the pool scores are exactly the streaming scores —
the mode is provably identical to the streaming path (up to tie
order).  Looser (m, t) trade recall for speed; the benchmark gate
measures recall@k.

Rows with zero count->=t candidates fall back to the best count>=1
candidate (the "t=1 backfill") so serving never samples from an empty
set.  The backfill rides in the same top-k via a penalty-offset score
encoding — OFFSET is larger than the estimator score range, so
penalized entries sort strictly below every valid one and are decoded
(or discarded) after the top-k on the small (n, k) result.

Two implementations with identical semantics:
  * ``mach_candidate_topk`` — pure jnp (CPU fallback + table mode);
  * ``mach_candidate_topk_pallas`` — fused Pallas filter->gather->score
    pipeline (inline multiply-shift mode): the inverted-table rows are
    DMA-gathered per chunk via scalar-prefetched bucket ids, hashes are
    recomputed in-register, and scores merge into a running top-k in
    VMEM scratch — candidates never round-trip through HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.estimators import ESTIMATORS
from repro.kernels.mach_decode import NEG_INF, round_up
from repro.kernels.mach_topk import _LANE, _merge_topk

# Penalty subtracted from backfill (count < t) candidate scores.  Every
# estimator score lies in (-1/(B-1), 1], so subtracting OFFSET maps the
# backfill band to (-OFFSET - eps, -OFFSET + 1] — disjoint from and
# strictly below the valid band, and far above NEG_INF/2 (the
# "unclaimed" sentinel), letting one top-k rank valid > backfill > dead.
OFFSET = 4.0


def _affine_unbiased(mean_g: jnp.ndarray, b: int) -> jnp.ndarray:
    """Eq. 2: B/(B-1) · (mean_j g_j − 1/B)."""
    return (b / (b - 1.0)) * (mean_g - 1.0 / b)


def _median_sorted(g_sorted: jnp.ndarray, axis: int) -> jnp.ndarray:
    """jnp.median semantics given an already-sorted axis."""
    r = g_sorted.shape[axis]
    lo = jax.lax.index_in_dim(g_sorted, (r - 1) // 2, axis, keepdims=False)
    hi = jax.lax.index_in_dim(g_sorted, r // 2, axis, keepdims=False)
    return (lo + hi) * 0.5


def validate_candidate_args(num_classes: int, k: int, m: int, t: int,
                            r: int, b: int, estimator: str) -> None:
    if estimator not in ESTIMATORS:
        raise ValueError(f"estimator must be one of {ESTIMATORS}, "
                         f"got {estimator!r}")
    if not 1 <= k <= num_classes:
        raise ValueError(f"need 1 <= k <= num_classes, got k={k}, "
                         f"num_classes={num_classes}")
    if not 1 <= m <= b:
        raise ValueError(f"need 1 <= m <= B, got m={m}, B={b}")
    if not 1 <= t <= r:
        raise ValueError(f"need 1 <= t <= R, got t={t}, R={r}")


# ---------------------------------------------------------------------------
# Stage 1: per-repetition bucket top-m.
# ---------------------------------------------------------------------------

def bucket_topm(meta_probs: jnp.ndarray, m: int
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(n, R, B) -> (tau (n, R) f32, ids (n, R, m) int32).

    tau is the m-th largest bucket value per (row, repetition) — the
    membership threshold g >= tau used by the count-min filter.  Note
    ``jnp.min`` over the top-m values, not ``tv[..., -1]``: identical
    value, but the slice forces XLA:CPU into a pathological fusion with
    the downstream pool gather (~14x decode slowdown).
    """
    tv, ti = jax.lax.top_k(meta_probs, m)
    return jnp.min(tv, axis=-1).astype(jnp.float32), ti.astype(jnp.int32)


def _topm_body(r: int, b: int, m: int, mpad: int,
               probs_ref, ids_out, tau_out):
    """Iterative max-extract: m rounds of (max, argmax, mask) per
    repetition.  Reproduces lax.top_k's lowest-index tie order (argmax
    takes the first maximum; masking removes exactly that column)."""
    p = probs_ref[...].reshape(r, b).astype(jnp.float32)
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (r, b), 1)
    cols = []
    tau = None
    for _ in range(m):
        v = jnp.max(p, axis=-1, keepdims=True)                 # (r, 1)
        i = jnp.argmax(p, axis=-1, keepdims=True).astype(jnp.int32)
        cols.append(i)
        p = jnp.where(iota_b == i, NEG_INF, p)
        tau = v
    if mpad > m:
        cols.append(jnp.zeros((r, mpad - m), jnp.int32))
    ids_out[...] = jnp.concatenate(cols, axis=-1).reshape(1, r * mpad)
    tau_out[...] = tau.reshape(1, r)


def bucket_topm_pallas(meta_probs: jnp.ndarray, m: int,
                       interpret: bool = False
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas bucket top-m: (n, R, B) -> (tau (n, R), ids (n, R, m)).

    One grid step per row; the (R, B) tile stays in VMEM across the m
    extraction rounds.  The ids output is lane-padded internally and
    sliced back to m on the host.
    """
    n, r, b = meta_probs.shape
    if not 1 <= m <= b:
        raise ValueError(f"need 1 <= m <= B, got m={m}, B={b}")
    mpad = round_up(m, _LANE)
    ids, tau = pl.pallas_call(
        functools.partial(_topm_body, r, b, m, mpad),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, r * b), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((1, r * mpad), lambda i: (i, 0)),
                   pl.BlockSpec((1, r), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((n, r * mpad), jnp.int32),
                   jax.ShapeDtypeStruct((n, r), jnp.float32)),
        interpret=interpret,
    )(meta_probs.reshape(n, r * b))
    return tau, ids.reshape(n, r, mpad)[:, :, :m]


# ---------------------------------------------------------------------------
# Shared host-side pieces: chunk ids and penalty-offset decode.
# ---------------------------------------------------------------------------

def candidate_chunks(ids: jnp.ndarray, b: int) -> jnp.ndarray:
    """Top-m bucket ids (n, R, m) -> inverted-table row ids (n, R·m)."""
    n, r, m = ids.shape
    return (jnp.arange(r, dtype=jnp.int32)[None, :, None] * b
            + ids).reshape(n, r * m)


def decode_penalty_topk(val: jnp.ndarray, idx: jnp.ndarray, t: int
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode the penalty-offset encoding after the top-k.

    val/idx: (n, k) from a top-k over encoded scores.  Valid entries
    (count >= t) pass through; dead entries become (-inf, -1); backfill
    entries (claimed, count < t) are dropped — except that a row with
    *no* valid candidate keeps its best backfill in slot 0 (score
    restored by +OFFSET) so serving never sees an empty row.
    """
    if t <= 1:
        # valid == claimed: no backfill band was encoded
        dead = val <= NEG_INF / 2
        return (jnp.where(dead, -jnp.inf, val),
                jnp.where(dead, -1, idx))
    is_valid = val > -OFFSET / 2
    is_claimed = val > NEG_INF / 2
    keep0 = (~is_valid[:, :1]) & is_claimed[:, :1]   # row empty, has backfill
    out_val = jnp.where(is_valid, val, -jnp.inf)
    out_idx = jnp.where(is_valid, idx, -1)
    out_val = out_val.at[:, :1].set(
        jnp.where(keep0, val[:, :1] + OFFSET, out_val[:, :1]))
    out_idx = out_idx.at[:, :1].set(
        jnp.where(keep0, idx[:, :1], out_idx[:, :1]))
    return out_val, out_idx


# ---------------------------------------------------------------------------
# Pure-jnp candidate path (CPU fallback + table mode).
# ---------------------------------------------------------------------------

def mach_candidate_topk(meta_probs: jnp.ndarray,
                        inverted: jnp.ndarray,
                        table: Optional[jnp.ndarray] = None,
                        *,
                        num_classes: int,
                        k: int,
                        m: int,
                        t: int = 1,
                        estimator: str = "unbiased",
                        inline_coeffs: Optional[jnp.ndarray] = None,
                        inline_shift: Optional[int] = None,
                        compact_cap: int = 2048
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Candidate-filtered top-k, pure jnp.  meta_probs (N, R, B) ->
    (val, idx) (N, k); unclaimed/filtered slots are (-inf, -1).

    ``inverted`` is the (R·B, L) table from ``hashing.inverted_table``.
    Bucket ids for the gathered pool come from ``inline_coeffs`` /
    ``inline_shift`` (multiply-shift recompute, no table in memory) or
    from gathering ``table`` ((R, K), any hash family).

    min/median compute their order statistic on a count-prioritized
    compaction of the pool (``compact_cap`` entries — a full-pool
    median is pathological on XLA:CPU); the result is exact whenever
    the number of claimed candidates is <= compact_cap.
    """
    n, r, b = meta_probs.shape
    validate_candidate_args(num_classes, k, m, t, r, b, estimator)
    ell = inverted.shape[1]
    p_pool = r * m * ell

    tau, ids = bucket_topm(meta_probs, m)                 # (n, R), (n, R, m)
    chunk = candidate_chunks(ids, b)                      # (n, R·m)
    pool = jnp.take(inverted, chunk, axis=0).reshape(n, p_pool)

    # bucket of every pool entry under every repetition
    if table is not None:
        h = jnp.moveaxis(jnp.take(table, jnp.clip(pool, 0, num_classes - 1),
                                  axis=1), 0, -1)         # (n, P, R)
    else:
        if inline_coeffs is None or inline_shift is None:
            raise ValueError("need table or (inline_coeffs, inline_shift)")
        h = jax.lax.shift_right_logical(
            pool[..., None].astype(jnp.uint32) * inline_coeffs[None, None, :],
            jnp.uint32(inline_shift)).astype(jnp.int32)   # (n, P, R)

    flat = meta_probs.reshape(n, r * b)
    gidx = (h + (jnp.arange(r, dtype=jnp.int32) * b)[None, None, :])
    g = jnp.take_along_axis(flat, gidx.reshape(n, p_pool * r),
                            axis=-1).reshape(n, p_pool, r)

    member = g >= tau[:, None, :]                         # (n, P, R)
    count = member.sum(-1)
    first = jnp.argmax(member, -1)
    chunk_r = (jnp.arange(p_pool, dtype=jnp.int32) // (m * ell))[None]
    claimed = (first == chunk_r) & (pool < num_classes)
    valid = claimed if t <= 1 else claimed & (count >= t)

    if estimator == "unbiased":
        # one consumer of the mean score — a second ``where`` over it
        # re-triggers the XLA:CPU scalar-regather pathology
        sall = _affine_unbiased(jnp.mean(g, -1), b)
        s_enc = jnp.where(claimed,
                          sall - OFFSET * (1.0 - valid.astype(jnp.float32)),
                          NEG_INF)
        val, pos = jax.lax.top_k(s_enc, k)
        idx = jnp.take_along_axis(pool, pos, axis=-1)
        return decode_penalty_topk(val, idx, t)

    # min/median: compact to the highest-count claimed entries first so
    # the order statistic runs on (n, cap, R), never (n, P, R)
    cap = min(p_pool, max(compact_cap, k))
    sel = jnp.where(claimed, count.astype(jnp.float32), 0.0)
    _, cpos = jax.lax.top_k(sel, cap)
    cg = jnp.take_along_axis(g, cpos[..., None], axis=1)  # (n, cap, R)
    cpool = jnp.take_along_axis(pool, cpos, axis=-1)
    cclaimed = jnp.take_along_axis(claimed, cpos, axis=-1)
    cvalid = jnp.take_along_axis(valid, cpos, axis=-1)
    if estimator == "min":
        score = jnp.min(cg, axis=-1)
    else:
        score = _median_sorted(jnp.sort(cg, axis=-1), axis=-1)
    s_enc = jnp.where(cclaimed,
                      score - OFFSET * (1.0 - cvalid.astype(jnp.float32)),
                      NEG_INF)
    val, pos = jax.lax.top_k(s_enc, k)
    idx = jnp.take_along_axis(cpool, pos, axis=-1)
    return decode_penalty_topk(val, idx, t)


# ---------------------------------------------------------------------------
# Fused Pallas pipeline (inline multiply-shift mode).
# ---------------------------------------------------------------------------

def _cand_body(num_classes, r, b, m, ell, kcap, t, shift, estimator,
               chunks_ref, coeffs_ref, meta_ref, tau_ref, inv_ref,
               val_out, idx_out, run_val, run_idx):
    """Grid (n, R·m), chunk minor.  inv_ref is the (1, L) inverted-table
    row for this chunk, DMA-selected by the scalar-prefetched chunk id;
    meta_ref (1, R·B) and tau_ref (1, R) are row-resident in VMEM."""
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        run_val[...] = jnp.full((1, kcap), NEG_INF, jnp.float32)
        run_idx[...] = jnp.full((1, kcap), -1, jnp.int32)

    pool = inv_ref[0, :]                                   # (L,) int32
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (ell, b), 1)
    gs = []
    for rr in range(r):
        h = jax.lax.shift_right_logical(
            pool.astype(jnp.uint32) * coeffs_ref[rr],
            jnp.uint32(shift)).astype(jnp.int32)           # (L,)
        onehot = (iota_b == h[:, None]).astype(jnp.float32)
        meta_r = meta_ref[0, rr * b:(rr + 1) * b].astype(jnp.float32)
        gs.append(jnp.dot(onehot, meta_r[:, None],
                          preferred_element_type=jnp.float32))  # (L, 1)
    g = jnp.concatenate(gs, axis=-1)                       # (L, R)

    member = g >= tau_ref[0, :][None, :]
    count = member.sum(-1)
    first = jnp.argmax(member, -1)
    claimed = (first == c // m) & (pool < num_classes)
    valid = claimed if t <= 1 else claimed & (count >= t)

    if estimator == "unbiased":
        score = _affine_unbiased(jnp.mean(g, -1), b)
    elif estimator == "min":
        score = jnp.min(g, axis=-1)
    else:
        # odd-even transposition sort over the (static, small) R axis,
        # then the two middle elements — matches jnp.median
        for phase in range(r):
            lo = phase % 2
            for i in range(lo, r - 1, 2):
                a, bb = g[:, i], g[:, i + 1]
                g = g.at[:, i].set(jnp.minimum(a, bb))
                g = g.at[:, i + 1].set(jnp.maximum(a, bb))
        score = _median_sorted(g, axis=-1)

    s_enc = jnp.where(claimed,
                      score - OFFSET * (1.0 - valid.astype(jnp.float32)),
                      NEG_INF)

    width = max(ell, kcap)
    if width > ell:
        s_enc = jnp.concatenate(
            [s_enc, jnp.full((width - ell,), NEG_INF, jnp.float32)])
        pool = jnp.concatenate(
            [pool, jnp.full((width - ell,), num_classes, jnp.int32)])
    blk_val, blk_pos = jax.lax.top_k(s_enc[None, :], kcap)
    blk_idx = jnp.take_along_axis(pool[None, :], blk_pos, axis=-1)

    # skip the merge sort when no chunk entry can displace a kept one
    @pl.when(jnp.max(blk_val) > jnp.min(run_val[...]))
    def _merge():
        new_val, new_idx = _merge_topk(run_val[...], run_idx[...],
                                       blk_val, blk_idx, kcap)
        run_val[...] = new_val
        run_idx[...] = new_idx

    @pl.when(c == nc - 1)
    def _flush():
        val_out[...] = run_val[...]
        idx_out[...] = run_idx[...]


def mach_candidate_topk_pallas(meta_probs: jnp.ndarray,
                               inverted: jnp.ndarray,
                               *,
                               num_classes: int,
                               k: int,
                               m: int,
                               t: int = 1,
                               estimator: str = "unbiased",
                               inline_coeffs: jnp.ndarray,
                               inline_shift: int,
                               interpret: bool = False
                               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused candidate filter->gather->score.  (N, R, B) -> (val, idx)
    (N, k), same semantics as ``mach_candidate_topk``.

    Inline multiply-shift mode only: the chunk's class list is the only
    HBM gather (BlockSpec-selected by the scalar-prefetched chunk id);
    buckets are recomputed in-register, the per-repetition scores come
    from in-VMEM one-hot matmuls, and the running top-k lives in VMEM
    scratch — no (n, K) or (n, P) tensor exists anywhere.
    """
    n, r, b = meta_probs.shape
    validate_candidate_args(num_classes, k, m, t, r, b, estimator)
    if b & (b - 1):
        raise ValueError("inline mode requires power-of-two B")
    ell = inverted.shape[1]
    kcap = round_up(k, _LANE)

    tau, ids = bucket_topm_pallas(meta_probs, m, interpret=interpret)
    chunks = candidate_chunks(ids, b)                      # (n, R·m)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n, r * m),
        in_specs=[
            pl.BlockSpec((1, r * b), lambda i, c, chunks, coeffs: (i, 0)),
            pl.BlockSpec((1, r), lambda i, c, chunks, coeffs: (i, 0)),
            pl.BlockSpec((1, ell),
                         lambda i, c, chunks, coeffs: (chunks[i, c], 0)),
        ],
        out_specs=(pl.BlockSpec((1, kcap), lambda i, c, chunks, coeffs: (i, 0)),
                   pl.BlockSpec((1, kcap), lambda i, c, chunks, coeffs: (i, 0))),
        scratch_shapes=[pltpu.VMEM((1, kcap), jnp.float32),
                        pltpu.VMEM((1, kcap), jnp.int32)],
    )
    val, idx = pl.pallas_call(
        functools.partial(_cand_body, num_classes, r, b, m, ell, kcap, t,
                          inline_shift, estimator),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n, kcap), jnp.float32),
                   jax.ShapeDtypeStruct((n, kcap), jnp.int32)),
        interpret=interpret,
    )(chunks, inline_coeffs.astype(jnp.uint32),
      meta_probs.reshape(n, r * b), tau, inverted)

    return decode_penalty_topk(val[:, :k], idx[:, :k], t)
