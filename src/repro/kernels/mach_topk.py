"""Fused streaming top-k MACH decode (the sampling/serving hot path).

``mach_decode.py`` fuses Algorithm 2's *argmax* — a running top-1 in
VMEM scratch across K blocks.  Every path that needs more than the
argmax (top-k sampling, the min/median estimators, retrieval-style
serving) previously fell back to materializing the full (N, K) score
matrix in HBM, re-introducing the O(N·K) traffic the fused kernel was
built to avoid.  This kernel generalizes the running accumulator to a
streaming *top-k*:

  * per K block, scores are built in VMEM with the same on-the-fly
    multi-hot matmul recast (MXU, depth R·B) as the top-1 kernel;
  * the block's ``jax.lax.top_k`` is merged into a running (values,
    indices) top-k held in VMEM scratch via one stable two-operand
    sort over the 2·kcap concatenation — the (bn, bk) score tile never
    leaves VMEM and the (N, K) matrix never exists anywhere;
  * the per-block reduction over the R axis is swappable, giving all
    three paper estimators:
        unbiased  (Eq. 2)  — single (bn, R·B) @ (R·B, bk) matmul (the
                             affine map is applied after selection; it
                             is monotone so the ordering is identical),
        min       (Eq. 7)  — R batched one-hot matmuls (exact gathers),
                             then min over R,
        median    (Eq. 8)  — same, then median over R.

Tie-breaking matches ``jax.lax.top_k`` on the full score matrix: the
running set (earlier K blocks → lower class ids) is concatenated first
and the merge sort is stable, so equal values resolve to the lowest
class id.

Hash sources mirror the top-1 kernel: a tiled (R, K) bucket table
(any 2-universal family) or inline multiply-shift hashing computed
in-register (B = 2^k), which removes the table from HBM entirely.

HBM traffic: O(N·R·B + K·R [table mode] + N·k) vs the reference path's
O(N·K) score materialization — the paper's O(RBd + KR) serving claim,
extended from argmax to top-k.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.estimators import ESTIMATORS
from repro.kernels.mach_decode import (NEG_INF, choose_decode_blocks,
                                       mask_k_tail, multihot_block,
                                       prepare_decode_operands, round_up)

_LANE = 128          # TPU lane width: running-top-k capacity granularity


def _merge_topk(run_val, run_idx, blk_val, blk_idx, kcap):
    """Stable descending merge of two (bn, kcap) top-k sets.

    One two-operand sort over the concatenation; stability + run-first
    ordering reproduces lax.top_k's lowest-index tie-breaking globally.
    """
    cat_val = jnp.concatenate([run_val, blk_val], axis=-1)   # (bn, 2·kcap)
    cat_idx = jnp.concatenate([run_idx, blk_idx], axis=-1)
    neg_val, idx = jax.lax.sort((-cat_val, cat_idx), dimension=-1,
                                is_stable=True, num_keys=1)
    return -neg_val[:, :kcap], idx[:, :kcap]


def _block_scores(probs, m, bn, r, b, bk, estimator):
    """Per-block estimator scores (bn, bk) from the VMEM multi-hot m.

    probs: (bn, R·B) f32;  m: (R, B, bk) f32 one-hot over buckets.
    """
    if estimator == "unbiased":
        # sum over R in the contraction itself — one MXU matmul of
        # depth R·B; the affine map of Eq. 2 is applied post-selection.
        return jnp.dot(probs, m.reshape(r * b, bk),
                       preferred_element_type=jnp.float32)
    # min/median need the per-repetition gathered values: R batched
    # one-hot matmuls (exact gathers on the MXU — each row of m has at
    # most one 1), then the order statistic over R.
    g = jax.lax.dot_general(
        probs.reshape(bn, r, b), m,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.float32)                  # (R, bn, bk)
    if estimator == "min":
        return jnp.min(g, axis=0)
    return jnp.median(g, axis=0)


def _topk_body(num_classes, bn, bk, r, b, kcap, estimator, inline_shift,
               probs_ref, hash_ref, val_out, idx_out, run_val, run_idx):
    """Grid (N/bn, K/bk), K minor.  hash_ref is the (r, bk) table tile in
    table mode or the (r, 1) uint32 coefficients in inline mode."""
    kblk = pl.program_id(1)
    nk = pl.num_programs(1)
    kbase = kblk * bk

    @pl.when(kblk == 0)
    def _init():
        run_val[...] = jnp.full((bn, kcap), NEG_INF, jnp.float32)
        run_idx[...] = jnp.zeros((bn, kcap), jnp.int32)

    m = multihot_block(hash_ref, inline_shift, kbase, r, b, bk)
    scores = _block_scores(probs_ref[...].astype(jnp.float32),
                           m, bn, r, b, bk, estimator)        # (bn, bk)
    scores = mask_k_tail(scores, kbase, num_classes, bn, bk)

    blk_val, blk_pos = jax.lax.top_k(scores, kcap)
    blk_idx = kbase + blk_pos.astype(jnp.int32)

    # Merge only when some block entry can displace a kept one.  Ties
    # resolve to the running set (lower class ids, stable run-first
    # merge), so skipping on <= is exact — and most K blocks of a
    # selective decode never beat the running floor, making the skip the
    # common case.
    @pl.when(jnp.max(blk_val) > jnp.min(run_val[...]))
    def _merge():
        new_val, new_idx = _merge_topk(run_val[...], run_idx[...],
                                       blk_val, blk_idx, kcap)
        run_val[...] = new_val
        run_idx[...] = new_idx

    @pl.when(kblk == nk - 1)
    def _flush():
        val_out[...] = run_val[...]
        idx_out[...] = run_idx[...]


def mach_topk_pallas(meta_probs: jnp.ndarray,
                     table: Optional[jnp.ndarray] = None,
                     *,
                     num_classes: int,
                     k: int,
                     estimator: str = "unbiased",
                     inline_coeffs: Optional[jnp.ndarray] = None,
                     inline_shift: Optional[int] = None,
                     block_n: Optional[int] = None,
                     block_k: Optional[int] = None,
                     interpret: bool = False
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused streaming top-k.  meta_probs (N, R, B) -> (val, idx) (N, k).

    Values are on the chosen estimator's scale (matching
    ``estimators.estimate_class_probs`` + ``jax.lax.top_k`` up to tie
    order).  Exactly one of ``table`` ((R, K) int32) or
    (``inline_coeffs`` ((R,) uint32), ``inline_shift``) must be given.
    """
    n, r, b = meta_probs.shape
    if estimator not in ESTIMATORS:
        raise ValueError(f"estimator must be one of {ESTIMATORS}, "
                         f"got {estimator!r}")
    if not 1 <= k <= num_classes:
        raise ValueError(f"need 1 <= k <= num_classes, got k={k}, "
                         f"num_classes={num_classes}")
    rb = r * b
    kcap = round_up(k, _LANE)            # lane-aligned running capacity
    # estimator-aware tile accounting: min/median also hold the
    # (R, bn, bk) gathered tensor in VMEM alongside the (R·B, bk)
    # multi-hot, and the merge scratch scales with kcap.
    bn, bk = choose_decode_blocks(n, rb, block_n, block_k,
                                  r=r, estimator=estimator, kcap=kcap)
    bk = max(round_up(bk, _LANE), kcap)  # block top_k needs bk >= kcap
    k_grid = pl.cdiv(num_classes, bk)
    probs2d, npad, hash_arg, hash_spec, shift = prepare_decode_operands(
        meta_probs, table, num_classes, inline_coeffs, inline_shift, bn, bk,
        k_grid)

    val, idx = pl.pallas_call(
        functools.partial(_topk_body, num_classes, bn, bk, r, b, kcap,
                          estimator, shift),
        grid=(npad // bn, k_grid),
        in_specs=[pl.BlockSpec((bn, rb), lambda i, j: (i, 0)), hash_spec],
        out_specs=(pl.BlockSpec((bn, kcap), lambda i, j: (i, 0)),
                   pl.BlockSpec((bn, kcap), lambda i, j: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((npad, kcap), jnp.float32),
                   jax.ShapeDtypeStruct((npad, kcap), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((bn, kcap), jnp.float32),
                        pltpu.VMEM((bn, kcap), jnp.int32)],
        interpret=interpret,
    )(probs2d, hash_arg)

    val, idx = val[:n, :k], idx[:n, :k]
    if estimator == "unbiased":
        # Eq. 2's affine map of the summed scores — monotone, so applying
        # it after selection preserves the ordering bit-for-bit.
        val = (b / (b - 1.0)) * (val / r - 1.0 / b)
    return val, idx
