"""Fused MACH cross-entropy kernel (Algorithm 1's training loss).

The R-head loss is ``Σ_r [logsumexp(logits[n,r,:]) − logits[n,r,y_nr]]``.
XLA emits this as R segmented reductions plus a gather, round-tripping
the (N, R, B) logits through HBM several times.  The Pallas kernel does
one pass: an N-block of logits is loaded to VMEM once; the per-head
max / exp / sum / log and the label pick (as an in-VMEM one-hot
contraction — no gather) are all fused.

A custom VJP pairs it with a backward kernel computing
``g · (softmax(logits) − onehot(labels))`` in the same single pass.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _xent_fwd_body(bn, r, b, logits_ref, labels_ref, loss_ref):
    """logits_ref: (bn, R*B); labels_ref: (bn, R) int32; loss_ref: (bn, 1)."""
    lg = logits_ref[...].astype(jnp.float32).reshape(bn, r, b)
    mx = jnp.max(lg, axis=-1, keepdims=True)                      # (bn, R, 1)
    lse = jnp.log(jnp.sum(jnp.exp(lg - mx), axis=-1)) + mx[..., 0]  # (bn, R)
    # label pick via one-hot contraction (gather-free)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, r, b), 2)
    onehot = (iota == labels_ref[...][:, :, None]).astype(jnp.float32)
    picked = jnp.sum(lg * onehot, axis=-1)                        # (bn, R)
    loss_ref[...] = jnp.sum(lse - picked, axis=-1, keepdims=True)


def _xent_bwd_body(bn, r, b, logits_ref, labels_ref, g_ref, grad_ref):
    """grad = g · (softmax − onehot);  grad_ref: (bn, R*B)."""
    lg = logits_ref[...].astype(jnp.float32).reshape(bn, r, b)
    mx = jnp.max(lg, axis=-1, keepdims=True)
    e = jnp.exp(lg - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)                    # (bn, R, B)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, r, b), 2)
    onehot = (iota == labels_ref[...][:, :, None]).astype(jnp.float32)
    g = g_ref[...][:, :, None]                                    # (bn, 1, 1)
    grad_ref[...] = (g * (p - onehot)).reshape(bn, r * b).astype(grad_ref.dtype)


def _block_n(n: int, rb: int, block_n: Optional[int],
             vmem_budget: int = 8 * 2**20) -> int:
    if block_n is not None:
        return block_n
    bn = (vmem_budget // (4 * rb * 3)) // 8 * 8  # logits + onehot + grad
    return int(min(max(bn, 8), 256, max(8, n)))


def _fwd_call(logits2d: jnp.ndarray, labels: jnp.ndarray, r: int, b: int,
              bn: int, interpret: bool) -> jnp.ndarray:
    n = logits2d.shape[0]
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_xent_fwd_body, bn, r, b),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, r * b), lambda i: (i, 0)),
                  pl.BlockSpec((bn, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(logits2d, labels)


def _bwd_call(logits2d: jnp.ndarray, labels: jnp.ndarray, g: jnp.ndarray,
              r: int, b: int, bn: int, interpret: bool) -> jnp.ndarray:
    n = logits2d.shape[0]
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_xent_bwd_body, bn, r, b),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, r * b), lambda i: (i, 0)),
                  pl.BlockSpec((bn, r), lambda i: (i, 0)),
                  pl.BlockSpec((bn, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, r * b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r * b), logits2d.dtype),
        interpret=interpret,
    )(logits2d, labels, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def mach_xent_pallas(logits: jnp.ndarray, hashed_labels: jnp.ndarray,
                     block_n: Optional[int] = None,
                     interpret: bool = False) -> jnp.ndarray:
    """Per-example summed R-head CE.  logits (N, R, B), labels (N, R) ->
    (N,) float32.  Differentiable (fused backward kernel)."""
    out, _ = _mach_xent_fwd(logits, hashed_labels, block_n, interpret)
    return out


def _pad_n(x: jnp.ndarray, bn: int) -> jnp.ndarray:
    pad = -x.shape[0] % bn
    if pad:
        pads = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pads)
    return x


def _mach_xent_fwd(logits, hashed_labels, block_n, interpret):
    n, r, b = logits.shape
    bn = _block_n(n, r * b, block_n)
    lg2 = _pad_n(logits.reshape(n, r * b), bn)
    lbl = _pad_n(hashed_labels.astype(jnp.int32), bn)
    loss = _fwd_call(lg2, lbl, r, b, bn, interpret)[:n, 0]
    return loss, (logits, hashed_labels)


def _mach_xent_bwd(block_n, interpret, res, g):
    logits, hashed_labels = res
    n, r, b = logits.shape
    bn = _block_n(n, r * b, block_n)
    lg2 = _pad_n(logits.reshape(n, r * b), bn)
    lbl = _pad_n(hashed_labels.astype(jnp.int32), bn)
    gp = _pad_n(g.astype(jnp.float32).reshape(n, 1), bn)
    grad = _bwd_call(lg2, lbl, gp, r, b, bn, interpret)[:n]
    return grad.reshape(n, r, b), None


mach_xent_pallas.defvjp(_mach_xent_fwd, _mach_xent_bwd)
