"""Modality frontend stubs ([audio] seamless-m4t, [vlm] paligemma).

Per the assignment, [audio]/[vlm] entries specify the transformer
BACKBONE only; the modality frontend is a STUB — ``input_specs()``
provides precomputed frame/patch embeddings.  What remains trainable
here is a linear adapter projecting frontend features into the
backbone's d_model (the "multimodal projector" in PaLiGemma / the
length-adapted conformer output projection in SeamlessM4T).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

# feature dims of the (stubbed) frontends
AUDIO_FEATURE_DIM = 1024     # w2v-BERT 2.0 conformer output (seamless)
VISION_FEATURE_DIM = 1152    # SigLIP-So400m/14 output (paligemma)


def init_adapter(key, feature_dim: int, d_model: int):
    p, a = layers.init_dense(key, feature_dim, (d_model,), None, ("embed",))
    return {"proj": p}, {"proj": a}


def apply_adapter(params, feats: jnp.ndarray, dtype) -> jnp.ndarray:
    """(B, S, feature_dim) precomputed frontend features -> (B, S, d_model)."""
    return layers.dense(params["proj"], feats.astype(dtype))


def frontend_feature_dim(kind: str) -> int:
    if kind == "audio":
        return AUDIO_FEATURE_DIM
    if kind == "vision":
        return VISION_FEATURE_DIM
    raise ValueError(f"unknown frontend {kind!r}")
