"""Mixture-of-Experts substrate (mixtral-8x22b, qwen2-moe-a2.7b).

GShard-style top-k capacity routing, implemented as einsums so XLA SPMD
can shard it (expert dim over the mesh 'model'/'data' axes induces the
all-to-all automatically when divisible; otherwise expert weights are
tensor-sharded on d_ff — "expert tensor parallelism" — which is always
valid).

Tokens are processed in groups of ``group_size`` via lax.scan so the
(S, E, C) dispatch one-hot never exceeds ~10 MB regardless of batch —
the standard trick for bounding dispatch memory (C grows linearly with
S, so the live tensor is quadratic in group size).

Aux losses: switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, d_model: int, moe_d_ff: int, num_experts: int,
             num_shared_experts: int = 0, shared_d_ff: int = 0,
             activation: str = "swiglu"):
    kg, k1, k2, k3, ks, kgs = jax.random.split(key, 6)
    p, a = {}, {}
    p["router"], a["router"] = layers.init_dense(
        kg, d_model, (num_experts,), "embed", (None,))
    gated = activation in ("swiglu", "geglu")
    shape_in = (num_experts, d_model, moe_d_ff)
    p["wi"] = {"kernel": layers.truncated_normal_init(k1, shape_in, 1.0)}
    a["wi"] = {"kernel": ("experts", "embed", "mlp")}
    if gated:
        p["wg"] = {"kernel": layers.truncated_normal_init(k2, shape_in, 1.0)}
        a["wg"] = {"kernel": ("experts", "embed", "mlp")}
    p["wo"] = {"kernel": layers.truncated_normal_init(
        k3, (num_experts, moe_d_ff, d_model), 1.0)}
    a["wo"] = {"kernel": ("experts", "mlp", "embed")}
    if num_shared_experts:
        p["shared"], a["shared"] = layers.init_mlp(
            ks, d_model, shared_d_ff, activation)
        p["shared_gate"], a["shared_gate"] = layers.init_dense(
            kgs, d_model, (1,), "embed", (None,))
    return p, a


def _expert_ffn(params, xe: jnp.ndarray, activation: str) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d), batched over experts."""
    wi = params["wi"]["kernel"].astype(xe.dtype)
    wo = params["wo"]["kernel"].astype(xe.dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    if activation == "swiglu":
        wg = params["wg"]["kernel"].astype(xe.dtype)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * h
    elif activation == "geglu":
        wg = params["wg"]["kernel"].astype(xe.dtype)
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, wg)) * h
    else:
        h = layers.ACT[activation](h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def apply_moe(params, x: jnp.ndarray, *, num_experts: int, top_k: int,
              activation: str = "swiglu", capacity_factor: float = 1.25,
              group_size: int = 1024,
              renormalize: bool = True) -> tuple[jnp.ndarray, dict]:
    """x: (B, T, d) -> (y (B, T, d), aux losses dict)."""
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    s = min(group_size, n)
    # pad to a multiple of the group size
    pad = -n % s
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    g = xf.shape[0] // s
    xg = xf.reshape(g, s, d)
    cap = max(1, int(s * top_k * capacity_factor / num_experts))

    router = params["router"]["kernel"]

    def group_body(_, xs):
        xt = xs                                              # (S, d) bf16
        # router math on the small (S, E) tensor in f32; xt itself stays
        # in storage dtype (an .astype(f32) here would copy every token)
        logits = jnp.einsum("sd,de->se", xt, router.astype(xt.dtype),
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)    # (S, K)
        if renormalize:
            gate_vals = gate_vals / jnp.maximum(
                jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
        # one-hot (S, K, E); position of each token within its expert queue
        oh = jax.nn.one_hot(gate_idx, num_experts, dtype=jnp.float32)
        pos = jnp.cumsum(oh.reshape(s * top_k, num_experts), axis=0) \
            .reshape(s, top_k, num_experts) * oh - 1.0       # (S, K, E)
        keep = (pos < cap) & (oh > 0)
        pos_cap = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=xt.dtype)
        # dispatch (S, E, C) and combine (S, E, C) in storage dtype: both
        # are one-hot selections (<= top_k nonzeros per row), so low
        # precision loses nothing
        dispatch = jnp.einsum("ske,skec->sec",
                              (oh * keep).astype(xt.dtype), pos_cap)
        combine = jnp.einsum("sk,ske,skec->sec",
                             gate_vals.astype(xt.dtype),
                             (oh * keep).astype(xt.dtype), pos_cap)
        xe = jnp.einsum("sd,sec->ecd", xt, dispatch)
        ye = _expert_ffn(params, xe, activation)             # (E, C, d)
        y = jnp.einsum("ecd,sec->sd", ye, combine)
        # switch load-balance loss terms
        density = jnp.mean(oh[:, 0], axis=0)                 # top-1 fraction
        density_prob = jnp.mean(probs, axis=0)
        lb = num_experts * jnp.sum(density * density_prob)
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        return None, (y, lb, z)

    # remat the per-group body: differentiating the group scan otherwise
    # stacks every group's (E, C, f) expert activations as saved
    # residuals — 10s of GB at mixtral scale; recomputing them in the
    # backward pass costs ~1 extra forward of the MoE FFN.
    _, (yg, lb, z) = jax.lax.scan(jax.checkpoint(group_body), None, xg)
    y = yg.reshape(g * s, d)[:n].reshape(b, t, d)

    if "shared" in params:
        sh = layers.apply_mlp(params["shared"], x, activation)
        gate = jax.nn.sigmoid(layers.dense(params["shared_gate"], x))
        y = y + sh * gate
    aux = {"load_balance": jnp.mean(lb), "router_z": jnp.mean(z)}
    return y, aux
