"""Model substrate for the assigned architectures."""

from repro.models.model import LanguageModel
from repro.models.transformer import ModelConfig, plan_stacks

__all__ = ["LanguageModel", "ModelConfig", "plan_stacks"]
