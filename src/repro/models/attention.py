"""Attention substrate: MHA/GQA/MQA, full/sliding-window/local, caches.

Three execution paths, all numerically the softmax attention:

* ``_attend_dense``   — small sequences: one materialized score tensor.
* ``_attend_flash``   — jnp flash attention: lax.scan over query chunks,
                        inner scan over KV chunks with running
                        (max, denom, acc) — O(cq·ck) live memory, exact.
* banded window       — sliding-window/local attention slices only the
                        [qs − window, qs + cq) key band per query chunk:
                        O(T·(window+cq)) FLOPs instead of O(T²).

Decode uses a KV cache: linear cache for full attention, ring buffer of
size ``window`` for sliding-window archs — the latter is what makes
``long_500k`` decode O(window) memory at 524 288 context.

Shapes: activations (B, T, D); q (B, T, H, hd); k/v (B, S, KV, hd);
GQA groups G = H // KV are folded as (B, T, KV, G, hd) for the einsums.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = float(jnp.finfo(jnp.float32).min)


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, model_divisor: int = 1):
    """QKV + output projections.

    ``model_divisor``: if num_heads isn't divisible by the model-axis
    size the partitioner falls back to row-parallel sharding on 'embed';
    the axes we emit here are *logical* and the fallback happens in
    sharding/partitioning.py, so this arg is only kept for documentation.
    """
    kq, kk, kv, ko = jax.random.split(key, 4)
    p, a = {}, {}
    p["q"], a["q"] = layers.init_dense(kq, d_model, (num_heads, head_dim),
                                       "embed", ("heads", "qkv"))
    p["k"], a["k"] = layers.init_dense(kk, d_model, (num_kv_heads, head_dim),
                                       "embed", ("kv_heads", "qkv"))
    p["v"], a["v"] = layers.init_dense(kv, d_model, (num_kv_heads, head_dim),
                                       "embed", ("kv_heads", "qkv"))
    po = layers.truncated_normal_init(ko, (num_heads, head_dim, d_model), 1.0)
    p["o"], a["o"] = {"kernel": po}, {"kernel": ("heads", "qkv", "embed")}
    return p, a


def _group(q: jnp.ndarray, num_kv: int) -> jnp.ndarray:
    """(B, T, H, hd) -> (B, T, KV, G, hd)."""
    b, t, h, hd = q.shape
    return q.reshape(b, t, num_kv, h // num_kv, hd)


def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(B, Tq), (B, Sk) -> (B, 1, 1, Tq, Sk) additive mask."""
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    ok = kp >= 0                                   # -1 marks empty cache slots
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_dense(q, k, v, q_pos, k_pos, causal, window, scale):
    """q: (B,T,KV,G,hd); k/v: (B,S,KV,hd) -> (B,T,KV,G,hd).

    K/V stay in their storage dtype (bf16) — accumulation happens in f32
    via preferred_element_type.  An explicit .astype(f32) on the cache
    operand would materialize an f32 copy of the whole KV cache (and on
    the CPU backend, hoist+all-gather it)."""
    s = jnp.einsum("btkgh,bskh->bkgts", (q.astype(jnp.float32) * scale
                                         ).astype(q.dtype), k,
                   preferred_element_type=jnp.float32)
    m = _mask(q_pos, k_pos, causal, window)        # (B,1,1,T,S)
    s = s + m                                      # broadcast over (KV,G)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform softmax over -inf -> nan; zero them
    valid = jnp.any(m > NEG_INF / 2, axis=-1, keepdims=True)
    p = jnp.where(valid, p, 0.0)
    out = jnp.einsum("bkgts,bskh->btkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _attend_flash(q, k, v, q_pos, k_pos, causal, window, scale,
                  chunk_q: int, chunk_k: int):
    """Exact two-level online-softmax attention (jnp 'flash').

    Banded mode: when ``window`` is set and the band [qs−window, qs+cq)
    is shorter than S, only that key band is sliced per query chunk —
    sub-quadratic FLOPs for sliding-window archs.
    """
    b, t, kv, g, hd = q.shape
    s_len = k.shape[1]
    cq = min(chunk_q, t)
    ck = min(chunk_k, s_len)
    assert t % cq == 0, (t, cq)
    band = window is not None and (window + cq) < s_len
    band_len = None
    if band:
        band_len = min(s_len, ((window + cq + ck - 1) // ck) * ck)

    kf = k          # storage dtype; f32 accumulation via the einsums
    vf = v

    def q_chunk_body(_, qi):
        qs = qi * cq
        qc = (jax.lax.dynamic_slice_in_dim(q, qs, cq, axis=1)
              .astype(jnp.float32) * scale).astype(q.dtype)
        qpc = jax.lax.dynamic_slice_in_dim(q_pos, qs, cq, axis=1)

        if band:
            ks = jnp.clip(qs + cq - band_len, 0, s_len - band_len)
            kb = jax.lax.dynamic_slice_in_dim(kf, ks, band_len, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ks, band_len, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(k_pos, ks, band_len, axis=1)
        else:
            kb, vb, kpb = kf, vf, k_pos
        sb = kb.shape[1]

        def kv_chunk_body(carry, kj):
            m_run, l_run, acc = carry
            ksl = kj * ck
            kc = jax.lax.dynamic_slice_in_dim(kb, ksl, ck, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vb, ksl, ck, axis=1)
            kpc = jax.lax.dynamic_slice_in_dim(kpb, ksl, ck, axis=1)
            s = jnp.einsum("btkgh,bskh->bkgts", qc, kc,
                           preferred_element_type=jnp.float32)
            s = s + _mask(qpc, kpc, causal, window)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            corr = jnp.exp(m_run - m_new)
            e = jnp.exp(s - m_new[..., None])
            l_new = l_run * corr + jnp.sum(e, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgts,bskh->bkgth", e.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kv, g, cq), NEG_INF, jnp.float32),
                jnp.zeros((b, kv, g, cq), jnp.float32),
                jnp.zeros((b, kv, g, cq, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_chunk_body, init,
                                          jnp.arange(sb // ck))
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        out = jnp.where((l_f > 0)[..., None], out, 0.0)
        return None, out.transpose(0, 3, 1, 2, 4)   # (B, cq, KV, G, hd)

    _, chunks = jax.lax.scan(q_chunk_body, None, jnp.arange(t // cq))
    # chunks: (nq, B, cq, KV, G, hd) -> (B, T, KV, G, hd)
    out = jnp.moveaxis(chunks, 0, 1).reshape(b, t, kv, g, hd)
    return out.astype(q.dtype)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, k_pos: jnp.ndarray, *,
           causal: bool = True, window: Optional[int] = None,
           flash_threshold: int = 2048,
           chunk_q: int = 512, chunk_k: int = 1024) -> jnp.ndarray:
    """Dispatching attention. q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H,hd)."""
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    qg = _group(q, kvh)
    scale = 1.0 / math.sqrt(hd)
    use_flash = t >= flash_threshold and t % min(chunk_q, t) == 0
    if use_flash:
        out = _attend_flash(qg, k, v, q_pos, k_pos, causal, window, scale,
                            chunk_q, chunk_k)
    else:
        out = _attend_dense(qg, k, v, q_pos, k_pos, causal, window, scale)
    return out.reshape(b, t, h, hd)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Linear or ring-buffer KV cache.

    k, v:      (B, S, KV, hd) — S = max_len (linear) or window (ring)
    positions: (B, S) int32 absolute positions; −1 = empty
    index:     (B,) int32 next write offset (absolute count of tokens)
    ring:      python bool (static) — ring-buffer mode
    """
    k: jnp.ndarray
    v: jnp.ndarray
    positions: jnp.ndarray
    index: jnp.ndarray

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_cache(batch: int, capacity: int, num_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, num_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, num_kv, head_dim), dtype),
        positions=jnp.full((batch, capacity), -1, jnp.int32),
        index=jnp.zeros((batch,), jnp.int32),
    )


def cache_update_prefill(cache: KVCache, k: jnp.ndarray, v: jnp.ndarray,
                         positions: jnp.ndarray) -> KVCache:
    """Write a full prefill segment at the cache head (linear caches) or
    the last ``capacity`` tokens of it (ring caches)."""
    t = k.shape[1]
    cap = cache.capacity
    if t <= cap:
        newk = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1)
        newv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1)
        newp = jax.lax.dynamic_update_slice_in_dim(cache.positions, positions, 0, axis=1)
    else:
        # keep only the trailing window, rolled so the ring invariant
        # (position p lives at row p mod cap) holds for subsequent decode
        shift = t % cap
        newk = jnp.roll(k[:, t - cap:].astype(cache.k.dtype), shift, axis=1)
        newv = jnp.roll(v[:, t - cap:].astype(cache.v.dtype), shift, axis=1)
        newp = jnp.roll(positions[:, t - cap:], shift, axis=1)
    return KVCache(newk, newv, newp, cache.index + t)


def cache_update_decode(cache: KVCache, k1: jnp.ndarray, v1: jnp.ndarray,
                        ring: bool, per_row: bool = False) -> KVCache:
    """Insert one token (B, 1, KV, hd).

    ``per_row=False`` — *lockstep* decode: every row writes at the same
    position (rows were left-padded so the batch decodes in lockstep).
    A single scalar-indexed dynamic_update_slice keeps the update local
    under SPMD.  (A per-row vmapped scatter here makes XLA all-gather
    the entire batch-sharded cache — 11.8 GB/token on the decode_32k
    cell — which is why the lockstep path isn't expressed per-row.)

    ``per_row=True`` — *slot* decode for the continuous-batching engine:
    row i writes at its own ``index[i]`` (mod capacity for ring caches),
    so requests at different depths share one fixed cache pool.  This is
    the vmapped scatter the lockstep comment warns about; the slot
    engine trades that SPMD hazard for scheduling freedom — shard slot
    pools over replicas (batch axis untouched per row), not over the
    cache's sequence axis.
    """
    idx = cache.index            # (B,)
    if per_row:
        slot = jnp.mod(idx, cache.capacity) if ring else idx

        def put_row(buf, new, s):
            return jax.lax.dynamic_update_slice_in_dim(buf, new, s, axis=0)

        newk = jax.vmap(put_row)(cache.k, k1.astype(cache.k.dtype), slot)
        newv = jax.vmap(put_row)(cache.v, v1.astype(cache.v.dtype), slot)
        newp = jax.vmap(put_row)(cache.positions,
                                 idx[:, None].astype(jnp.int32), slot)
        return KVCache(newk, newv, newp, idx + 1)
    pos = idx[0]                 # scalar write position, uniform in lockstep
    slot = jnp.mod(pos, cache.capacity) if ring else pos
    zero = jnp.zeros((), slot.dtype)
    newk = jax.lax.dynamic_update_slice(
        cache.k, k1.astype(cache.k.dtype), (zero, slot, zero, zero))
    newv = jax.lax.dynamic_update_slice(
        cache.v, v1.astype(cache.v.dtype), (zero, slot, zero, zero))
    newp = jax.lax.dynamic_update_slice(
        cache.positions, jnp.broadcast_to(pos, (cache.positions.shape[0], 1)
                                          ).astype(jnp.int32), (zero, slot))
    return KVCache(newk, newv, newp, idx + 1)


def decode_attend(q1: jnp.ndarray, cache: KVCache, *,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention over the cache.  q1: (B, 1, H, hd)."""
    q_pos = cache.index[:, None] - 1          # position of the new token
    return attend(q1, cache.k, cache.v, q_pos, cache.positions,
                  causal=True, window=window, flash_threshold=1 << 62)


# ---------------------------------------------------------------------------
# Paged KV cache (shared page pool + per-slot page tables)
# ---------------------------------------------------------------------------

class PagedKVCache(NamedTuple):
    """Shared page pool with per-slot page tables.

    The contiguous ``KVCache`` reserves a worst-case ``num_slots ×
    max_len`` strip per slot; one long-context straggler dictates the
    HBM bill for every slot.  The paged layout allocates KV in fixed
    ``page_size``-token pages from one shared pool — a slot holds a
    *page table* of pool indices instead of a strip, so resident memory
    is ``num_pages × page_size`` regardless of per-slot ``max_len``.

    k, v:       (num_pages, page_size, KV, hd) — the shared pool
    positions:  (num_pages, page_size) int32 absolute positions; −1 =
                empty or stale (freed pages keep their contents; masking
                is entirely position-driven)
    page_table: (num_slots, max_pages) int32 pool page ids; −1 =
                unassigned.  Logical token p of slot s lives at pool
                coordinate (page_table[s, p // page_size], p % page_size).
    index:      (num_slots,) int32 next absolute write position

    All geometry (page_size, num_pages, max_pages, num_slots) is
    derivable from leaf shapes, so the pytree carries no static fields
    and scans/jits treat it like any other cache leaf.
    """
    k: jnp.ndarray
    v: jnp.ndarray
    positions: jnp.ndarray
    page_table: jnp.ndarray
    index: jnp.ndarray

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def num_pages(self) -> int:
        return self.k.shape[0]

    @property
    def max_pages(self) -> int:
        return self.page_table.shape[1]

    @property
    def num_slots(self) -> int:
        return self.index.shape[0]


def init_paged_cache(num_slots: int, num_pages: int, page_size: int,
                     max_pages: int, num_kv: int, head_dim: int,
                     dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((num_pages, page_size, num_kv, head_dim), dtype),
        v=jnp.zeros((num_pages, page_size, num_kv, head_dim), dtype),
        positions=jnp.full((num_pages, page_size), -1, jnp.int32),
        page_table=jnp.full((num_slots, max_pages), -1, jnp.int32),
        index=jnp.zeros((num_slots,), jnp.int32),
    )


def paged_insert_prefill(cache: PagedKVCache, one: KVCache, slot,
                         pages: jnp.ndarray) -> PagedKVCache:
    """Scatter a freshly prefilled batch-1 contiguous cache into the
    pool pages reserved for ``slot``.

    ``one`` must have capacity C = len(pages) · page_size (the engine
    prefills with a page-rounded capacity); its positions carry −1
    beyond the prompt, so the padded tail of the last page is masked
    exactly like empty cache rows.  ``pages`` is the (n_prompt_pages,)
    page-id vector from the allocator; the slot's table row is set to
    those pages followed by −1 (pages appended later by the scheduler
    on boundary crossings)."""
    ps = cache.page_size
    c = one.k.shape[1]
    npg = c // ps
    assert npg * ps == c, (c, ps)

    def paginate(strip):                     # (1, C, ...) -> (npg, ps, ...)
        return strip[0].reshape((npg, ps) + strip.shape[2:])

    newk = cache.k.at[pages].set(paginate(one.k).astype(cache.k.dtype))
    newv = cache.v.at[pages].set(paginate(one.v).astype(cache.v.dtype))
    newp = cache.positions.at[pages].set(paginate(one.positions))
    row = jnp.concatenate([
        pages.astype(jnp.int32),
        jnp.full((cache.max_pages - npg,), -1, jnp.int32)])
    table = cache.page_table.at[slot].set(row)
    index = cache.index.at[slot].set(one.index[0])
    return PagedKVCache(newk, newv, newp, table, index)


def paged_append_page(cache: PagedKVCache, slot, page_idx,
                      page_id) -> PagedKVCache:
    """Grow ``slot``'s table by one page (decode boundary crossing)."""
    table = cache.page_table.at[slot, page_idx].set(
        jnp.asarray(page_id, jnp.int32))
    return cache._replace(page_table=table)


def paged_reset_slot(cache: PagedKVCache, slot) -> PagedKVCache:
    """Clear ``slot``: table row → −1, index → 0.  Page *contents* are
    left stale on purpose — freed pages are masked by positions the
    moment they are rewritten (prefill writes whole pages; a decode
    write at page offset 0 rewrites the page's position row) — so
    freeing is O(max_pages), not O(tokens)."""
    table = cache.page_table.at[slot].set(-1)
    index = cache.index.at[slot].set(0)
    return cache._replace(page_table=table, index=index)


def paged_cache_update_decode(cache: PagedKVCache, k1: jnp.ndarray,
                              v1: jnp.ndarray) -> PagedKVCache:
    """Insert one token per slot (k1/v1: (S, 1, KV, hd)) at each slot's
    own (page, offset) = (table[s, idx // ps], idx % ps).

    Slots whose table entry is unassigned (−1) — free slots, or slots
    whose index ran past their table — scatter out of bounds and are
    dropped: free-slot inertness is structural, a free slot cannot
    touch the pool.  A write at offset 0 rewrites the page's whole
    position row (token position at 0, −1 elsewhere), so a recycled
    page's stale positions can never leak into the attention mask."""
    idx = cache.index                                  # (S,)
    ps, mp, npages = cache.page_size, cache.max_pages, cache.num_pages
    pj = idx // ps
    off = idx % ps
    entry = jnp.take_along_axis(cache.page_table,
                                jnp.minimum(pj, mp - 1)[:, None],
                                axis=1)[:, 0]          # (S,)
    valid = (entry >= 0) & (pj < mp)
    page = jnp.where(valid, entry, npages)             # OOB -> dropped
    newk = cache.k.at[page, off].set(k1[:, 0].astype(cache.k.dtype),
                                     mode="drop")
    newv = cache.v.at[page, off].set(v1[:, 0].astype(cache.v.dtype),
                                     mode="drop")
    # full position-row rewrite: stale offsets of a fresh page -> -1
    cur = jnp.where(valid[:, None],
                    cache.positions[jnp.where(valid, entry, 0)], -1)
    lane = jnp.arange(ps, dtype=jnp.int32)[None]       # (1, ps)
    row = jnp.where(lane == off[:, None], idx[:, None],
                    jnp.where(off[:, None] == 0, -1, cur))
    newp = cache.positions.at[page].set(row, mode="drop")
    return PagedKVCache(newk, newv, newp, cache.page_table, idx + 1)


def paged_decode_attend(q1: jnp.ndarray, cache: PagedKVCache, *,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Single-token attention over a paged cache.  q1: (S, 1, H, hd).

    Online-softmax scan over the page axis: each step gathers one page
    per slot — an (S, page_size, KV, hd) tile — and folds it into a
    running (max, denom, acc), exactly the ``_attend_flash`` recurrence
    with pages as KV chunks.  No intermediate ever carries both the
    slot dim and the logical max_len = max_pages · page_size dim: the
    per-slot worst-case strip the paged layout exists to kill is never
    materialized, not even transiently.

    Unassigned table entries gather page 0 but mask its positions to
    −1, so a slot only ever attends to its own pages."""
    s_dim, _, h, hd = q1.shape
    kv = cache.k.shape[2]
    g = h // kv
    ps, mp = cache.page_size, cache.max_pages
    scale = 1.0 / math.sqrt(hd)
    qg = _group(q1, kv)                                # (S, 1, KV, G, hd)
    qc = (qg.astype(jnp.float32) * scale).astype(q1.dtype)
    q_pos = cache.index[:, None] - 1                   # (S, 1)

    def page_body(carry, j):
        m_run, l_run, acc = carry
        pid = cache.page_table[:, j]                   # (S,)
        ok = pid >= 0
        safe = jnp.where(ok, pid, 0)
        kb = cache.k[safe]                             # (S, ps, KV, hd)
        vb = cache.v[safe]
        kp = jnp.where(ok[:, None], cache.positions[safe], -1)
        s = jnp.einsum("btkgh,bskh->bkgts", qc, kb,
                       preferred_element_type=jnp.float32)
        s = s + _mask(q_pos, kp, True, window)         # (S, KV, G, 1, ps)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        corr = jnp.exp(m_run - m_new)
        e = jnp.exp(s - m_new[..., None])
        l_new = l_run * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", e.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((s_dim, kv, g, 1), NEG_INF, jnp.float32),
            jnp.zeros((s_dim, kv, g, 1), jnp.float32),
            jnp.zeros((s_dim, kv, g, 1, hd), jnp.float32))
    (_, l_f, acc), _ = jax.lax.scan(page_body, init, jnp.arange(mp))
    out = acc / jnp.maximum(l_f, 1e-37)[..., None]
    out = jnp.where((l_f > 0)[..., None], out, 0.0)
    out = out.transpose(0, 3, 1, 2, 4)                 # (S, 1, KV, G, hd)
    return out.reshape(s_dim, 1, h, hd).astype(q1.dtype)
