"""Model assembly: ModelConfig, block dispatch, scanned stacks, LM classes.

A model is a cycled ``block_pattern`` of block kinds:

  attn        self-attention (+MLP)          — dense transformers
  attn_local  sliding-window self-attention  — griffin local / SWA layers
  moe         self-attention + MoE FFN       — mixtral / qwen2-moe
  rglru       RG-LRU recurrent block (+MLP)  — recurrentgemma
  mlstm/slstm xLSTM blocks                   — xlstm-350m
  xattn       self + cross attention (+MLP)  — enc-dec decoder layers

Layers are *scanned*: the cycled pattern is factored into maximal
(pattern × n_periods) stacks whose parameters are stacked on a leading
'layers' axis, and each stack runs as one ``lax.scan`` — compile time
and HLO size stay O(pattern), not O(num_layers), which is what makes
88-layer × 512-device dry-runs tractable.  ``remat`` wraps the scan body
(full activation checkpointing).

The output head is either the dense OAA softmax (paper baseline) or the
MACH head (the paper's technique) — selected per-config via ``mach``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mach import MACHConfig, MACHOutputHead
from repro.kernels import ops
from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, recurrent, xlstm
from repro.sharding.partitioning import constrain


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    family: str = "dense"            # dense | moe | enc_dec | hybrid | xlstm | vlm
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention
    attention_kind: str = "full"     # full | sliding_window
    window: int = 4096               # SWA window (attention_kind=sliding_window)
    local_window: int = 2048         # window for attn_local blocks
    rope_theta: float = 10000.0
    flash_threshold: int = 2048
    chunk_q: int = 512
    chunk_k: int = 1024
    # block pattern (cycled over num_layers)
    block_pattern: tuple = ("attn",)
    # MoE
    num_experts: int = 0
    experts_top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    moe_group_size: int = 1024
    capacity_factor: float = 1.25
    lb_loss_coef: float = 0.01
    z_loss_coef: float = 1e-3
    # enc-dec
    num_encoder_layers: int = 0
    # recurrent widths
    rnn_width: int = 0               # 0 -> d_model
    mlstm_proj: float = 2.0
    # frontend stubs
    frontend: Optional[str] = None   # audio | vision
    num_prefix_tokens: int = 0       # vision patch count (prefix embeddings)
    # head
    mach: Optional[MACHConfig] = None
    mach_fused_loss: bool = False    # train via the logit-free fused
                                     # projection+CE kernel (activation
                                     # memory O(N·d), not O(N·R·B))
    mach_bucket_select: Optional[tuple] = None
                                     # (c_sel, refresh_every): dynamic
                                     # bucket selection on the fused
                                     # loss — top-c_sel proxy-scored
                                     # buckets per repetition, labels
                                     # force-included (one-sided,
                                     # bounded bias); the trainer
                                     # refreshes the cached proxy every
                                     # refresh_every steps
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    embed_scale: float = 1.0         # gemma-family: sqrt(d_model)
    # numerics / structure
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = None          # None -> f32; full configs use bf16
                                     # (+ f32 master weights in the optimizer)
    remat: str = "full"              # none | full
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    def layout(self, n: Optional[int] = None) -> list:
        n = n or self.num_layers
        pat = self.block_pattern
        return [pat[i % len(pat)] for i in range(n)]

    def block_window(self, kind: str) -> Optional[int]:
        if kind == "attn_local":
            return self.local_window
        if kind in ("attn", "moe", "xattn") and self.attention_kind == "sliding_window":
            return self.window
        return None

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline term)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        per = {}
        per["attn"] = d * hd * (self.num_heads * 2 + self.num_kv_heads * 2) \
            + (3 if self.activation in ("swiglu", "geglu") else 2) * d * f + 2 * d
        per["attn_local"] = per["attn"]
        per["xattn"] = per["attn"] + d * hd * (self.num_heads + self.num_kv_heads * 2) + d
        mo = self.moe_d_ff or f
        per["moe"] = d * hd * (self.num_heads * 2 + self.num_kv_heads * 2) \
            + self.num_experts * 3 * d * mo + d * self.num_experts \
            + (3 * d * self.shared_d_ff if self.num_shared_experts else 0) + 2 * d
        w = self.resolved_rnn_width
        per["rglru"] = 3 * d * w + 2 * w * w + 5 * w \
            + (3 if self.activation in ("swiglu", "geglu") else 2) * d * f + 2 * d
        di = int(d * self.mlstm_proj)
        hdm = di // self.num_heads
        per["mlstm"] = d * 2 * di + 3 * di * self.num_heads * hdm \
            + 2 * di * self.num_heads + di * d + 2 * d
        hds = d // self.num_heads
        per["slstm"] = 4 * d * d + 4 * self.num_heads * hds * hds \
            + 3 * d * int(d * 4 / 3) + 2 * d
        total = sum(per[k] for k in self.layout())
        total += per["attn"] * self.num_encoder_layers
        total += v * d                                    # embedding
        if self.mach is not None:
            total += d * self.mach.num_repetitions * self.mach.num_buckets
        elif not self.tie_embeddings:
            total += d * v
        return total


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, a = {}, {}
    p["norm1"], a["norm1"] = layers.init_norm(cfg.d_model, cfg.norm, "embed")
    if kind in ("attn", "attn_local", "moe", "xattn", "enc"):
        p["attn"], a["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim)
    if kind == "xattn":
        p["norm_x"], a["norm_x"] = layers.init_norm(cfg.d_model, cfg.norm, "embed")
        p["xattn"], a["xattn"] = attn_lib.init_attention(
            k4, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim)
    if kind == "rglru":
        p["rglru"], a["rglru"] = recurrent.init_rglru_block(
            k1, cfg.d_model, cfg.resolved_rnn_width)
    if kind == "mlstm":
        p["mlstm"], a["mlstm"] = xlstm.init_mlstm_block(
            k1, cfg.d_model, cfg.num_heads, cfg.mlstm_proj)
        return p, a                                   # no second MLP
    if kind == "slstm":
        p["slstm"], a["slstm"] = xlstm.init_slstm_block(
            k1, cfg.d_model, cfg.num_heads)
        return p, a
    p["norm2"], a["norm2"] = layers.init_norm(cfg.d_model, cfg.norm, "embed")
    if kind == "moe":
        p["moe"], a["moe"] = moe_lib.init_moe(
            k2, cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts,
            cfg.num_shared_experts, cfg.shared_d_ff, cfg.activation)
    else:
        p["mlp"], a["mlp"] = layers.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                             cfg.activation)
    return p, a


def _self_attention(params, cfg: ModelConfig, x, positions, window,
                    cache, causal=True, per_slot=False):
    """Returns (attn_out, new_cache)."""
    q = layers.dense(params["q"], x)
    k = layers.dense(params["k"], x)
    v = layers.dense(params["v"], x)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = attn_lib.attend(q, k, v, positions, positions, causal=causal,
                              window=window,
                              flash_threshold=cfg.flash_threshold,
                              chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
        new_cache = None
    elif isinstance(cache, attn_lib.PagedKVCache):   # paged slot decode
        if x.shape[1] > 1:
            raise NotImplementedError(
                "paged caches decode one token per slot; prefill goes "
                "through a batch-1 contiguous cache that the engine "
                "scatters into reserved pages (chunked paged prefill is "
                "a future admission policy)")
        new_cache = attn_lib.paged_cache_update_decode(cache, k, v)
        out = attn_lib.paged_decode_attend(q, new_cache, window=window)
    elif x.shape[1] > 1:                      # prefill into cache
        new_cache = attn_lib.cache_update_prefill(cache, k, v, positions)
        out = attn_lib.attend(q, k, v, positions, positions, causal=causal,
                              window=window,
                              flash_threshold=cfg.flash_threshold,
                              chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
    else:                                     # single-token decode
        ring = window is not None and cache.capacity <= window
        new_cache = attn_lib.cache_update_decode(cache, k, v, ring,
                                                 per_row=per_slot)
        out = attn_lib.decode_attend(q, new_cache, window=window)
    o = params["o"]["kernel"].astype(out.dtype)
    return jax.lax.dot_general(out, o, (((2, 3), (0, 1)), ((), ()))), new_cache


def _cross_attention(params, cfg: ModelConfig, x, enc_kv):
    """enc_kv: (k, v) precomputed from encoder output."""
    q = layers.dense(params["q"], x)
    k, v = enc_kv
    b, t = x.shape[:2]
    s = k.shape[1]
    q_pos = jnp.zeros((b, t), jnp.int32)
    k_pos = jnp.zeros((b, s), jnp.int32)
    out = attn_lib.attend(q, k, v, q_pos, k_pos, causal=False, window=None,
                          flash_threshold=cfg.flash_threshold,
                          chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k)
    o = params["o"]["kernel"].astype(out.dtype)
    return jax.lax.dot_general(out, o, (((2, 3), (0, 1)), ((), ())))


def cross_kv(params_block, x_enc):
    """Precompute cross-attention K/V from encoder output (per xattn block)."""
    k = layers.dense(params_block["xattn"]["k"], x_enc)
    v = layers.dense(params_block["xattn"]["v"], x_enc)
    return k, v


def apply_block(params, cfg: ModelConfig, kind: str, x, positions,
                cache=None, enc_kv=None, decode: bool = False,
                per_slot: bool = False):
    """Pre-norm residual block.  Returns (x, new_cache, aux)."""
    aux = {}
    h = layers.apply_norm(params["norm1"], x, cfg.norm)
    window = cfg.block_window(kind)
    if kind in ("attn", "attn_local", "moe", "enc", "xattn"):
        out, new_cache = _self_attention(params["attn"], cfg, h, positions,
                                         window, cache,
                                         causal=(kind != "enc"),
                                         per_slot=per_slot)
        x = x + out
        if kind == "xattn":
            hx = layers.apply_norm(params["norm_x"], x, cfg.norm)
            x = x + _cross_attention(params["xattn"], cfg, hx, enc_kv)
    elif kind == "rglru":
        out, new_cache = recurrent.apply_rglru_block(params["rglru"], h, cache)
        x = x + out
    elif kind == "mlstm":
        out, new_cache = xlstm.apply_mlstm_block(params["mlstm"], h, cache,
                                                 decode=decode)
        return x + out, new_cache, aux
    elif kind == "slstm":
        out, new_cache = xlstm.apply_slstm_block(params["slstm"], h, cache,
                                                 decode=decode)
        return x + out, new_cache, aux
    else:
        raise ValueError(kind)

    h2 = layers.apply_norm(params["norm2"], x, cfg.norm)
    if kind == "moe":
        out2, aux = moe_lib.apply_moe(
            params["moe"], h2, num_experts=cfg.num_experts,
            top_k=cfg.experts_top_k, activation=cfg.activation,
            capacity_factor=cfg.capacity_factor,
            group_size=cfg.moe_group_size)
    else:
        out2 = layers.apply_mlp(params["mlp"], h2, cfg.activation)
    return x + out2, new_cache, aux


# ---------------------------------------------------------------------------
# Stacked scan over cycled patterns
# ---------------------------------------------------------------------------

def plan_stacks(layout: list) -> list:
    """Factor the layer layout into [(period_kinds, n_periods), ...]."""
    if not layout:
        return []
    # find the cycled pattern length = position where layout repeats
    pat_len = 1
    for pl in range(1, len(layout) + 1):
        if all(layout[i] == layout[i % pl] for i in range(len(layout))):
            pat_len = pl
            break
    n_full = len(layout) // pat_len
    stacks = []
    if n_full:
        stacks.append((tuple(layout[:pat_len]), n_full))
    rem = layout[n_full * pat_len:]
    if rem:
        stacks.append((tuple(rem), 1))
    return stacks


def init_stacks(key, cfg: ModelConfig, layout: list):
    """Returns (params, axes): list over stacks of list over period
    positions of stacked block params."""
    stacks = plan_stacks(layout)
    params, axes = [], []
    keys = jax.random.split(key, len(stacks))
    for (period, n), sk in zip(stacks, keys):
        pos_keys = jax.random.split(sk, len(period))
        p_list, a_list = [], []
        for kind, pk in zip(period, pos_keys):
            if n == 1:
                p, a = init_block(pk, cfg, kind)
                p = jax.tree.map(lambda x: x[None], p)
                a = jax.tree.map(lambda ax: ("layers",) + tuple(ax), a,
                                 is_leaf=lambda v: isinstance(v, tuple))
            else:
                p, a = layers.stack_inits(
                    functools.partial(init_block, cfg=cfg, kind=kind), pk, n)
            p_list.append(p)
            a_list.append(a)
        params.append(p_list)
        axes.append(a_list)
    return params, axes


def apply_stacks(params, cfg: ModelConfig, layout: list, x, positions,
                 caches=None, enc_kvs=None, decode: bool = False,
                 per_slot: bool = False):
    """Run all stacks.  caches/enc_kvs mirror the params nesting.
    Returns (x, new_caches, aux_sums)."""
    stacks = plan_stacks(layout)
    new_caches = []
    aux_sum = {"load_balance": 0.0, "router_z": 0.0}

    for si, ((period, n), p_list) in enumerate(zip(stacks, params)):
        st_caches = caches[si] if caches is not None else None
        st_enc = enc_kvs[si] if enc_kvs is not None else None

        def body(carry, xs, period=period):
            x = carry
            layer_params, layer_caches, layer_enc = xs
            new_lc = []
            laux = {"load_balance": 0.0, "router_z": 0.0}
            for pi, kind in enumerate(period):
                c = layer_caches[pi] if layer_caches is not None else None
                ek = layer_enc[pi] if layer_enc is not None else None
                x, nc, aux = apply_block(layer_params[pi], cfg, kind, x,
                                         positions, c, ek, decode, per_slot)
                # residual-stream sharding (DP on batch; + SP over 'model'
                # on seq when the active rules enable it) — no-op outside
                # an activate() context
                x = constrain(x, ("batch", "seq", None))
                new_lc.append(nc)
                for k2 in laux:
                    laux[k2] = laux[k2] + aux.get(k2, 0.0)
            return x, (new_lc, laux)

        if cfg.remat == "full":
            body = jax.checkpoint(body)

        use_scan = cfg.scan_layers and n > 1
        if use_scan:
            xs = (p_list, st_caches, st_enc)
            x, (nc, laux) = jax.lax.scan(body, x, xs)
            aux_sum = {k2: aux_sum[k2] + jnp.sum(laux[k2]) for k2 in aux_sum}
            new_caches.append(nc)
        else:
            nc_layers = None
            for li in range(n):
                lp = jax.tree.map(lambda v: v[li], p_list)
                lc = (jax.tree.map(lambda v: v[li], st_caches)
                      if st_caches is not None else None)
                le = (jax.tree.map(lambda v: v[li], st_enc)
                      if st_enc is not None else None)
                x, (nc, laux) = body(x, (lp, lc, le))
                aux_sum = {k2: aux_sum[k2] + laux[k2] for k2 in aux_sum}
                if caches is not None:
                    nc_exp = jax.tree.map(lambda v: v[None], nc)
                    nc_layers = nc_exp if nc_layers is None else jax.tree.map(
                        lambda a, b: jnp.concatenate([a, b], 0),
                        nc_layers, nc_exp)
            new_caches.append(nc_layers)
    return x, (new_caches if caches is not None else None), aux_sum
