"""LanguageModel: embeddings → (encoder) → scanned decoder stacks → head.

One class serves all 10 assigned architectures; the config decides the
block pattern, MoE/recurrent substrates, enc-dec structure, frontend
stubs, and — the paper's feature — whether the output head is the dense
OAA softmax or the MACH head.

Public surface:
  init(key)                       -> (params, axes)
  loss(params, batch)             -> (loss, metrics)        [train_step body]
  prefill(params, batch, max_len) -> (caches, enc_kvs, last_hidden)
  decode_step(params, caches, enc_kvs, tokens, pos) -> (caches, hidden)
  next_token(params, hidden)      -> (token ids, scores)    [greedy]
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.mach import MACHOutputHead, mach_meta_probs
from repro.kernels import ops
from repro.models import attention as attn_lib
from repro.models import frontends, layers, recurrent, xlstm
from repro.models.transformer import (ModelConfig, apply_stacks, cross_kv,
                                      init_stacks, plan_stacks)
from repro.sharding.partitioning import constrain


class LanguageModel:

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.head = (MACHOutputHead(cfg.mach, cfg.d_model, jnp.float32)
                     if cfg.mach is not None else None)

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p, a = {}, {}
        p["embed"], a["embed"] = layers.init_embedding(
            keys[0], cfg.vocab_size, cfg.d_model)
        p["stacks"], a["stacks"] = init_stacks(keys[1], cfg, self._dec_layout())
        p["final_norm"], a["final_norm"] = layers.init_norm(
            cfg.d_model, cfg.norm, "embed")
        if cfg.mach is not None:
            hp = self.head.init(keys[2])
            p["mach_head"] = hp
            a["mach_head"] = {"kernel": ("embed", "mach_rb")}
        elif not cfg.tie_embeddings:
            p["lm_head"], a["lm_head"] = layers.init_dense(
                keys[3], cfg.d_model, (cfg.vocab_size,), "embed", ("vocab",))
        if cfg.num_encoder_layers:
            p["enc_adapter"], a["enc_adapter"] = frontends.init_adapter(
                keys[4], frontends.frontend_feature_dim(cfg.frontend or "audio"),
                cfg.d_model)
            p["enc_stacks"], a["enc_stacks"] = init_stacks(
                keys[5], cfg, ["enc"] * cfg.num_encoder_layers)
            p["enc_norm"], a["enc_norm"] = layers.init_norm(
                cfg.d_model, cfg.norm, "embed")
        if cfg.frontend == "vision":
            p["vis_adapter"], a["vis_adapter"] = frontends.init_adapter(
                keys[6], frontends.VISION_FEATURE_DIM, cfg.d_model)
        if cfg.param_dtype is not None:
            p = jax.tree.map(
                lambda x: x.astype(cfg.param_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
        return p, a

    def _dec_layout(self):
        cfg = self.cfg
        if cfg.num_encoder_layers:
            return ["xattn"] * cfg.num_layers
        return cfg.layout()

    # --------------------------------------------------------------- forward
    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens, cfg.dtype)
        if cfg.embed_scale != 1.0:
            x = x * jnp.asarray(cfg.embed_scale, cfg.dtype)
        return x

    def encode(self, params, enc_feats: jnp.ndarray) -> jnp.ndarray:
        """Stubbed frontend features (B, S, F) -> encoder output (B, S, d)."""
        cfg = self.cfg
        x = frontends.apply_adapter(params["enc_adapter"], enc_feats, cfg.dtype)
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x, _, _ = apply_stacks(params["enc_stacks"], cfg,
                               ["enc"] * cfg.num_encoder_layers, x, pos)
        return layers.apply_norm(params["enc_norm"], x, cfg.norm)

    def enc_kvs(self, params, enc_out: jnp.ndarray):
        """Precompute per-decoder-layer cross-attention K/V (stacked)."""
        cfg = self.cfg
        out = []
        for p_list in params["stacks"]:
            st = []
            for pp in p_list:
                # pp leaves have a leading 'layers' dim; vmap cross_kv over it
                st.append(jax.vmap(lambda q: cross_kv(q, enc_out))(pp))
            out.append(st)
        return out

    def hidden_states(self, params, tokens: jnp.ndarray, *,
                      prefix_emb: Optional[jnp.ndarray] = None,
                      enc_kvs=None, caches=None,
                      positions: Optional[jnp.ndarray] = None,
                      decode: bool = False, per_slot: bool = False):
        """tokens (B, T) -> hidden (B, T(+P), d).  Returns (h, caches, aux)."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        if prefix_emb is not None:
            pe = frontends.apply_adapter(params["vis_adapter"], prefix_emb,
                                         cfg.dtype)
            x = jnp.concatenate([pe, x], axis=1)
        b, t = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                         (b, t))
        x, caches, aux = apply_stacks(params["stacks"], cfg, self._dec_layout(),
                                      x, positions, caches, enc_kvs, decode,
                                      per_slot)
        x = layers.apply_norm(params["final_norm"], x, cfg.norm)
        return x, caches, aux

    # ------------------------------------------------------------------ head
    def oaa_logits(self, params, h: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.tie_embeddings:
            logits = layers.unembed(params["embed"], h)
        else:
            logits = layers.dense(params["lm_head"], h)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits

    def mach_logits(self, params, h: jnp.ndarray) -> jnp.ndarray:
        return self.head.apply(params["mach_head"], h)      # (..., R, B)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch: dict):
        """batch: tokens (B, L+1) int32; optional weights (B, L),
        enc_feats (B, S, F), prefix_feats (B, P, F)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        weights = batch.get("weights")
        if weights is None:
            weights = jnp.ones(labels.shape, jnp.float32)

        enc_kvs = None
        if cfg.num_encoder_layers:
            enc_out = self.encode(params, batch["enc_feats"])
            enc_kvs = self.enc_kvs(params, enc_out)
        prefix = batch.get("prefix_feats")

        h, _, aux = self.hidden_states(params, inputs, prefix_emb=prefix,
                                       enc_kvs=enc_kvs)
        if prefix is not None:
            h = h[:, prefix.shape[1]:]                      # predict text only

        if cfg.mach is not None:
            hashed = jnp.moveaxis(cfg.mach.hash_labels(labels), 0, -1)
            if cfg.mach_fused_loss:
                # logit-free fast path: projection fused into the CE —
                # the (B, T, R·Bk) logits tensor never exists in HBM.
                # Constraints pin the kernel's operand (and so cotangent)
                # shardings: dh on batch, dW on ("embed", "mach_rb").
                hc = constrain(h, ("batch", None, None))
                wk = constrain(params["mach_head"]["kernel"],
                               ("embed", "mach_rb"))
                # dynamic bucket selection: cfg.mach_bucket_select =
                # (c_sel, refresh_every) cuts the kernel C-axis to
                # R·c_sel; the trainer caches (R, B) proxy scores in
                # batch["bucket_proxy"] every refresh_every steps —
                # absent, the proxy is recomputed in-graph each step.
                per_tok = ops.mach_fused_xent(
                    hc, wk, hashed, num_buckets=cfg.mach.num_buckets,
                    bucket_select=cfg.mach_bucket_select,
                    bucket_proxy=batch.get("bucket_proxy"))
            else:
                logits = self.mach_logits(params, h)        # (B, T, R, Bk)
                per_tok = ops.mach_xent(logits, hashed)      # (B, T)
        else:
            logits = self.oaa_logits(params, h).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            # label pick via one-hot contraction, NOT take_along_axis: a
            # gather on the vocab-sharded logits would force XLA to
            # all-gather the full (B, T, V) f32 tensor per device; the
            # one-hot product-sum stays sharded on V end to end.
            onehot = jax.nn.one_hot(labels, cfg.vocab_size,
                                    dtype=logits.dtype)
            picked = jnp.sum(logits * onehot, axis=-1)
            per_tok = logz - picked
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        loss = jnp.sum(per_tok * weights) / denom
        total = loss
        metrics = {"loss": loss, "tokens": jnp.sum(weights)}
        if cfg.num_experts:
            total = total + cfg.lb_loss_coef * aux["load_balance"] \
                + cfg.z_loss_coef * aux["router_z"]
            metrics.update(aux)
        return total, metrics

    # --------------------------------------------------------------- serving
    def init_caches(self, batch_size: int, max_len: int,
                    linear_cap: Optional[int] = None):
        """Build the decode cache pytree mirroring the stack nesting.

        ``linear_cap`` (optional) overrides the capacity of *linear*
        attention caches only — ring caches keep their O(window)
        capacity and recurrent states are O(1).  The paged engine
        prefills with ``linear_cap`` = the page-rounded prompt length so
        the batch-1 prefill cache reshapes exactly into the slot's
        reserved pages instead of carrying a max_len strip."""
        cfg = self.cfg
        layout = self._dec_layout()
        stacks = plan_stacks(layout)
        caches = []
        hd = cfg.resolved_head_dim
        for period, n in stacks:
            st = []
            for kind in period:
                st.append(_init_kind_cache(cfg, kind, n, batch_size, max_len,
                                           hd, linear_cap=linear_cap))
            caches.append(st)
        return caches

    def init_paged_caches(self, num_slots: int, max_len: int,
                          page_size: int, num_pages: int):
        """Paged decode pool: linear attention caches become one shared
        ``(num_pages, page_size, KV, hd)`` page pool per layer with
        per-slot page tables; ring caches (O(window)) and recurrent
        states (O(1)) stay per-slot strips — they are not the
        worst-case-length pathology paging exists to kill."""
        cfg = self.cfg
        layout = self._dec_layout()
        stacks = plan_stacks(layout)
        max_pages = -(-max_len // page_size)
        paged = (num_pages, page_size, max_pages)
        caches = []
        hd = cfg.resolved_head_dim
        for period, n in stacks:
            st = []
            for kind in period:
                st.append(_init_kind_cache(cfg, kind, n, num_slots, max_len,
                                           hd, paged=paged))
            caches.append(st)
        return caches

    def prefill(self, params, batch: dict, max_len: int,
                linear_cap: Optional[int] = None):
        """Process the prompt; returns (caches, enc_kvs, last_hidden (B, d))."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        enc_kvs = None
        if cfg.num_encoder_layers:
            enc_out = self.encode(params, batch["enc_feats"])
            enc_kvs = self.enc_kvs(params, enc_out)
        prefix = batch.get("prefix_feats")
        caches = self.init_caches(b, max_len, linear_cap=linear_cap)
        h, caches, _ = self.hidden_states(params, tokens, prefix_emb=prefix,
                                          enc_kvs=enc_kvs, caches=caches)
        return caches, enc_kvs, h[:, -1]

    def decode_step(self, params, caches, enc_kvs, tokens: jnp.ndarray,
                    pos: jnp.ndarray, per_slot: bool = False):
        """One token step.  tokens (B,), pos (B,) absolute positions.
        Returns (caches, hidden (B, d)).

        ``per_slot=True`` writes each row's KV at its own cache index
        (continuous batching: rows are independent slots at different
        depths); the default writes all rows at the lockstep position."""
        h, caches, _ = self.hidden_states(
            params, tokens[:, None], enc_kvs=enc_kvs, caches=caches,
            positions=pos[:, None], decode=True, per_slot=per_slot)
        return caches, h[:, 0]

    # ----------------------------------------------------- slot-pool caches
    @staticmethod
    def insert_cache_slot(pool, one, slot):
        """Scatter a batch-1 cache/enc_kvs pytree into row ``slot`` of a
        pooled pytree (batch axis 1 on every leaf — the (n_layers, B, ...)
        stacking).  Used by the serving engine to admit a freshly
        prefilled request into a free slot of the fixed decode pool."""
        def put(p, o):
            return jax.lax.dynamic_update_index_in_dim(p, o[:, 0], slot,
                                                       axis=1)
        return jax.tree.map(put, pool, one)

    def reset_cache_slot(self, pool, slot, max_len: int):
        """Return ``pool`` with row ``slot`` restored to the freshly
        initialized state (empty positions, zero indices/recurrent
        state) so a freed slot carries nothing across requests."""
        return self.insert_cache_slot(pool, self.init_caches(1, max_len),
                                      slot)

    # ------------------------------------------------------ paged slot pool
    @staticmethod
    def insert_cache_slot_paged(pool, one, slot, pages):
        """Admit a batch-1 prefill cache into slot ``slot`` of a *paged*
        pool: linear-attention leaves scatter their page-rounded strips
        into the pool pages reserved by the allocator (``pages``, one id
        per prompt page) and set the slot's page table row; ring /
        recurrent leaves take the contiguous per-slot scatter."""
        def put(p, o):
            return jax.lax.dynamic_update_index_in_dim(p, o[:, 0], slot,
                                                       axis=1)
        out = []
        for p_st, o_st in zip(pool, one):
            row = []
            for pc, oc in zip(p_st, o_st):
                if isinstance(pc, attn_lib.PagedKVCache):
                    # leaves carry a leading stacked-layers dim; the page
                    # assignment is identical across layers
                    row.append(jax.vmap(
                        lambda c, o: attn_lib.paged_insert_prefill(
                            c, o, slot, pages))(pc, oc))
                else:
                    row.append(jax.tree.map(put, pc, oc))
            out.append(row)
        return out

    def reset_cache_slot_paged(self, pool, slot, max_len: int):
        """Free slot ``slot`` of a paged pool: page-table row → −1 and
        index → 0 on paged leaves (stale page contents stay — masking is
        position-driven, see ``paged_reset_slot``); ring / recurrent
        leaves are restored to their freshly initialized state."""
        fresh = None
        out = []
        for si, p_st in enumerate(pool):
            row = []
            for pi, pc in enumerate(p_st):
                if isinstance(pc, attn_lib.PagedKVCache):
                    row.append(jax.vmap(
                        lambda c: attn_lib.paged_reset_slot(c, slot))(pc))
                else:
                    if fresh is None:
                        fresh = self.init_caches(1, max_len)
                    row.append(jax.tree.map(
                        lambda p, o: jax.lax.dynamic_update_index_in_dim(
                            p, o[:, 0], slot, axis=1), pc, fresh[si][pi]))
            out.append(row)
        return out

    @staticmethod
    def append_cache_page(pool, slot, page_idx, page_id):
        """Grow ``slot``'s page table by one pool page at table position
        ``page_idx`` on every paged leaf (decode boundary crossing)."""
        out = []
        for p_st in pool:
            row = []
            for pc in p_st:
                if isinstance(pc, attn_lib.PagedKVCache):
                    row.append(jax.vmap(
                        lambda c: attn_lib.paged_append_page(
                            c, slot, page_idx, page_id))(pc))
                else:
                    row.append(pc)
            out.append(row)
        return out

    def next_token(self, params, hidden: jnp.ndarray):
        """Greedy next token from final hidden states (B, d).
        MACH path: fused decode kernel (never materializes (B, V)) —
        the top-1 summed-score kernel for the unbiased estimator, the
        k=1 streaming top-k kernel for min/median, so greedy decode
        always follows the configured prediction rule."""
        cfg = self.cfg
        if cfg.mach is not None:
            if cfg.mach.estimator != "unbiased":
                vals, idxs = self.topk_scores(params, hidden, 1)
                return idxs[:, 0], vals[:, 0]
            logits = self.mach_logits(params, hidden)        # (B, R, Bk)
            probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            fam = cfg.mach.family
            if getattr(fam, "inline_kernel_ok", False):
                val, idx = ops.mach_top1(
                    probs, num_classes=cfg.vocab_size,
                    inline_coeffs=jnp.asarray(fam.coeffs()),
                    inline_shift=fam.shift)
            else:
                val, idx = ops.mach_top1(probs, cfg.mach.table(),
                                         num_classes=cfg.vocab_size)
            return idx, val
        logits = self.oaa_logits(params, hidden)
        idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        val = jnp.max(logits, axis=-1)
        return idx, val

    def mach_inverted_table(self):
        """Cached (R·B, L) inverted bucket->class table (candidate-
        filtered decode).  Built host-side once per model instance."""
        if getattr(self, "_mach_inverted", None) is None:
            self._mach_inverted = self.cfg.mach.inverted_table()
        return self._mach_inverted

    def topk_scores(self, params, hidden: jnp.ndarray, k: int,
                    estimator: Optional[str] = None,
                    candidate_mode=None):
        """Top-k (values, class ids) from final hidden states (B, d).

        MACH path: the fused streaming top-k kernel — the (B, V) score
        matrix is never materialized; values are on the configured
        estimator's scale.  OAA path: plain ``lax.top_k`` over logits.

        ``candidate_mode``: None | "exact" stream all V classes; an
        (m, t) tuple routes through the count-min candidate filter
        (cost independent of V; filtered slots come back (-inf, -1)).
        Ignored on the OAA path."""
        cfg = self.cfg
        if cfg.mach is None:
            scores = self.oaa_logits(params, hidden).astype(jnp.float32)
            return jax.lax.top_k(scores, k)
        logits = self.mach_logits(params, hidden)                # (B, R, Bk)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        est = estimator or cfg.mach.estimator
        fam = cfg.mach.family
        filtered = candidate_mode is not None and candidate_mode != "exact"
        kw = dict(candidate_mode=candidate_mode,
                  inverted=self.mach_inverted_table() if filtered else None)
        if getattr(fam, "inline_kernel_ok", False):
            return ops.mach_topk(
                probs, num_classes=cfg.vocab_size, k=k, estimator=est,
                inline_coeffs=jnp.asarray(fam.coeffs()),
                inline_shift=fam.shift, **kw)
        return ops.mach_topk(probs, cfg.mach.table(),
                             num_classes=cfg.vocab_size, k=k, estimator=est,
                             **kw)

    def topk_candidates(self, params, hidden: jnp.ndarray, top_k: int,
                        estimator: Optional[str] = None,
                        candidate_mode=None):
        """Top-k sampling candidates (vals, idxs), each (B, top_k), on
        the *sampling* scale.

        MACH path: the fused streaming top-k over the requested
        estimator (Eq. 2/7/8) — no (B, V) tensor exists anywhere on this
        path — or, with an (m, t) ``candidate_mode``, the count-min
        candidate filter (cost independent of V).  For the unbiased
        estimator the values are rescaled back to the summed-score scale
        (Eq. 2's affine map would otherwise multiply the effective
        temperature by ~R), preserving the historical
        softmax(Σ_r scores / T) semantics exactly; min/median sample on
        their own scale."""
        cfg = self.cfg
        vals, idxs = self.topk_scores(params, hidden, top_k, estimator,
                                      candidate_mode)           # (B, k)
        if cfg.mach is not None:
            est = estimator or cfg.mach.estimator
            if est == "unbiased":
                r, b = cfg.mach.num_repetitions, cfg.mach.num_buckets
                # inverse of Eq. 2's affine map up to a per-row constant
                # (which cancels in the categorical)
                vals = vals * (r * (b - 1.0) / b)
        return vals, idxs

    @staticmethod
    def sample_from_candidates(vals, idxs, key, *, temperature=1.0,
                               row_top_k: Optional[jnp.ndarray] = None,
                               per_row_keys: bool = False):
        """Temperature/top-k categorical pick over (B, k) candidates.

        ``temperature`` may be a scalar or a per-row (B,) array;
        ``row_top_k`` (optional (B,) int) restricts each row to its own
        k_i <= top_k candidates (serving: per-request knobs inside one
        fused batched call).  Values are clamped to [1, top_k]: a row
        with k_i <= 0 would mask every candidate to -inf and make
        ``jax.random.categorical`` return an undefined index.

        ``per_row_keys=True`` takes ``key`` as a (B,) key array and
        draws each row from its own stream — the serving engine keys
        rows by (request id, token index) so a request's samples don't
        depend on which slot it lands in or who its batch neighbours
        are.  A row at temperature ~0 with row_top_k 1 is fully
        deterministic (its single unmasked candidate wins regardless of
        the Gumbel draw), which is what makes free/greedy slots inert."""
        top_k = vals.shape[-1]
        temp = jnp.maximum(jnp.asarray(temperature, jnp.float32), 1e-6)
        if temp.ndim:
            temp = temp[:, None]
        logits_k = vals / temp
        if row_top_k is not None:
            row_k = jnp.clip(jnp.asarray(row_top_k, jnp.int32), 1, top_k)
            rank = jnp.arange(top_k, dtype=jnp.int32)[None]     # (1, k)
            logits_k = jnp.where(rank < row_k[:, None], logits_k,
                                 -jnp.inf)
        if per_row_keys:
            gk = jax.vmap(jax.random.categorical)(key, logits_k)
        else:
            gk = jax.random.categorical(key, logits_k)
        picked = jnp.take_along_axis(idxs, gk[:, None], axis=-1)[:, 0]
        return picked.astype(jnp.int32)

    def sample_token(self, params, hidden: jnp.ndarray, key: jax.Array,
                     *, temperature=1.0, top_k: int = 50,
                     row_top_k: Optional[jnp.ndarray] = None,
                     estimator: Optional[str] = None):
        """Top-k temperature sampling from final hidden states (B, d):
        ``topk_candidates`` (fused streaming top-k on the MACH path)
        followed by ``sample_from_candidates``."""
        vals, idxs = self.topk_candidates(params, hidden, top_k, estimator)
        return self.sample_from_candidates(vals, idxs, key,
                                           temperature=temperature,
                                           row_top_k=row_top_k)


def _init_kind_cache(cfg: ModelConfig, kind: str, n: int, batch: int,
                     max_len: int, hd: int,
                     linear_cap: Optional[int] = None,
                     paged: Optional[tuple] = None):
    """Stacked (n, ...) cache for one period position.

    ``paged`` = (num_pages, page_size, max_pages) turns *linear*
    attention caches into a shared page pool + per-slot page tables
    (``batch`` is then the slot count); ring caches (window < max_len)
    keep their O(window) strips.  ``linear_cap`` (mutually exclusive in
    practice) overrides only the linear-cache capacity — the paged
    engine's batch-1 prefill path."""
    if kind in ("attn", "moe", "xattn", "attn_local"):
        kv = cfg.num_kv_heads
        window = cfg.block_window(kind)
        ring = window is not None and window < max_len
        if not ring and paged is not None:
            num_pages, page_size, max_pages = paged
            return attn_lib.PagedKVCache(
                k=jnp.zeros((n, num_pages, page_size, kv, hd), cfg.dtype),
                v=jnp.zeros((n, num_pages, page_size, kv, hd), cfg.dtype),
                positions=jnp.full((n, num_pages, page_size), -1, jnp.int32),
                page_table=jnp.full((n, batch, max_pages), -1, jnp.int32),
                index=jnp.zeros((n, batch), jnp.int32),
            )
        if ring:
            cap = window
        else:
            cap = linear_cap if linear_cap else max_len
            if window is not None:
                cap = min(cap, window)
        return attn_lib.KVCache(
            k=jnp.zeros((n, batch, cap, kv, hd), cfg.dtype),
            v=jnp.zeros((n, batch, cap, kv, hd), cfg.dtype),
            positions=jnp.full((n, batch, cap), -1, jnp.int32),
            index=jnp.zeros((n, batch), jnp.int32),
        )
    if kind == "rglru":
        w = cfg.resolved_rnn_width
        return recurrent.RecurrentState(
            conv=jnp.zeros((n, batch, recurrent._CONV_W - 1, w), cfg.dtype),
            h=jnp.zeros((n, batch, w), jnp.float32),
        )
    if kind == "mlstm":
        di = int(cfg.d_model * cfg.mlstm_proj)
        hdm = di // cfg.num_heads
        return xlstm.MLSTMState(
            c=jnp.zeros((n, batch, cfg.num_heads, hdm, hdm), jnp.float32),
            n=jnp.zeros((n, batch, cfg.num_heads, hdm), jnp.float32),
            m=jnp.full((n, batch, cfg.num_heads), -1e30, jnp.float32),
        )
    if kind == "slstm":
        hds = cfg.d_model // cfg.num_heads
        def z():
            # distinct buffers per field: donated cache pools reject
            # pytrees whose leaves alias one array
            return jnp.zeros((n, batch, cfg.num_heads, hds), jnp.float32)
        return xlstm.SLSTMState(c=z(), n=z() + 1e-6, h=z(), m=z() - 1e30)
    raise ValueError(kind)
