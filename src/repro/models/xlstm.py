"""xLSTM blocks (xlstm-350m substrate): mLSTM and sLSTM.

mLSTM — matrix-memory LSTM with exponential gating; attention-free.
  Parallel (training/prefill) form, stabilized as in the xLSTM paper:
     logD[t,s] = Σ_{j=s+1..t} log f_j + log i_s          (s ≤ t)
     m_t = max_s logD[t,s]
     S[t,s] = (q_t·k_s/√d) · exp(logD[t,s] − m_t)
     h_t = Σ_s S[t,s] v_s / max(|Σ_s S[t,s]|, exp(−m_t))
  Recurrent (decode) form:
     C_t = f̄ C_{t−1} + ī v k^T;  n_t = f̄ n_{t−1} + ī k
     m_t = max(log f + m_{t−1}, log i);  f̄ = e^{log f + m_{t−1} − m_t}, ī = e^{log i − m_t}
     h_t = C_t q / max(|n_t·q|, exp(−m_t))
  The two forms are algebraically identical (tested).

sLSTM — scalar-memory LSTM with recurrent memory mixing (block-diagonal
  per-head R matrices); *inherently sequential*, runs as lax.scan for
  any T, one step for decode.

Both blocks follow the paper's pre-LN residual structure; the mLSTM
block has an (up → gate → down) projection shell (proj factor 2), the
sLSTM block a gated-FFN shell (proj factor 4/3 ≈ "ffn_proj").
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, H, hd, hd) matrix memory
    n: jnp.ndarray   # (B, H, hd) normalizer
    m: jnp.ndarray   # (B, H) stabilizer


def init_mlstm_block(key, d_model: int, num_heads: int, proj_factor: float = 2.0):
    di = int(d_model * proj_factor)
    hd = di // num_heads
    ku, kq, kk, kv, ki, kf, ko, kd = jax.random.split(key, 8)
    p, a = {}, {}
    p["up"], a["up"] = layers.init_dense(ku, d_model, (2 * di,), "embed", ("mlp",))
    p["q"], a["q"] = layers.init_dense(kq, di, (num_heads, hd), "mlp", ("heads", "qkv"))
    p["k"], a["k"] = layers.init_dense(kk, di, (num_heads, hd), "mlp", ("heads", "qkv"))
    p["v"], a["v"] = layers.init_dense(kv, di, (num_heads, hd), "mlp", ("heads", "qkv"))
    p["igate"], a["igate"] = layers.init_dense(ki, di, (num_heads,), "mlp", ("heads",))
    p["fgate"], a["fgate"] = layers.init_dense(kf, di, (num_heads,), "mlp", ("heads",))
    # forget bias init positive so early training doesn't wash memory
    p["gate_bias"] = {"i": jnp.zeros((num_heads,), jnp.float32),
                      "f": jnp.full((num_heads,), 3.0, jnp.float32)}
    a["gate_bias"] = {"i": ("heads",), "f": ("heads",)}
    p["ln_inner"], a["ln_inner"] = layers.init_norm(di, "rmsnorm", "mlp")
    p["down"], a["down"] = layers.init_dense(kd, di, (d_model,), "mlp", ("embed",))
    return p, a


def _mlstm_parallel(q, k, v, log_i, log_f):
    """q/k/v: (B, T, H, hd); log_i/log_f: (B, T, H) -> h: (B, T, H, hd)."""
    b, t, h, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    clf = jnp.cumsum(log_f, axis=1)                       # (B, T, H)
    # logD[t, s] = clf[t] - clf[s] + log_i[s], s <= t
    logd = clf[:, :, None, :] - clf[:, None, :, :] + log_i[:, None, :, :]
    tri = jnp.tril(jnp.ones((t, t), bool))
    logd = jnp.where(tri[None, :, :, None], logd, -jnp.inf)   # (B,T,S,H)
    m = jnp.max(logd, axis=2)                             # (B, T, H)
    d = jnp.exp(logd - m[:, :, None, :])
    s = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32)) * d
    norm = jnp.maximum(jnp.abs(jnp.sum(s, axis=2)), jnp.exp(-m))  # (B,T,H)
    out = jnp.einsum("btsh,bshd->bthd", s, v.astype(jnp.float32))
    return (out / norm[..., None]).astype(q.dtype)


def _mlstm_chunkwise(q, k, v, log_i, log_f, state: MLSTMState,
                     chunk: int = 256):
    """Chunkwise-parallel mLSTM: O(T·chunk) memory instead of O(T²).

    Within each chunk the stabilized quadratic form runs in parallel;
    across chunks the (C, n, m) recurrent state is carried — the exact
    same semantics as the per-step recurrence (tested), which is what
    makes 32k-token prefill feasible (the pure quadratic form would
    materialize a (B, 32k, 32k, H) tensor).

    q/k/v: (B, T, H, hd); log_i/log_f: (B, T, H); T % chunk == 0.
    Returns (h (B, T, H, hd), final MLSTMState).
    """
    b, t, h, hd = q.shape
    nc = t // chunk
    scale = 1.0 / math.sqrt(hd)

    def resh4(x):
        return jnp.moveaxis(x.reshape(b, nc, chunk, h, hd), 1, 0)

    def resh3(x):
        return jnp.moveaxis(x.reshape(b, nc, chunk, h), 1, 0)

    qs, ks, vs = resh4(q), resh4(k), resh4(v)
    lis, lfs = resh3(log_i), resh3(log_f)

    def qs_cast(x):
        return x.astype(jnp.float32)

    def chunk_body(st, xs):
        qc, kc, vc, li, lf = xs                   # (B, ck, H, ...)
        clf = jnp.cumsum(lf, axis=1)              # (B, ck, H)
        # intra-chunk log decay matrix
        logd = clf[:, :, None, :] - clf[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        logd = jnp.where(tri[None, :, :, None], logd, -jnp.inf)
        intra_max = jnp.max(logd, axis=2)         # (B, ck, H)
        w_inter = clf + st.m[:, None, :]          # (B, ck, H)
        m_t = jnp.maximum(intra_max, w_inter)
        d = jnp.exp(logd - m_t[:, :, None, :])
        inter = jnp.exp(w_inter - m_t)            # (B, ck, H)

        qf = qs_cast(qc) * scale
        s = jnp.einsum("bthd,bshd->btsh", qf, qs_cast(kc)) * d
        num = jnp.einsum("btsh,bshd->bthd", s, qs_cast(vc)) \
            + inter[..., None] * jnp.einsum("bhij,bthi->bthj", st.c, qf)
        den_sum = jnp.sum(s, axis=2) \
            + inter * jnp.einsum("bhi,bthi->bth", st.n, qf)
        den = jnp.maximum(jnp.abs(den_sum), jnp.exp(-m_t))
        hout = num / den[..., None]

        # end-of-chunk state
        wlog = clf[:, -1:, :] - clf + li          # (B, ck, H)
        m_new = jnp.maximum(jnp.max(wlog, axis=1),
                            clf[:, -1] + st.m)    # (B, H)
        wk = jnp.exp(wlog - m_new[:, None, :])
        carry_scale = jnp.exp(clf[:, -1] + st.m - m_new)
        c_new = jnp.einsum("bsh,bshi,bshj->bhij", wk, qs_cast(kc),
                           qs_cast(vc)) \
            + carry_scale[..., None, None] * st.c
        n_new = jnp.einsum("bsh,bshd->bhd", wk, qs_cast(kc)) \
            + carry_scale[..., None] * st.n
        return MLSTMState(c_new, n_new, m_new), hout

    st, hs = jax.lax.scan(chunk_body, state, (qs, ks, vs, lis, lfs))
    hseq = jnp.moveaxis(hs, 0, 1).reshape(b, t, h, hd)
    return hseq.astype(q.dtype), st


def _mlstm_step(state: MLSTMState, q, k, v, log_i, log_f):
    """One decode step. q/k/v: (B, H, hd); log gates: (B, H)."""
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    m_new = jnp.maximum(log_f + state.m, log_i)           # (B, H)
    fbar = jnp.exp(log_f + state.m - m_new)[..., None]
    ibar = jnp.exp(log_i - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = state.c * fbar[..., None] + ibar[..., None] * vf[..., None, :] * kf[..., :, None]
    n = state.n * fbar + ibar * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhij,bhi->bhj", c, qf)              # (B, H, hd)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return MLSTMState(c, n, m_new), h


def apply_mlstm_block(params, x: jnp.ndarray,
                      state: Optional[MLSTMState] = None,
                      decode: bool = False):
    """x: (B, T, d) -> (y, new_state).  decode=True requires T == 1."""
    b, t, _ = x.shape
    nh = params["igate"]["kernel"].shape[1]
    up = layers.dense(params["up"], x)
    di = up.shape[-1] // 2
    xm, z = up[..., :di], up[..., di:]
    q = layers.dense(params["q"], xm)
    k = layers.dense(params["k"], xm) / math.sqrt(q.shape[-1])
    v = layers.dense(params["v"], xm)
    log_i = (layers.dense(params["igate"], xm).astype(jnp.float32)
             + params["gate_bias"]["i"])
    log_f = jax.nn.log_sigmoid(
        layers.dense(params["fgate"], xm).astype(jnp.float32)
        + params["gate_bias"]["f"])
    if decode:
        if state is None:
            hd = q.shape[-1]
            state = init_mlstm_state(b, nh, hd)
        state, h1 = _mlstm_step(state, q[:, 0], k[:, 0], v[:, 0],
                                log_i[:, 0], log_f[:, 0])
        h = h1[:, None]
    else:
        if state is None:
            state = init_mlstm_state(b, nh, q.shape[-1])
        chunk = 256
        if t > chunk and t % chunk == 0:
            h, state = _mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk)
        elif t % 64 == 0 and t > 64:
            h, state = _mlstm_chunkwise(q, k, v, log_i, log_f, state, 64)
        else:
            h, state = _mlstm_chunkwise(q, k, v, log_i, log_f, state, t)
    h = h.reshape(b, t, di)
    h = layers.apply_norm(params["ln_inner"], h, "rmsnorm")
    out = layers.dense(params["down"], h * jax.nn.silu(z))
    return out, state


def init_mlstm_state(batch: int, num_heads: int, head_dim: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        n=jnp.zeros((batch, num_heads, head_dim), jnp.float32),
        m=jnp.full((batch, num_heads), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, H, hd) cell
    n: jnp.ndarray   # (B, H, hd) normalizer
    h: jnp.ndarray   # (B, H, hd) hidden (memory mixing input)
    m: jnp.ndarray   # (B, H, hd) stabilizer


def init_slstm_block(key, d_model: int, num_heads: int,
                     ffn_factor: float = 4.0 / 3.0):
    hd = d_model // num_heads
    kz, ki, kf, ko, kr, kffn = jax.random.split(key, 6)
    p, a = {}, {}
    for name, kk in (("wz", kz), ("wi", ki), ("wf", kf), ("wo", ko)):
        p[name], a[name] = layers.init_dense(kk, d_model, (num_heads, hd),
                                             "embed", ("heads", "qkv"))
    # block-diagonal recurrent mixing: (4 gates, H, hd, hd)
    p["r"] = {"kernel": layers.truncated_normal_init(kr, (4, num_heads, hd, hd),
                                                     1.0)}
    a["r"] = {"kernel": (None, "heads", "qkv", None)}
    p["gate_bias"] = {"i": jnp.zeros((num_heads, hd), jnp.float32),
                      "f": jnp.full((num_heads, hd), 3.0, jnp.float32),
                      "z": jnp.zeros((num_heads, hd), jnp.float32),
                      "o": jnp.zeros((num_heads, hd), jnp.float32)}
    a["gate_bias"] = {k: ("heads", "qkv") for k in ("i", "f", "z", "o")}
    p["ln_inner"], a["ln_inner"] = layers.init_norm(d_model, "rmsnorm", "embed")
    dff = int(d_model * ffn_factor)
    p["ffn"], a["ffn"] = layers.init_mlp(kffn, d_model, dff, "geglu")
    return p, a


def _slstm_step(params, state: SLSTMState, xz, xi, xf, xo):
    """All inputs (B, H, hd)."""
    r = params["r"]["kernel"].astype(jnp.float32)
    hprev = state.h
    mix = jnp.einsum("bhd,ghde->gbhe", hprev, r)      # (4, B, H, hd)
    gb = params["gate_bias"]
    z = jnp.tanh(xz + mix[0] + gb["z"])
    log_i = xi + mix[1] + gb["i"]
    log_f = jax.nn.log_sigmoid(xf + mix[2] + gb["f"])
    o = jax.nn.sigmoid(xo + mix[3] + gb["o"])
    m_new = jnp.maximum(log_f + state.m, log_i)
    fbar = jnp.exp(log_f + state.m - m_new)
    ibar = jnp.exp(log_i - m_new)
    c = fbar * state.c + ibar * z
    n = jnp.maximum(fbar * state.n + ibar, 1e-6)
    h = o * c / n
    return SLSTMState(c=c, n=n, h=h, m=m_new), h


def apply_slstm_block(params, x: jnp.ndarray,
                      state: Optional[SLSTMState] = None,
                      decode: bool = False):
    """x: (B, T, d) -> (y, new_state).  Sequential scan over T."""
    b, t, d = x.shape
    nh, hd = params["wz"]["kernel"].shape[1:]
    xz = layers.dense(params["wz"], x).astype(jnp.float32)
    xi = layers.dense(params["wi"], x).astype(jnp.float32)
    xf = layers.dense(params["wf"], x).astype(jnp.float32)
    xo = layers.dense(params["wo"], x).astype(jnp.float32)
    if state is None:
        state = init_slstm_state(b, nh, hd)

    if decode:
        state, h = _slstm_step(params, state, xz[:, 0], xi[:, 0],
                               xf[:, 0], xo[:, 0])
        hseq = h[:, None]
    else:
        def step(st, inp):
            st, h = _slstm_step(params, st, *inp)
            return st, h
        xs = tuple(jnp.moveaxis(u, 1, 0) for u in (xz, xi, xf, xo))
        state, hs = jax.lax.scan(step, state, xs)
        hseq = jnp.moveaxis(hs, 0, 1)                 # (B, T, H, hd)
    hflat = hseq.reshape(b, -1, d).astype(x.dtype)
    hflat = layers.apply_norm(params["ln_inner"], hflat, "rmsnorm")
    out = hflat + layers.apply_mlp(params["ffn"], hflat, "geglu")
    return out, state


def init_slstm_state(batch: int, num_heads: int, head_dim: int) -> SLSTMState:
    z = jnp.zeros((batch, num_heads, head_dim), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, h=z, m=z - 1e30)
