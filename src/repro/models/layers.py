"""Shared neural-net layers (pure-functional, pytree params).

Every ``init_*`` returns ``(params, axes)`` — two pytrees of identical
structure where ``axes`` holds the *logical* axis name per dimension of
each parameter (or None).  ``sharding/partitioning.py`` maps logical
axes onto the device mesh; models never mention mesh axes directly.

Logical axis vocabulary:
  batch, seq            activations
  embed                 d_model
  mlp                   feed-forward hidden
  heads, kv_heads, qkv  attention projections (qkv = head_dim)
  vocab                 embedding / OAA softmax rows
  mach_rb               MACH head output (R·B)
  experts               MoE expert dimension
  layers                stacked-scan layer dimension (never sharded)
"""

from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    """Fan-in scaled truncated normal (MaxText-style default)."""
    stddev = scale / max(1.0, math.sqrt(shape[0] if len(shape) else 1))
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype) * stddev


def init_dense(key, in_dim: int, out_dims: Sequence[int],
               in_axis: Optional[str], out_axes: Sequence[Optional[str]],
               scale: float = 1.0):
    """Dense kernel (in_dim, *out_dims) with fan-in init."""
    shape = (in_dim,) + tuple(out_dims)
    w = truncated_normal_init(key, shape, scale)
    return {"kernel": w}, {"kernel": (in_axis,) + tuple(out_axes)}


def dense(params, x: jnp.ndarray, ndim_out: int = 1) -> jnp.ndarray:
    """x (..., in) @ kernel (in, *out) -> (..., *out)."""
    k = params["kernel"].astype(x.dtype)
    return jax.lax.dot_general(
        x, k, (((x.ndim - 1,), (0,)), ((), ())))


def init_norm(dim: int, kind: str = "rmsnorm", axis: Optional[str] = None):
    if kind == "rmsnorm":
        return ({"scale": jnp.ones((dim,), jnp.float32)},
                {"scale": (axis,)})
    if kind == "layernorm":
        return ({"scale": jnp.ones((dim,), jnp.float32),
                 "bias": jnp.zeros((dim,), jnp.float32)},
                {"scale": (axis,), "bias": (axis,)})
    raise ValueError(kind)


def apply_norm(params, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def init_embedding(key, vocab: int, dim: int):
    emb = truncated_normal_init(key, (vocab, dim), scale=1.0)
    return {"embedding": emb}, {"embedding": ("vocab", "embed")}


def embed(params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0).astype(dtype)


def unembed(params, h: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: h (..., d) @ E^T (d, V)."""
    e = params["embedding"].astype(h.dtype)
    return jax.lax.dot_general(h, e, (((h.ndim - 1,), (1,)), ((), ())))


# ---------------------------------------------------------------------------
# Activations / gated MLP
# ---------------------------------------------------------------------------

ACT = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def init_mlp(key, d_model: int, d_ff: int, activation: str = "swiglu",
             mlp_axis: str = "mlp"):
    """Gated (swiglu/geglu) or plain MLP."""
    k1, k2, k3 = jax.random.split(key, 3)
    gated = activation in ("swiglu", "geglu")
    p, a = {}, {}
    p["wi"], a["wi"] = {}, {}
    pi, ai = init_dense(k1, d_model, (d_ff,), "embed", (mlp_axis,))
    p["wi"], a["wi"] = pi, ai
    if gated:
        pg, ag = init_dense(k2, d_model, (d_ff,), "embed", (mlp_axis,))
        p["wg"], a["wg"] = pg, ag
    po, ao = init_dense(k3, d_ff, (d_model,), mlp_axis, ("embed",))
    p["wo"], a["wo"] = po, ao
    return p, a


def apply_mlp(params, x: jnp.ndarray, activation: str = "swiglu") -> jnp.ndarray:
    h = dense(params["wi"], x)
    if activation == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * h
    elif activation == "geglu":
        h = jax.nn.gelu(dense(params["wg"], x)) * h
    else:
        h = ACT[activation](h)
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
         ) -> jnp.ndarray:
    """Rotary embedding.  x: (..., T, H, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq        # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]                             # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# pytree helpers shared by model assembly
# ---------------------------------------------------------------------------

def stack_inits(init_fn: Callable[[jax.Array], tuple[dict, dict]],
                key: jax.Array, n: int):
    """Initialize n copies of a module and stack leaves on axis 0
    (the 'layers' scan dim).  Returns (params, axes) with axes gaining a
    leading 'layers' entry."""
    keys = jax.random.split(key, n)
    ps, axs = [], None
    for i in range(n):
        p, a = init_fn(keys[i])
        ps.append(p)
        axs = a
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)
    axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                        axs, is_leaf=lambda v: isinstance(v, tuple))
    return stacked, axes
