"""RG-LRU recurrent block (Griffin / recurrentgemma substrate).

Block layout (Griffin Fig. 2):
    x ─ linear_y ─ GeLU ─────────────────────┐
    x ─ linear_x ─ causal conv1d(4) ─ RG-LRU ┴ ⊙ ─ linear_out

RG-LRU (paper eq. 1-4):
    r_t = σ(W_a ξ_t);  i_t = σ(W_x ξ_t)
    log a_t = −c · softplus(Λ) ⊙ r_t                 (c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ ξ_t)

The recurrence runs through ``kernels/ops.lru_scan`` (Pallas on TPU,
associative scan elsewhere).  Decode carries (conv tail, h) as state —
O(1) memory in sequence length, which is what qualifies recurrentgemma
for the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers

_C = 8.0
_CONV_W = 4


class RecurrentState(NamedTuple):
    conv: jnp.ndarray   # (B, CONV_W-1, W) trailing inputs
    h: jnp.ndarray      # (B, W) recurrence state


def init_rglru_block(key, d_model: int, width: Optional[int] = None):
    w = width or d_model
    ky, kx, kc, ka, ki, ko, kl = jax.random.split(key, 7)
    p, a = {}, {}
    p["lin_y"], a["lin_y"] = layers.init_dense(ky, d_model, (w,), "embed", ("mlp",))
    p["lin_x"], a["lin_x"] = layers.init_dense(kx, d_model, (w,), "embed", ("mlp",))
    p["conv"] = {"w": layers.truncated_normal_init(kc, (_CONV_W, w), 1.0),
                 "b": jnp.zeros((w,), jnp.float32)}
    a["conv"] = {"w": (None, "mlp"), "b": ("mlp",)}
    p["gate_a"], a["gate_a"] = layers.init_dense(ka, w, (w,), "mlp", ("mlp",))
    p["gate_x"], a["gate_x"] = layers.init_dense(ki, w, (w,), "mlp", ("mlp",))
    # Λ init so that a^(1/r) spans ~[0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(kl, (w,), jnp.float32, 0.9, 0.999)
    p["lam"] = {"log": jnp.log(jnp.expm1(-jnp.log(u) / _C))}
    a["lam"] = {"log": ("mlp",)}
    p["lin_out"], a["lin_out"] = layers.init_dense(ko, w, (d_model,), "mlp", ("embed",))
    return p, a


def _causal_conv(params, x: jnp.ndarray, tail: Optional[jnp.ndarray]
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel causal conv, width 4.  x: (B, T, W).

    tail: (B, 3, W) previous inputs (decode) or None (prefill from zero).
    Returns (y, new_tail)."""
    b, t, w = x.shape
    if tail is None:
        tail = jnp.zeros((b, _CONV_W - 1, w), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)   # (B, T+3, W)
    y = jnp.zeros_like(x)
    cw = params["w"].astype(x.dtype)
    for i in range(_CONV_W):
        y = y + xp[:, i:i + t] * cw[_CONV_W - 1 - i]
    y = y + params["b"].astype(x.dtype)
    return y, xp[:, -( _CONV_W - 1):]


def _rglru(params, xi: jnp.ndarray, h0: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """xi: (B, T, W) conv output; h0: (B, W). Returns (h_seq, h_last)."""
    r = jax.nn.sigmoid(layers.dense(params["gate_a"], xi).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense(params["gate_x"], xi).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]["log"]) * r     # (B, T, W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bterm = mult * i * xi.astype(jnp.float32)
    h = ops.lru_scan(a, bterm, h0.astype(jnp.float32))
    return h.astype(xi.dtype), h[:, -1].astype(jnp.float32)


def apply_rglru_block(params, x: jnp.ndarray,
                      state: Optional[RecurrentState] = None
                      ) -> tuple[jnp.ndarray, RecurrentState]:
    """x: (B, T, d_model) -> (y, new_state).  state=None starts at zero."""
    b = x.shape[0]
    w = params["lin_y"]["kernel"].shape[1]
    ybr = jax.nn.gelu(layers.dense(params["lin_y"], x))
    xbr = layers.dense(params["lin_x"], x)
    tail = state.conv if state is not None else None
    h0 = state.h if state is not None else jnp.zeros((b, w), jnp.float32)
    xc, new_tail = _causal_conv(params["conv"], xbr, tail)
    hseq, h_last = _rglru(params, xc, h0)
    out = layers.dense(params["lin_out"], hseq * ybr)
    return out, RecurrentState(conv=new_tail, h=h_last)


def init_recurrent_state(batch: int, width: int, dtype=jnp.bfloat16
                         ) -> RecurrentState:
    return RecurrentState(
        conv=jnp.zeros((batch, _CONV_W - 1, width), dtype),
        h=jnp.zeros((batch, width), jnp.float32),
    )
