"""Synthetic extreme-classification dataset (ODP / ImageNet-21k stand-in).

The paper's datasets are not redistributable offline, so experiments run
on a generator with a *known Bayes-optimal classifier*: class centroids
μ_k on the unit sphere, x = normalize(μ_y + σ·ε).  This is strictly more
informative than reproducing one accuracy number — we can verify MACH's
accuracy as a *fraction of the Bayes accuracy* across (B, R), which is
the paper's Figure-1 tradeoff with ground truth attached.

Deterministic: sample i is a pure function of (seed, i); restart-safe
like data/lm.py.  Class frequencies are Zipf (extreme classification's
signature long tail — most ODP classes are rare).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ExtremeDataConfig:
    num_classes: int
    dim: int
    noise: float = 0.5
    seed: int = 0
    zipf_a: float = 1.0          # 0 = uniform class frequencies


class ExtremeDataset:

    def __init__(self, cfg: ExtremeDataConfig):
        self.cfg = cfg
        key = jax.random.key(cfg.seed)
        kc, = jax.random.split(key, 1)
        mu = jax.random.normal(kc, (cfg.num_classes, cfg.dim), jnp.float32)
        self.centroids = mu / jnp.linalg.norm(mu, axis=1, keepdims=True)
        if cfg.zipf_a > 0:
            ranks = np.arange(1, cfg.num_classes + 1, dtype=np.float64)
            w = ranks ** (-cfg.zipf_a)
            self.class_probs = jnp.asarray(w / w.sum(), jnp.float32)
        else:
            self.class_probs = None

    def batch_at(self, step: int, batch_size: int, split: str = "train"
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x (B, d), y (B,)).  Splits use disjoint key spaces."""
        cfg = self.cfg
        base = jax.random.fold_in(jax.random.key(cfg.seed + 1),
                                  {"train": 0, "test": 1}[split])
        key = jax.random.fold_in(base, step)
        ky, kn = jax.random.split(key)
        if self.class_probs is not None:
            y = jax.random.choice(ky, cfg.num_classes, (batch_size,),
                                  p=self.class_probs)
        else:
            y = jax.random.randint(ky, (batch_size,), 0, cfg.num_classes)
        eps = jax.random.normal(kn, (batch_size, cfg.dim), jnp.float32)
        x = self.centroids[y] + cfg.noise * eps
        x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
        return x, y.astype(jnp.int32)

    def bayes_predict(self, x: jnp.ndarray) -> jnp.ndarray:
        """Nearest-centroid = Bayes-optimal under isotropic noise
        (ignoring the mild Zipf prior)."""
        return jnp.argmax(x @ self.centroids.T, axis=-1).astype(jnp.int32)

    def bayes_accuracy(self, steps: int = 8, batch_size: int = 512) -> float:
        accs = []
        for s in range(steps):
            x, y = self.batch_at(10_000 + s, batch_size, "test")
            accs.append(float(jnp.mean(self.bayes_predict(x) == y)))
        return float(np.mean(accs))
