"""Synthetic extreme-classification dataset (ODP / ImageNet-21k stand-in).

The paper's datasets are not redistributable offline, so experiments run
on a generator with a *known Bayes-optimal classifier*: class centroids
μ_k on the unit sphere, x = normalize(μ_y + σ·ε).  This is strictly more
informative than reproducing one accuracy number — we can verify MACH's
accuracy as a *fraction of the Bayes accuracy* across (B, R), which is
the paper's Figure-1 tradeoff with ground truth attached.

Sparse features (the paper's ODP regime — bag-of-words, d=422k,
~100 nonzeros/doc): ``SparseExtremeDataset`` emits CSR ``SparseBatch``es
from a Zipf-sparse generator — each class owns a random signature set of
feature ids, each sample carries those plus Zipf-popular background
noise features — with the dense fallback (``to_dense`` / ``format=
"dense"``) retained as the exact densification of the same batch, so
the fused-CSR and materializing training paths see identical data.

Deterministic: sample i is a pure function of (seed, i); restart-safe
like data/lm.py.  Class frequencies are Zipf (extreme classification's
signature long tail — most ODP classes are rare).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseBatch:
    """A CSR batch of sparse feature vectors.

    Row n's features are ``indices[indptr[n]:indptr[n+1]]`` with weights
    ``values[...]``; duplicate indices within a row sum on
    densification (scatter-add semantics, matching the fused kernel).
    ``num_features`` (d) and ``nnz_max`` (longest row — the kernel's
    static J extent) are aux metadata, so SparseBatch traces through
    ``jax.jit`` as a pytree with static shape info.
    """

    indptr: jnp.ndarray     # (N+1,) int32
    indices: jnp.ndarray    # (nnz,) int32
    values: jnp.ndarray     # (nnz,) float
    num_features: int
    nnz_max: int

    @property
    def num_rows(self) -> int:
        return self.indptr.shape[0] - 1

    def to_dense(self) -> jnp.ndarray:
        """(N, d) densification — the materializing-path fallback."""
        from repro.kernels.ref import csr_densify_ref  # single source
        return csr_densify_ref(self.indptr, self.indices, self.values,
                               self.num_features)


jax.tree_util.register_pytree_node(
    SparseBatch,
    lambda sb: ((sb.indptr, sb.indices, sb.values),
                (sb.num_features, sb.nnz_max)),
    lambda aux, ch: SparseBatch(*ch, *aux),
)


@dataclasses.dataclass(frozen=True)
class ExtremeDataConfig:
    num_classes: int
    dim: int
    noise: float = 0.5
    seed: int = 0
    zipf_a: float = 1.0          # 0 = uniform class frequencies


class ExtremeDataset:

    def __init__(self, cfg: ExtremeDataConfig):
        self.cfg = cfg
        key = jax.random.key(cfg.seed)
        kc, = jax.random.split(key, 1)
        mu = jax.random.normal(kc, (cfg.num_classes, cfg.dim), jnp.float32)
        self.centroids = mu / jnp.linalg.norm(mu, axis=1, keepdims=True)
        if cfg.zipf_a > 0:
            ranks = np.arange(1, cfg.num_classes + 1, dtype=np.float64)
            w = ranks ** (-cfg.zipf_a)
            self.class_probs = jnp.asarray(w / w.sum(), jnp.float32)
        else:
            self.class_probs = None

    def batch_at(self, step: int, batch_size: int, split: str = "train"
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x (B, d), y (B,)).  Splits use disjoint key spaces."""
        cfg = self.cfg
        base = jax.random.fold_in(jax.random.key(cfg.seed + 1),
                                  {"train": 0, "test": 1}[split])
        key = jax.random.fold_in(base, step)
        ky, kn = jax.random.split(key)
        if self.class_probs is not None:
            y = jax.random.choice(ky, cfg.num_classes, (batch_size,),
                                  p=self.class_probs)
        else:
            y = jax.random.randint(ky, (batch_size,), 0, cfg.num_classes)
        eps = jax.random.normal(kn, (batch_size, cfg.dim), jnp.float32)
        x = self.centroids[y] + cfg.noise * eps
        x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
        return x, y.astype(jnp.int32)

    def bayes_predict(self, x: jnp.ndarray) -> jnp.ndarray:
        """Nearest-centroid = Bayes-optimal under isotropic noise
        (ignoring the mild Zipf prior)."""
        return jnp.argmax(x @ self.centroids.T, axis=-1).astype(jnp.int32)

    def bayes_accuracy(self, steps: int = 8, batch_size: int = 512) -> float:
        accs = []
        for s in range(steps):
            x, y = self.batch_at(10_000 + s, batch_size, "test")
            accs.append(float(jnp.mean(self.bayes_predict(x) == y)))
        return float(np.mean(accs))


# ---------------------------------------------------------------------------
# Zipf-sparse feature generator (the ODP bag-of-words regime).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparseExtremeDataConfig:
    num_classes: int
    num_features: int            # d — the sparse feature space
    nnz: int = 32                # max nonzeros per example (= nnz_max)
    sig_features: int = 16       # class-signature features per class
    noise: float = 0.3           # value scale of background features
    seed: int = 0
    zipf_a: float = 1.0          # class-frequency Zipf (0 = uniform)
    feature_zipf_a: float = 1.0  # background-feature popularity Zipf
    length_zipf_a: float = 0.0   # doc-length Zipf: 0 = every row has
    #                              exactly nnz entries; > 0 = ragged
    #                              rows, length in [sig_features, nnz]
    #                              with P(len = sig + t) ∝ (1+t)^-a
    #                              (long documents are rare, like real
    #                              bag-of-words corpora)

    def __post_init__(self):
        if not 0 < self.sig_features <= self.nnz:
            raise ValueError("need 0 < sig_features <= nnz")
        if self.length_zipf_a < 0:
            raise ValueError("length_zipf_a must be >= 0")


class SparseExtremeDataset:
    """Each class owns ``sig_features`` random signature feature ids
    (value 1); each sample carries them plus up to ``nnz -
    sig_features`` Zipf-popular background features (value ~
    noise·U[0,1]), L2 normalized.  With ``length_zipf_a > 0`` the
    background count per row is Zipf-distributed (ragged CSR — real
    bag-of-words doc lengths); otherwise every row has exactly ``nnz``
    entries.  Linear in the signature indicators, so MACH logistic
    regression is the right model class — and the CSR batch densifies
    to exactly the dense fallback, so the fused-CSR and materializing
    paths train on identical data."""

    def __init__(self, cfg: SparseExtremeDataConfig):
        self.cfg = cfg
        ks = jax.random.key(cfg.seed)
        self.signatures = jax.random.randint(
            ks, (cfg.num_classes, cfg.sig_features), 0, cfg.num_features)
        if cfg.zipf_a > 0:
            ranks = np.arange(1, cfg.num_classes + 1, dtype=np.float64)
            w = ranks ** (-cfg.zipf_a)
            self.class_probs = jnp.asarray(w / w.sum(), jnp.float32)
        else:
            self.class_probs = None
        ranks = np.arange(1, cfg.num_features + 1, dtype=np.float64)
        w = ranks ** (-max(cfg.feature_zipf_a, 0.0))
        self.feature_probs = jnp.asarray(w / w.sum(), jnp.float32)

    def batch_at(self, step: int, batch_size: int, split: str = "train",
                 format: str = "csr"):
        """Returns (SparseBatch, y (B,)) — or the exact densification
        (x (B, d), y) with ``format="dense"`` (the materializing-path
        fallback).  Splits use disjoint key spaces; pure in (seed, step).
        """
        cfg = self.cfg
        base = jax.random.fold_in(jax.random.key(cfg.seed + 2),
                                  {"train": 0, "test": 1}[split])
        key = jax.random.fold_in(base, step)
        ky, kn, kv = jax.random.split(key, 3)
        if self.class_probs is not None:
            y = jax.random.choice(ky, cfg.num_classes, (batch_size,),
                                  p=self.class_probs)
        else:
            y = jax.random.randint(ky, (batch_size,), 0, cfg.num_classes)
        n_bg = cfg.nnz - cfg.sig_features
        sig_ids = self.signatures[y]                     # (B, sig)
        sig_vals = jnp.ones((batch_size, cfg.sig_features), jnp.float32)
        if n_bg:
            bg_ids = jax.random.choice(kn, cfg.num_features,
                                       (batch_size, n_bg),
                                       p=self.feature_probs)
            bg_vals = cfg.noise * jax.random.uniform(kv, (batch_size, n_bg))
            ids = jnp.concatenate([sig_ids, bg_ids], axis=1)
            vals = jnp.concatenate([sig_vals, bg_vals], axis=1)
        else:
            ids, vals = sig_ids, sig_vals
        if cfg.length_zipf_a > 0:
            # ragged Zipf doc lengths: every row keeps its signature
            # ids; a Zipf-distributed count of background features
            # rides along (long documents are rare), so real ragged
            # rows flow through the fused CSR path — not only
            # fixed-nnz or handmade fixtures.  Row lengths and CSR
            # assembly stay pure in (seed, step).
            kl = jax.random.fold_in(key, 3)
            t = jnp.arange(n_bg + 1, dtype=jnp.float32)
            extra = jax.random.categorical(
                kl, jnp.broadcast_to(-cfg.length_zipf_a * jnp.log1p(t),
                                     (batch_size, n_bg + 1)))
            keep = cfg.sig_features + extra                   # (B,)
            mask = jnp.arange(cfg.nnz)[None, :] < keep[:, None]
            vals = jnp.where(mask, vals, 0.0)
            vals = vals / jnp.linalg.norm(vals, axis=1, keepdims=True)
            mask_np = np.asarray(mask)                # row-major gather
            batch = SparseBatch(
                indptr=jnp.asarray(np.concatenate(
                    [[0], np.cumsum(np.asarray(keep))]), jnp.int32),
                indices=jnp.asarray(np.asarray(ids)[mask_np], jnp.int32),
                values=jnp.asarray(np.asarray(vals)[mask_np]),
                num_features=cfg.num_features,
                nnz_max=cfg.nnz)
        else:
            vals = vals / jnp.linalg.norm(vals, axis=1, keepdims=True)
            batch = SparseBatch(
                indptr=(jnp.arange(batch_size + 1, dtype=jnp.int32)
                        * cfg.nnz),
                indices=ids.reshape(-1).astype(jnp.int32),
                values=vals.reshape(-1),
                num_features=cfg.num_features,
                nnz_max=cfg.nnz)
        if format == "dense":
            return batch.to_dense(), y.astype(jnp.int32)
        if format != "csr":
            raise ValueError(f"format must be csr|dense, got {format!r}")
        return batch, y.astype(jnp.int32)
