"""Deterministic synthetic LM data pipeline.

Restart-safe by construction: batch at step s is a pure function of
(seed, step) — a restarted job resumes at step s and sees *exactly* the
remaining stream, never replaying or skipping data.  This is the
fault-tolerance property real pipelines get from checkpointing iterator
state; we get it for free from counter-based PRNG.

Token statistics follow a Zipf distribution with a planted bigram
structure so the LM loss actually *decreases* during example training
(pure uniform noise has no learnable signal).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    # planted structure: token t is followed by (t*mult + off) % V w.p. p
    bigram_p: float = 0.5
    # modality stubs
    enc_feats_dim: int = 0          # >0 -> emit enc_feats (audio enc-dec)
    enc_len: int = 0
    prefix_feats_dim: int = 0       # >0 -> emit prefix_feats (vision)
    prefix_len: int = 0


class SyntheticLMStream:
    """Stateless stream: ``batch_at(step)`` for any step, plus iterator
    sugar.  Per-host sharding: pass (host_index, host_count) to carve a
    disjoint slice of the global batch."""

    def __init__(self, cfg: LMDataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        # zipf weights (host-side, once)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(w / w.sum(), jnp.float32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed), step),
            self.host_index)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        b, l = self.local_batch, cfg.seq_len
        base = jax.random.choice(k1, cfg.vocab_size, (b, l + 1),
                                 p=self._probs)
        # plant bigram structure: with prob p, token[i+1] = f(token[i])
        follow = (base[:, :-1] * 31 + 7) % cfg.vocab_size
        use = jax.random.bernoulli(k2, cfg.bigram_p, follow.shape)
        tokens = jnp.concatenate(
            [base[:, :1], jnp.where(use, follow, base[:, 1:])], axis=1)
        batch = {"tokens": tokens.astype(jnp.int32)}
        if cfg.enc_feats_dim:
            batch["enc_feats"] = jax.random.normal(
                k3, (b, cfg.enc_len, cfg.enc_feats_dim), jnp.float32)
        if cfg.prefix_feats_dim:
            batch["prefix_feats"] = jax.random.normal(
                k4, (b, cfg.prefix_len, cfg.prefix_feats_dim), jnp.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
