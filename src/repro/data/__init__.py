from repro.data.lm import LMDataConfig, SyntheticLMStream
from repro.data.extreme import ExtremeDataConfig, ExtremeDataset

__all__ = ["LMDataConfig", "SyntheticLMStream",
           "ExtremeDataConfig", "ExtremeDataset"]
