from repro.data.lm import LMDataConfig, SyntheticLMStream
from repro.data.extreme import (
    ExtremeDataConfig,
    ExtremeDataset,
    SparseBatch,
    SparseExtremeDataConfig,
    SparseExtremeDataset,
)

__all__ = ["LMDataConfig", "SyntheticLMStream",
           "ExtremeDataConfig", "ExtremeDataset",
           "SparseBatch", "SparseExtremeDataConfig", "SparseExtremeDataset"]
