"""Fault tolerance: checkpoint-restart, stragglers, elastic resharding.

At 1000+ nodes the mean time between node failures drops below the job
length; the framework assumes *every* run will be interrupted:

* ``run_with_restarts`` — supervisor loop: restore-latest → train →
  on failure, re-enter.  Combined with the deterministic step-indexed
  data stream (data/lm.py) a restart is *semantically invisible*: the
  resumed run consumes exactly the batches the failed run would have.
* ``StragglerMonitor`` — per-step wall-time EWMA + z-score; steps slower
  than ``threshold_sigma`` are flagged.  On a real cluster the flag
  feeds the scheduler (hot-spare swap / re-slice); here it is surfaced
  in metrics and tested with an injected delay.
* ``reshard_state`` — elastic restart path: checkpoints are
  topology-free (gathered arrays), so a job that lost a pod restores
  onto the surviving mesh by re-sharding every leaf (device_put with the
  new NamedSharding).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.train.train_state import TrainState


class StragglerMonitor:
    """EWMA-based step-time anomaly detector."""

    def __init__(self, alpha: float = 0.1, threshold_sigma: float = 3.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold_sigma
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.count = 0
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.count > self.warmup:
            sigma = math.sqrt(self.var) if self.var > 0 else self.mean * 0.1
            if dt > self.mean + self.threshold * max(sigma, 1e-9):
                is_straggler = True
                self.flagged.append((step, dt, self.mean))
        # EWMA update (skip updating stats with outliers so one straggler
        # doesn't mask the next)
        if not is_straggler:
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


def reshard_state(state: Any, shardings: Any) -> Any:
    """Elastic restart: move every leaf to the new mesh's sharding.
    ``shardings`` is a pytree matching state (or a single sharding)."""
    if isinstance(shardings, jax.sharding.Sharding):
        return jax.tree.map(lambda x: jax.device_put(x, shardings), state)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)


def run_with_restarts(train_once: Callable[[TrainState, int], TrainState],
                      init_state_fn: Callable[[], TrainState],
                      manager: CheckpointManager,
                      total_steps: int,
                      max_restarts: int = 10,
                      log=print) -> TrainState:
    """Supervisor: restore latest (or init), run, restart on exception.

    ``train_once(state, remaining_steps)`` must checkpoint through
    ``manager`` as it goes; on any exception the supervisor restores the
    last durable step and re-enters, so progress is monotone.
    """
    restarts = 0
    while True:
        template = init_state_fn()
        step = manager.latest_step()
        if step is not None:
            state, step = manager.restore(template, step)
            if log:
                log(f"[ft] restored checkpoint at step {step}")
        else:
            state, step = template, 0
        remaining = total_steps - int(state.step)
        if remaining <= 0:
            return state
        try:
            state = train_once(state, remaining)
            if int(state.step) >= total_steps:
                return state
        except Exception as e:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                raise
            if log:
                log(f"[ft] failure at ~step {manager.latest_step()}: "
                    f"{type(e).__name__}: {e} — restarting "
                    f"({restarts}/{max_restarts})")
