"""TrainState pytree."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jnp.ndarray     # () int32
    params: Any
    opt_state: Any


def new_train_state(params, opt) -> TrainState:
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params))
