"""Training loop: jit/pjit train step, microbatching, clipping, metrics.

The train step is a single jit-compiled function (state, batch) ->
(state, metrics).  Under a mesh, state and batch shardings come from
``sharding/partitioning.py`` and the same code runs SPMD — there is no
separate "distributed trainer".  MACH drops in through the model's loss
(the R-head hashed cross-entropy); nothing in the loop is MACH-specific,
which is exactly the paper's point that the R meta-classifiers are
plain classifiers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import (accumulate_grads, apply_updates,
                         clip_by_global_norm, make_optimizer, make_schedule)
from repro.train.train_state import TrainState, new_train_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "warmup_cosine"
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    num_microbatches: int = 1
    master_weights: bool = False     # f32 masters for bf16 params
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10


def make_optimizer_from_config(tcfg: TrainConfig):
    if tcfg.schedule == "warmup_cosine":
        sched = make_schedule("warmup_cosine", peak=tcfg.peak_lr,
                              warmup_steps=tcfg.warmup_steps,
                              total_steps=tcfg.total_steps)
    elif tcfg.schedule == "constant":
        sched = make_schedule("constant", value=tcfg.peak_lr)
    else:
        sched = make_schedule(tcfg.schedule, peak=tcfg.peak_lr,
                              warmup_steps=tcfg.warmup_steps)
    kw = {}
    if tcfg.optimizer in ("adamw",):
        kw["weight_decay"] = tcfg.weight_decay
    return make_optimizer(tcfg.optimizer, sched,
                          master_weights=tcfg.master_weights, **kw), sched


def make_train_step(loss_fn: Callable[[Any, dict], tuple],
                    tcfg: TrainConfig):
    """loss_fn(params, batch) -> (loss, metrics).  Returns the pure
    (state, batch) -> (state, metrics) step (jit it with shardings)."""
    opt, sched = make_optimizer_from_config(tcfg)

    def step_fn(state: TrainState, batch: dict):
        (loss, metrics), grads = accumulate_grads(
            loss_fn, state.params, batch, tcfg.num_microbatches)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = sched(state.step)
        return TrainState(state.step + 1, params, opt_state), metrics

    return step_fn, opt


class Trainer:
    """Single-host convenience driver (examples, tests).  The pod-scale
    path is launch/train.py which jits the same step under a mesh."""

    def __init__(self, model, tcfg: TrainConfig,
                 loss_fn: Optional[Callable] = None,
                 bucket_proxy_fn: Optional[Callable] = None):
        self.model = model
        self.tcfg = tcfg
        self.loss_fn = loss_fn or model.loss
        self.step_fn, self.opt = make_train_step(self.loss_fn, tcfg)
        self._jit_step = jax.jit(self.step_fn, donate_argnums=(0,))
        # Dynamic bucket selection: ``bucket_proxy_fn(params, batch)``
        # -> (R, B) proxy scores, recomputed every ``refresh_every``
        # steps (cfg.mach_bucket_select = (c_sel, refresh_every)) and
        # injected as batch["bucket_proxy"].  Without it the model's
        # loss recomputes the proxy in-graph each step — same math,
        # no cross-step caching.
        self.bucket_proxy_fn = bucket_proxy_fn
        sel = getattr(getattr(model, "cfg", None),
                      "mach_bucket_select", None)
        self._proxy_every = sel[1] if sel is not None and len(sel) > 1 else 1
        self._proxy = None

    def _with_bucket_proxy(self, state: TrainState, batch, step: int):
        """Refresh the cached bucket-proxy scores on schedule and hand
        them to the loss.  Selection itself is recomputed in-graph with
        the current batch's label buckets force-included, so a stale
        proxy only affects which *negative* buckets the loss sees."""
        if self.bucket_proxy_fn is None or not isinstance(batch, dict):
            return batch
        if self._proxy is None or step % max(self._proxy_every, 1) == 0:
            self._proxy = self.bucket_proxy_fn(state.params, batch)
        return {**batch, "bucket_proxy": self._proxy}

    def init_state(self, key) -> TrainState:
        params, _ = self.model.init(key)
        return new_train_state(params, self.opt)

    def fit(self, state: TrainState, stream, num_steps: int,
            manager=None, monitor=None, log=print) -> TrainState:
        start = int(state.step)
        for s in range(start, start + num_steps):
            t0 = time.perf_counter()
            batch = self._with_bucket_proxy(state, stream.batch_at(s), s)
            state, metrics = self._jit_step(state, batch)
            if monitor is not None:
                jax.block_until_ready(state.params)
                monitor.record(s, time.perf_counter() - t0)
            if manager is not None and (s + 1) % self.tcfg.checkpoint_every == 0:
                manager.save(s + 1, state, blocking=False)
            if (s + 1) % self.tcfg.log_every == 0 and log:
                log(f"step {s+1}: loss={float(metrics['loss']):.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} "
                    f"lr={float(metrics['lr']):.2e}")
        if manager is not None:
            manager.save(start + num_steps, state, blocking=True)
        return state
