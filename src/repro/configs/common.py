"""Config helpers shared by the per-architecture files."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.mach import MACHConfig
from repro.models.transformer import ModelConfig

# The four assigned LM shapes: (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def default_mach_head(vocab_size: int, enable: str = "auto",
                      num_buckets: int = 2048, num_repetitions: int = 8
                      ) -> Optional[MACHConfig]:
    """Framework policy: MACH replaces the softmax head where the vocab
    is extreme (>=100k) — seamless, qwen2-moe, paligemma, recurrentgemma.
    'on'/'off' force it either way (every arch supports both)."""
    if enable == "off":
        return None
    if enable == "auto" and vocab_size < 100_000:
        return None
    return MACHConfig(num_classes=vocab_size, num_buckets=num_buckets,
                      num_repetitions=num_repetitions, seed=0,
                      estimator="unbiased", hash_kind="mult_shift")


def supports_long_context(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (SSM/hybrid/SWA)."""
    if cfg.family in ("hybrid", "xlstm"):
        return True
    if cfg.attention_kind == "sliding_window":
        return True
    return False


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Returns (applicable, reason-if-not)."""
    if shape == "long_500k" and not supports_long_context(cfg):
        return False, ("pure full-attention arch: 524288-token dense KV "
                       "cache is the quadratic regime this shape excludes "
                       "(DESIGN.md §5)")
    return True, ""
