"""xlstm-350m [ssm] — 24L, d_model=1024, 4H, d_ff=0 (blocks carry their
own projections), vocab=50304.  Alternating mLSTM/sLSTM blocks.
[arXiv:2405.04517]  Attention-free, O(1) decode state -> long_500k runs.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "xlstm-350m"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="xlstm",
        num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("mlstm", "slstm"),
        activation="geglu", norm="layernorm",
        mach=default_mach_head(50304, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="xlstm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256,
        block_pattern=("mlstm", "slstm"),
        activation="geglu", norm="layernorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
