"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone.

24L(enc)+24L(dec), d_model=1024, 16H (MHA, kv=16), d_ff=8192,
vocab=256206.  [arXiv:2308.11596; hf]  Audio frontend stubbed:
input_specs provide precomputed w2v-BERT frame embeddings.
Extreme vocab (256k) -> MACH head on by default.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "seamless-m4t-large-v2"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="enc_dec",
        num_layers=24, num_encoder_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=256206,
        activation="gelu", norm="layernorm",
        frontend="audio",
        mach=default_mach_head(256206, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="enc_dec",
        num_layers=2, num_encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        activation="gelu", norm="layernorm",
        frontend="audio",
        mach=default_mach_head(256, "on", num_buckets=16, num_repetitions=4),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
