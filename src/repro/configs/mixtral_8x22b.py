"""mixtral-8x22b [moe] — 56L, d_model=6144, 48H (GQA kv=8),
d_ff=16384 per expert, vocab=32768, 8 experts top-2, SWA.
[arXiv:2401.04088; hf]  SWA (window 4096) qualifies it for long_500k.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "mixtral-8x22b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        block_pattern=("moe",),
        num_experts=8, experts_top_k=2, moe_d_ff=16384,
        moe_group_size=512,   # §Perf cell 2: dispatch one-hots are quadratic in group size
        attention_kind="sliding_window", window=4096,
        activation="swiglu", norm="rmsnorm", rope_theta=1e6,
        mach=default_mach_head(32768, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        block_pattern=("moe",),
        num_experts=4, experts_top_k=2, moe_d_ff=96, moe_group_size=16,
        attention_kind="sliding_window", window=8,
        activation="swiglu", norm="rmsnorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
