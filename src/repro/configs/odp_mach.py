"""The paper's own task configs: ODP and fine-grained ImageNet.

These are MACHLinear (logistic regression) setups, not LMs — Table 1/2
of the paper.  The offline stand-in datasets are synthetic with a known
Bayes optimum (data/extreme.py); the full-scale dimensions are kept here
for the record and for the model-size arithmetic in benchmarks.
"""

import dataclasses

from repro.core.mach import MACHConfig


@dataclasses.dataclass(frozen=True)
class ExtremeTaskConfig:
    name: str
    num_classes: int
    dim: int
    mach_b: int
    mach_r: int
    # reduced CPU-scale stand-in (same B; K, d, R scaled down)
    small_classes: int
    small_dim: int
    small_r: int

    def mach(self, small: bool = False) -> MACHConfig:
        return MACHConfig(
            num_classes=self.small_classes if small else self.num_classes,
            num_buckets=self.mach_b,
            num_repetitions=self.small_r if small else self.mach_r,
            hash_kind="mult_shift" if (self.mach_b & (self.mach_b - 1)) == 0
            else "carter_wegman")


# Paper Table 2 run: ODP (B=32, R=25) — 125x model-size reduction
ODP = ExtremeTaskConfig(
    name="odp", num_classes=105033, dim=422713,
    mach_b=32, mach_r=25,
    small_classes=1024, small_dim=256, small_r=12,
)

# Paper Table 2 run: ImageNet-21k (B=512, R=20) — 2x reduction
IMAGENET = ExtremeTaskConfig(
    name="imagenet21k", num_classes=21841, dim=6144,
    mach_b=512, mach_r=20,
    small_classes=1024, small_dim=256, small_r=6,
)
