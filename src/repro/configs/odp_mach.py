"""The paper's own task configs: ODP and fine-grained ImageNet.

These are MACHLinear (logistic regression) setups, not LMs — Table 1/2
of the paper.  The offline stand-in datasets are synthetic with a known
Bayes optimum (data/extreme.py); the full-scale dimensions are kept here
for the record and for the model-size arithmetic in benchmarks.
"""

import dataclasses

from repro.core.mach import MACHConfig


@dataclasses.dataclass(frozen=True)
class ExtremeTaskConfig:
    name: str
    num_classes: int
    dim: int
    mach_b: int
    mach_r: int
    # reduced CPU-scale stand-in (same B; K, d, R scaled down)
    small_classes: int
    small_dim: int
    small_r: int
    # sparse-feature (bag-of-words) tasks: nonzeros per example.  ODP's
    # d=422k features are CSR-sparse — the regime the fused-CSR training
    # path exists for; 0 means the task is dense (ImageNet embeddings).
    nnz: int = 0
    small_nnz: int = 0

    @property
    def sparse_features(self) -> bool:
        return self.nnz > 0

    def mach(self, small: bool = False) -> MACHConfig:
        return MACHConfig(
            num_classes=self.small_classes if small else self.num_classes,
            num_buckets=self.mach_b,
            num_repetitions=self.small_r if small else self.mach_r,
            hash_kind="mult_shift" if (self.mach_b & (self.mach_b - 1)) == 0
            else "carter_wegman")

    def sparse_data(self, small: bool = True, noise: float = 0.3,
                    seed: int = 0) -> "SparseExtremeDataConfig":
        """Config for the Zipf-sparse CSR generator (data/extreme.py)
        matching this task's (K, d, nnz) at the chosen scale."""
        from repro.data.extreme import SparseExtremeDataConfig
        if not self.sparse_features:
            raise ValueError(f"{self.name} is a dense-feature task")
        nnz = self.small_nnz if small else self.nnz
        return SparseExtremeDataConfig(
            num_classes=self.small_classes if small else self.num_classes,
            num_features=self.small_dim if small else self.dim,
            nnz=nnz, sig_features=max(1, nnz // 2), noise=noise,
            seed=seed)


# Paper Table 2 run: ODP (B=32, R=25) — 125x model-size reduction.
# Features are bag-of-words CSR (the paper trains d=422k on one GPU
# precisely because only ~100 features/doc are active).  The bias is a
# native kernel operand, so the padded ELL width is exactly nnz_max —
# any value up to a lane multiple (128) costs the same densify tile.
ODP = ExtremeTaskConfig(
    name="odp", num_classes=105033, dim=422713,
    mach_b=32, mach_r=25,
    small_classes=1024, small_dim=256, small_r=12,
    nnz=120, small_nnz=32,
)

# Paper Table 2 run: ImageNet-21k (B=512, R=20) — 2x reduction
IMAGENET = ExtremeTaskConfig(
    name="imagenet21k", num_classes=21841, dim=6144,
    mach_b=512, mach_r=20,
    small_classes=1024, small_dim=256, small_r=6,
)
