"""tinyllama-1.1b [dense] — 22L, d_model=2048, 32H (GQA kv=4),
d_ff=5632, vocab=32000.  llama2-arch small.  [arXiv:2401.02385; hf]
Also the ~100M-scale end-to-end training example's parent arch.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "tinyllama-1.1b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4,
        d_ff=5632, vocab_size=32000,
        activation="swiglu", norm="rmsnorm",
        mach=default_mach_head(32000, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=176, vocab_size=256,
        activation="swiglu", norm="rmsnorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
