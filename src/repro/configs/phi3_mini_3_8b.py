"""phi3-mini-3.8b [dense] — 32L, d_model=3072, 32H (MHA kv=32),
d_ff=8192, vocab=32064.  RoPE SwiGLU.  [arXiv:2404.14219]
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "phi3-mini-3.8b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        activation="swiglu", norm="rmsnorm",
        mach=default_mach_head(32064, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        activation="swiglu", norm="rmsnorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
