"""Architecture registry: --arch <id> resolution."""

from __future__ import annotations

from repro.configs import (granite_20b, mistral_large_123b, mixtral_8x22b,
                           paligemma_3b, phi3_mini_3_8b, qwen2_moe_a2_7b,
                           recurrentgemma_2b, seamless_m4t_large_v2,
                           tinyllama_1_1b, xlstm_350m)
from repro.configs.common import SHAPES, shape_applicable, supports_long_context

_MODULES = {
    m.ARCH_ID: m
    for m in (seamless_m4t_large_v2, mistral_large_123b, granite_20b,
              tinyllama_1_1b, phi3_mini_3_8b, mixtral_8x22b,
              qwen2_moe_a2_7b, paligemma_3b, recurrentgemma_2b, xlstm_350m)
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, *, smoke: bool = False, mach: str = "auto"):
    try:
        mod = _MODULES[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return mod.smoke_config() if smoke else mod.full_config(mach=mach)


__all__ = ["ARCH_IDS", "get_config", "SHAPES", "shape_applicable",
           "supports_long_context"]
