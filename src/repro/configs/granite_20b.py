"""granite-20b [dense] — 52L, d_model=6144, 48H (MQA kv=1), d_ff=24576,
vocab=49152.  Code model.  [arXiv:2405.04324; hf]

d_ff = 4·d_model with a *non-gated* MLP (GPT-BigCode lineage) — a gated
SwiGLU at this width would be a 28B model, not 20B.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "granite-20b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        activation="gelu", norm="layernorm",
        mach=default_mach_head(49152, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=192, vocab_size=256,
        activation="gelu", norm="layernorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
