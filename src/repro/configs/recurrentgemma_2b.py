"""recurrentgemma-2b [hybrid] — 26L, d_model=2560, 10H (MQA kv=1),
d_ff=7680, vocab=256000.  RG-LRU + local attention, pattern
(recurrent, recurrent, attention) cycled — Griffin.  [arXiv:2402.19427]
O(1)-in-seq decode state -> runs the long_500k cell.
Extreme vocab (256k) -> MACH head on by default.
"""

import math

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "recurrentgemma-2b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        d_ff=7680, vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn_local"),
        local_window=2048, rnn_width=2560,
        activation="geglu", norm="rmsnorm",
        tie_embeddings=True, embed_scale=math.sqrt(2560.0),
        mach=default_mach_head(256000, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=2, num_kv_heads=1,
        d_ff=128, vocab_size=256,
        block_pattern=("rglru", "rglru", "attn_local"),
        local_window=8, rnn_width=64,
        activation="geglu", norm="rmsnorm",
        tie_embeddings=True, embed_scale=8.0,
        mach=default_mach_head(256, "on", num_buckets=16, num_repetitions=4),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
