"""paligemma-3b [vlm] — 18L, d_model=2048, 8H (MQA kv=1), d_ff=16384,
vocab=257216.  SigLIP frontend stubbed (256 patch embeddings via
input_specs) + gemma decoder.  [arXiv:2407.07726; hf]
Largest head of the pool: 527M params -> 33.5M with MACH (B=2048, R=8).
"""

import math

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "paligemma-3b"
NUM_PATCHES = 256


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        d_ff=16384, vocab_size=257216,
        activation="geglu", norm="rmsnorm",
        frontend="vision", num_prefix_tokens=NUM_PATCHES,
        tie_embeddings=True, embed_scale=math.sqrt(2048.0),
        mach=default_mach_head(257216, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=192, vocab_size=512,
        activation="geglu", norm="rmsnorm",
        frontend="vision", num_prefix_tokens=4,
        tie_embeddings=True, embed_scale=8.0,
        mach=default_mach_head(512, "on", num_buckets=32, num_repetitions=4),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
