"""mistral-large-123b [dense] — 88L, d_model=12288, 96H (GQA kv=8),
d_ff=28672, vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407]
Small vocab -> OAA head by default (MACH supported via flag); at 123 B
params the trunk, not the head, is the memory story — FSDP + TP carry it.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "mistral-large-123b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=28672, vocab_size=32768,
        activation="swiglu", norm="rmsnorm", rope_theta=1e6,
        mach=default_mach_head(32768, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=192, vocab_size=256,
        activation="swiglu", norm="rmsnorm",
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
