"""qwen2-moe-a2.7b [moe] — 24L, d_model=2048, 16H (MHA kv=16),
moe_d_ff=1408, vocab=151936, 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
Extreme vocab (152k) -> MACH head on by default; the 311M-parameter
unembedding dwarfs each MoE layer — the paper's prime LM target.
"""

import jax.numpy as jnp

from repro.configs.common import default_mach_head
from repro.models.transformer import ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"


def full_config(mach: str = "auto") -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936,
        block_pattern=("moe",),
        num_experts=60, experts_top_k=4, moe_d_ff=1408,
        num_shared_experts=4, shared_d_ff=5632,
        moe_group_size=512,
        activation="swiglu", norm="rmsnorm",
        mach=default_mach_head(151936, mach),
        dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=48, vocab_size=512,
        block_pattern=("moe",),
        num_experts=6, experts_top_k=2, moe_d_ff=48,
        num_shared_experts=2, shared_d_ff=96, moe_group_size=16,
        activation="swiglu", norm="rmsnorm",
        mach=default_mach_head(512, "on", num_buckets=32, num_repetitions=4),
        dtype=jnp.float32, scan_layers=False, remat="none",
    )
