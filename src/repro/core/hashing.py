"""2-universal hashing for MACH (paper §2.1).

Two constructions are provided:

1. ``CarterWegmanFamily`` — h(x) = ((a·x + b) mod p) mod B with p the
   Mersenne prime 2^61 − 1.  Exactly 2-universal [Carter & Wegman 1977].
   Tables are materialized host-side with Python/numpy 64-bit integer
   arithmetic (exact for K < 2^31) and shipped to device as an (R, K)
   int32 array; on-device label hashing is a table gather (exact by
   construction).  Works for arbitrary B.

2. ``MultShiftFamily`` — the paper's "fastest way": sample a random odd
   a ∈ [2^32], h(x) = (a·x mod 2^32) >> (32 − log2 B).  Requires B to be
   a power of two; cheap enough to evaluate *inside* a Pallas kernel
   (one uint32 multiply + shift), which removes the hash-table load from
   the decode kernel's HBM traffic entirely.

Both expose the same interface:
  ``.table(K)``        → (R, K) int32 bucket ids
  ``.hash_labels(y)``  → (R, *y.shape) bucket ids for a batch of labels

Theory helpers implement Theorem 2 / Eq. 6 of the paper.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

MERSENNE_P = (1 << 61) - 1  # prime > any realistic K


def r_required(num_classes: int, num_buckets: int, delta: float = 1e-3) -> int:
    """Theorem 2: smallest R s.t. all class pairs are distinguishable
    with probability >= 1 - delta:  R = 2 log(K / sqrt(delta)) / log B.
    """
    if num_buckets < 2:
        raise ValueError("need B >= 2")
    r = 2.0 * math.log(num_classes / math.sqrt(delta)) / math.log(num_buckets)
    return max(1, int(math.ceil(r)))


def indistinguishable_pair_bound(num_classes: int, num_buckets: int,
                                 num_repetitions: int) -> float:
    """Union bound (Eq. 6): P(∃ indistinguishable pair) <= K^2 · B^-R."""
    log_p = 2.0 * math.log(num_classes) - num_repetitions * math.log(num_buckets)
    return min(1.0, math.exp(log_p))


def memory_reduction(num_classes: int, num_buckets: int,
                     num_repetitions: int) -> float:
    """Model-size ratio O(Kd) / O(BRd) — the paper's headline number
    (e.g. ODP B=32, R=25 → 105033/(32·25) ≈ 131x)."""
    return num_classes / float(num_buckets * num_repetitions)


@dataclasses.dataclass(frozen=True)
class CarterWegmanFamily:
    """R independent exactly-2-universal hash functions [K] -> [B]."""

    num_buckets: int
    num_repetitions: int
    seed: int = 0

    @property
    def inline_kernel_ok(self) -> bool:
        return False  # needs 61-bit arithmetic; use the table in kernels

    def coeffs(self) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 0xC33]))
        a = rng.integers(1, MERSENNE_P, size=self.num_repetitions, dtype=np.uint64)
        b = rng.integers(0, MERSENNE_P, size=self.num_repetitions, dtype=np.uint64)
        return a, b

    def table_np(self, num_classes: int) -> np.ndarray:
        a, b = self.coeffs()
        k = np.arange(num_classes, dtype=np.uint64)
        rows = []
        for j in range(self.num_repetitions):
            aj, bj = int(a[j]), int(b[j])
            # exact: split a into 30-bit limbs so products fit in uint64
            a_lo, a_hi = aj & ((1 << 30) - 1), aj >> 30
            lo = (a_lo * k) % MERSENNE_P
            hi = (a_hi % MERSENNE_P) * (k % MERSENNE_P) % MERSENNE_P
            hi = (hi * ((1 << 30) % MERSENNE_P)) % MERSENNE_P
            h = (lo + hi + bj) % MERSENNE_P
            rows.append((h % self.num_buckets).astype(np.int32))
        return np.stack(rows, axis=0)

    def table(self, num_classes: int) -> jnp.ndarray:
        return jnp.asarray(self.table_np(num_classes), dtype=jnp.int32)

    def hash_labels(self, labels: jnp.ndarray, num_classes: int) -> jnp.ndarray:
        """(...,) int labels -> (R, ...) bucket ids via exact table gather."""
        tab = self.table(num_classes)  # (R, K)
        return jnp.take(tab, labels, axis=1)


@dataclasses.dataclass(frozen=True)
class MultShiftFamily:
    """Multiply-shift hashing (paper §2.1 'fastest way'); B must be 2^k.

    h_j(x) = (a_j * x mod 2^32) >> (32 - log2 B), a_j random odd uint32.
    Evaluable with one integer multiply + shift — including inside a
    Pallas kernel, so the decode kernel never touches a hash table.
    """

    num_buckets: int
    num_repetitions: int
    seed: int = 0

    def __post_init__(self):
        if self.num_buckets & (self.num_buckets - 1):
            raise ValueError("MultShiftFamily requires power-of-two B")
        if self.num_buckets < 2:
            raise ValueError("need B >= 2")

    @property
    def inline_kernel_ok(self) -> bool:
        return True

    @property
    def shift(self) -> int:
        return 32 - int(math.log2(self.num_buckets))

    def coeffs(self) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, 0x5F7]))
        a = rng.integers(0, 1 << 31, size=self.num_repetitions,
                         dtype=np.uint32).astype(np.uint32) * np.uint32(2) + np.uint32(1)
        return a

    def table_np(self, num_classes: int) -> np.ndarray:
        a = self.coeffs().astype(np.uint64)
        k = np.arange(num_classes, dtype=np.uint64)
        prod = (a[:, None] * k[None, :]) & np.uint64(0xFFFFFFFF)
        return (prod >> np.uint64(self.shift)).astype(np.int32)

    def table(self, num_classes: int) -> jnp.ndarray:
        return jnp.asarray(self.table_np(num_classes), dtype=jnp.int32)

    def hash_labels(self, labels: jnp.ndarray, num_classes: int = 0) -> jnp.ndarray:
        """On-the-fly device hashing: (...,) -> (R, ...)."""
        a = jnp.asarray(self.coeffs())  # uint32
        y = labels.astype(jnp.uint32)
        prod = a.reshape((-1,) + (1,) * y.ndim) * y[None]  # wraps mod 2^32
        return jax.lax.shift_right_logical(
            prod, jnp.uint32(self.shift)).astype(jnp.int32)


# late import to keep module import cheap and avoid cycle
import jax  # noqa: E402


def inverted_table_np(table: np.ndarray, num_buckets: int,
                      pad_to: int = 128) -> np.ndarray:
    """Invert an (R, K) bucket table into (R·B, L) class lists.

    Row ``j*B + b`` lists, in ascending class id, every class c with
    ``table[j, c] == b``, padded with the sentinel ``K`` to L = the max
    bucket occupancy rounded up to ``pad_to`` (lane alignment for the
    candidate-decode kernels).  Built once per model host-side; the
    candidate filter gathers rows of this table instead of streaming K.
    """
    table = np.asarray(table)
    if table.ndim != 2:
        raise ValueError(f"table must be (R, K), got {table.shape}")
    r, k = table.shape
    b = num_buckets
    if table.size and (table.min() < 0 or table.max() >= b):
        raise ValueError("table entries out of range for num_buckets")
    counts = np.zeros((r, b), dtype=np.int64)
    for j in range(r):
        counts[j] = np.bincount(table[j], minlength=b)
    occ = int(counts.max()) if counts.size else 0
    ell = max(pad_to, -(-occ // pad_to) * pad_to)
    inv = np.full((r * b, ell), k, dtype=np.int32)
    cls = np.arange(k, dtype=np.int64)
    for j in range(r):
        # stable sort by bucket keeps each bucket's classes ascending
        order = np.argsort(table[j], kind="stable")
        starts = np.searchsorted(table[j][order], np.arange(b))
        pos = cls - starts[table[j][order]]  # slot within its bucket
        inv[j * b + table[j][order], pos] = order
    return inv


def inverted_table(table, num_buckets: int, pad_to: int = 128) -> jnp.ndarray:
    """Device-side (R·B, L) int32 inverted table (see inverted_table_np)."""
    return jnp.asarray(
        inverted_table_np(np.asarray(table), num_buckets, pad_to),
        dtype=jnp.int32)


# the known hash-family kinds — ``MACHConfig`` validates against this
# at construction so a typo fails fast, not later in make_hash_family
HASH_KINDS = ("auto", "carter_wegman", "mult_shift")


def make_hash_family(num_buckets: int, num_repetitions: int, seed: int = 0,
                     kind: str = "auto"):
    """kind: 'auto' (mult_shift when B=2^k else carter_wegman) |
    'carter_wegman' | 'mult_shift'."""
    if kind not in HASH_KINDS:
        raise ValueError(f"unknown hash family kind: {kind!r} "
                         f"(known: {HASH_KINDS})")
    if kind == "auto":
        kind = ("mult_shift"
                if num_buckets & (num_buckets - 1) == 0 else "carter_wegman")
    if kind == "mult_shift":
        return MultShiftFamily(num_buckets, num_repetitions, seed)
    return CarterWegmanFamily(num_buckets, num_repetitions, seed)
