"""One-vs-all (OAA) baseline — the paper's primary comparison point.

A plain K-way softmax (logistic) classifier with O(Kd) parameters and
O(Kd) inference multiplications.  Implemented so every MACH experiment
can report the paper's accuracy/memory tradeoff against the exact
baseline it compares to (paper §4.2).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


class OAAClassifier:
    """Standard softmax regression: W (d, K), b (K)."""

    def __init__(self, num_classes: int, dim: int):
        self.num_classes = num_classes
        self.dim = dim

    def init(self, key: jax.Array) -> dict:
        scale = 1.0 / math.sqrt(self.dim)
        return {
            "w": jax.random.normal(key, (self.dim, self.num_classes),
                                   jnp.float32) * scale,
            "b": jnp.zeros((self.num_classes,), jnp.float32),
        }

    def logits(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        return x @ params["w"] + params["b"]

    def loss(self, params: dict, x: jnp.ndarray, y: jnp.ndarray,
             weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        lg = self.logits(params, x)
        logp = lg - jax.nn.logsumexp(lg, axis=-1, keepdims=True)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        if weights is not None:
            return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
        return jnp.mean(nll)

    def predict(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.logits(params, x), axis=-1)

    def class_probs(self, params: dict, x: jnp.ndarray) -> jnp.ndarray:
        return jax.nn.softmax(self.logits(params, x), axis=-1)

    def param_count(self) -> int:
        return self.dim * self.num_classes + self.num_classes
