"""MACH — Merged-Averaged Classifiers via Hashing (the paper's algorithm).

Three integration levels, lowest to highest:

* ``mach_loss``        — loss-level: R-head cross-entropy on hashed labels
                         (Algorithm 1's trainLogistic target transform).
* ``MACHLinear``       — the paper-faithful model: R independent B-way
                         *logistic regressions* over raw features (dense or
                         CSR-sparse), trained jointly or per-repetition
                         (embarrassingly parallel).
* ``MACHOutputHead``   — the framework feature: drop-in replacement for an
                         LM's d×V softmax head, producing (…, R, B) logits
                         with O(d·R·B) = O(d log K) parameters.

Both trainable heads implement the shared ``MACHHead`` abstraction, so
``loss`` / ``fused_loss`` / ``predict`` / ``param_count`` are one
surface from the paper's ODP logistic regression to LM output heads —
they cannot drift apart, and the fused logit-free training kernels
(``ops.mach_fused_xent`` / ``ops.mach_fused_xent_csr``) serve both.

Prediction (Algorithm 2) lives in ``estimators.py`` (reference) and
``kernels/mach_decode.py`` (fused TPU path).
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimators as est
from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class MACHConfig:
    """Static configuration of a MACH classifier/head.

    B and R are the paper's two knobs (memory BRd, inference RBd + KR).
    """

    num_classes: int            # K
    num_buckets: int            # B
    num_repetitions: int        # R
    seed: int = 0
    estimator: str = "unbiased"         # unbiased | min | median
    hash_kind: str = "auto"             # auto | carter_wegman | mult_shift

    def __post_init__(self):
        if self.num_buckets < 2:
            raise ValueError("B must be >= 2")
        if self.num_repetitions < 1:
            raise ValueError("R must be >= 1")
        if self.estimator not in est.ESTIMATORS:
            raise ValueError(f"estimator {self.estimator!r} not in {est.ESTIMATORS}")
        if self.hash_kind not in hashing.HASH_KINDS:
            raise ValueError(f"hash_kind {self.hash_kind!r} not in "
                             f"{hashing.HASH_KINDS}")

    @property
    def family(self):
        return hashing.make_hash_family(
            self.num_buckets, self.num_repetitions, self.seed, self.hash_kind)

    def table(self) -> jnp.ndarray:
        return self.family.table(self.num_classes)

    def table_np(self) -> np.ndarray:
        return self.family.table_np(self.num_classes)

    def hash_labels(self, labels: jnp.ndarray) -> jnp.ndarray:
        """(...,) class ids -> (R, ...) bucket ids."""
        return self.family.hash_labels(labels, self.num_classes)

    def inverted_table_np(self, pad_to: int = 128) -> np.ndarray:
        """(R·B, L) bucket -> class lists for candidate-filtered decode."""
        return hashing.inverted_table_np(self.table_np(), self.num_buckets,
                                         pad_to)

    def inverted_table(self, pad_to: int = 128) -> jnp.ndarray:
        return hashing.inverted_table(self.table_np(), self.num_buckets,
                                      pad_to)

    # --- theory (paper §3.1) ---
    def indistinguishable_bound(self) -> float:
        return hashing.indistinguishable_pair_bound(
            self.num_classes, self.num_buckets, self.num_repetitions)

    def memory_reduction(self) -> float:
        return hashing.memory_reduction(
            self.num_classes, self.num_buckets, self.num_repetitions)

    @staticmethod
    def from_delta(num_classes: int, num_buckets: int, delta: float = 1e-3,
                   **kw) -> "MACHConfig":
        """Build a config with R chosen by Theorem 2."""
        r = hashing.r_required(num_classes, num_buckets, delta)
        return MACHConfig(num_classes, num_buckets, r, **kw)


# ---------------------------------------------------------------------------
# Loss (training): R independent B-way cross entropies on hashed labels.
# ---------------------------------------------------------------------------

def mach_loss(logits: jnp.ndarray, hashed_labels: jnp.ndarray,
              weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean (over batch) of the summed R-head cross-entropy.

    logits:        (..., R, B)
    hashed_labels: (R, ...)  bucket ids — note leading R (hash-family layout)
    weights:       (...,) optional 0/1 mask (e.g. padding tokens)

    Each head j is its own B-way classifier on dataset D_j = {x, h_j(y)}
    (Algorithm 1); the joint loss is the sum over heads, which is exactly
    training the R models independently when the trunk is fixed — and
    shares the trunk forward pass when it is not.
    """
    r, b = logits.shape[-2], logits.shape[-1]
    if hashed_labels.shape[0] != r:
        raise ValueError(f"R mismatch: logits {logits.shape}, labels "
                         f"{hashed_labels.shape}")
    # (..., R, B) log-softmax over B per head
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    logp = logits - logz
    # move labels R-axis last to align with logits' (..., R)
    lbl = jnp.moveaxis(hashed_labels, 0, -1)          # (..., R)
    picked = jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]  # (..., R)
    nll = -jnp.sum(picked, axis=-1)                   # (...,) summed over heads
    return _weighted_mean(nll, weights)


def _weighted_mean(nll: jnp.ndarray,
                   weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Mean per-example loss, optionally masked (all-zero weights -> 0)."""
    if weights is not None:
        return jnp.sum(nll * weights) / jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.mean(nll)


def is_sparse_batch(x: Any) -> bool:
    """Duck-typed CSR batch check (``data.extreme.SparseBatch`` or any
    object with indptr/indices/values) — core stays import-free of the
    data layer."""
    return hasattr(x, "indptr") and hasattr(x, "indices") \
        and hasattr(x, "values")


def mach_meta_probs(logits: jnp.ndarray) -> jnp.ndarray:
    """(..., R, B) logits -> (R, ..., B) per-head probabilities P^j."""
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.moveaxis(p, -2, 0)


# ---------------------------------------------------------------------------
# The shared head abstraction: one training/prediction surface from the
# paper's ODP logistic regression to LM output heads.
# ---------------------------------------------------------------------------

class MACHHead(abc.ABC):
    """Abstract base for trainable MACH heads.

    Implementations provide ``init`` / ``head_logits`` / ``fused_loss``
    / ``param_count``; the base derives ``loss`` (materializing R-head
    CE on hashed labels), ``meta_probs``, ``predict`` and
    ``class_probs`` from ``head_logits``, so the two heads share one
    semantic definition of training and Algorithm-2 decoding.

    ``loss`` materializes the (…, R, B) logits; ``fused_loss`` is the
    logit-free counterpart (same value and gradients) routed through
    the fused kernels — implementations pick the dense or CSR-sparse
    entry point from their input type.
    """

    cfg: MACHConfig

    @abc.abstractmethod
    def init(self, key: jax.Array) -> dict:
        ...

    @abc.abstractmethod
    def head_logits(self, params: dict, inputs: Any) -> jnp.ndarray:
        """inputs -> (..., R, B) per-head bucket logits."""

    @abc.abstractmethod
    def fused_loss(self, params: dict, inputs: Any, labels: jnp.ndarray,
                   weights: Optional[jnp.ndarray] = None,
                   bucket_select: Optional[tuple] = None,
                   bucket_proxy: Optional[jnp.ndarray] = None,
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
        """Logit-free counterpart of ``loss`` (fused projection+CE).

        ``bucket_select=(c_sel, refresh_every)`` enables dynamic bucket
        selection: the fused loss runs over the top-``c_sel``
        proxy-scored bucket columns per repetition (label buckets
        force-included — one-sided, bounded bias; see
        ``ops.mach_fused_xent``).  ``bucket_proxy`` passes cached (R, B)
        proxy scores (``train.Trainer`` refreshes them every
        ``refresh_every`` steps via ``bucket_proxy_scores``)."""

    def bucket_proxy_scores(self, params: dict, inputs: Any) -> jnp.ndarray:
        """(R, B) proxy scores for dynamic bucket selection — the
        logits of the batch-mean activation.  Cacheable across steps;
        cheap (one d·R·B matvec)."""
        raise NotImplementedError

    @abc.abstractmethod
    def param_count(self) -> int:
        ...

    def loss(self, params: dict, inputs: Any, labels: jnp.ndarray,
             weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        return mach_loss(self.head_logits(params, inputs),
                         self.cfg.hash_labels(labels), weights)

    def meta_probs(self, params: dict, inputs: Any) -> jnp.ndarray:
        """getProbability of Algorithm 2: (R, ..., B)."""
        return mach_meta_probs(self.head_logits(params, inputs))

    def predict(self, params: dict, inputs: Any,
                estimator: Optional[str] = None,
                candidate_mode=None,
                inverted: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """argmax-class prediction (Algorithm 2).

        ``candidate_mode``: None | "exact" score all K classes; an
        (m, t) tuple routes through the count-min candidate filter —
        cost independent of K.  ``inverted`` is the table from
        ``cfg.inverted_table()`` (built here when omitted — pass it
        explicitly under jit, construction is host-side).
        """
        name = estimator or self.cfg.estimator
        meta = self.meta_probs(params, inputs)
        if candidate_mode is not None and candidate_mode != "exact":
            if inverted is None:
                inverted = self.cfg.inverted_table()
            _, idx = est.predict_topk(meta, self.cfg.table(), 1, name,
                                      candidate_mode=candidate_mode,
                                      inverted=inverted)
            return idx[..., 0]
        return est.predict_classes(meta, self.cfg.table(), name)

    def class_probs(self, params: dict, inputs: Any,
                    estimator: Optional[str] = None) -> jnp.ndarray:
        table = self.cfg.table()
        return est.estimate_class_probs(self.meta_probs(params, inputs),
                                        table,
                                        estimator or self.cfg.estimator)


# ---------------------------------------------------------------------------
# Paper-faithful model: R independent logistic regressions.
# ---------------------------------------------------------------------------

class MACHLinear(MACHHead):
    """R B-way logistic regressions on d features — the paper's §4 model.

    Parameters: W (d, R, B), b (R, B) — total d·R·B + R·B, i.e. the
    paper's BRd model size versus OAA's Kd.

    Inputs may be dense (n, d) arrays or CSR ``SparseBatch``es (the ODP
    bag-of-words regime).  With ``fused=True`` the training ``loss``
    routes through the fused logit-free kernels — dense or CSR entry
    point by input type, the bias a native in-kernel operand — so the
    (n, R·B) logits tensor (and for CSR the dense (n, d) activation)
    never materializes.  The per-repetition slice/merge API (paper
    §6.1 embarrassing parallelism) is unchanged.
    """

    def __init__(self, cfg: MACHConfig, dim: int, fused: bool = False):
        self.cfg = cfg
        self.dim = dim
        self.fused = fused

    def init(self, key: jax.Array) -> dict:
        wkey, _ = jax.random.split(key)
        scale = 1.0 / math.sqrt(self.dim)
        return {
            "w": jax.random.normal(wkey, (self.dim, self.cfg.num_repetitions,
                                          self.cfg.num_buckets), jnp.float32) * scale,
            "b": jnp.zeros((self.cfg.num_repetitions, self.cfg.num_buckets),
                           jnp.float32),
        }

    def head_logits(self, params: dict, x: Any) -> jnp.ndarray:
        """(n, d) dense or CSR SparseBatch -> (n, R, B)."""
        if is_sparse_batch(x):
            x = x.to_dense()          # materializing path only; fused stays sparse
        return jnp.einsum("nd,drb->nrb", x, params["w"]) + params["b"]

    # back-compat alias (pre-MACHHead name)
    def logits(self, params: dict, x: Any) -> jnp.ndarray:
        return self.head_logits(params, x)

    def loss(self, params: dict, x: Any, y: jnp.ndarray,
             weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Routes through the fused logit-free path when ``fused=True``
        (identical value/grads), else materializes the (n, R, B) logits."""
        if self.fused:
            return self.fused_loss(params, x, y, weights)
        return super().loss(params, x, y, weights)

    def fused_loss(self, params: dict, x: Any, y: jnp.ndarray,
                   weights: Optional[jnp.ndarray] = None,
                   bucket_select: Optional[tuple] = None,
                   bucket_proxy: Optional[jnp.ndarray] = None,
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
        """Logit-free loss via ``ops.mach_fused_xent`` (dense x) or
        ``ops.mach_fused_xent_csr`` (SparseBatch x).  The bias is a
        native kernel operand on both branches — no per-step
        (d+1, R·B) W-concat on the dense path and no ELL widening on
        the CSR path; dbias comes from the kernels' (1, bc) scratch
        reduction.  ``bucket_select``/``bucket_proxy`` as on
        ``MACHHead.fused_loss``."""
        from repro.kernels import ops  # deferred: kernels import core
        c = self.cfg
        hashed = jnp.moveaxis(c.hash_labels(y), 0, -1)       # (n, R)
        w2 = params["w"].reshape(self.dim, -1)               # (d, R·B)
        bias = params["b"].reshape(-1)                       # (R·B,)
        if is_sparse_batch(x):
            nll = ops.mach_fused_xent_csr(
                x.indptr, x.indices, x.values, w2, hashed,
                num_buckets=c.num_buckets, nnz_max=x.nnz_max, bias=bias,
                bucket_select=bucket_select, bucket_proxy=bucket_proxy,
                use_pallas=use_pallas, interpret=interpret)
        else:
            nll = ops.mach_fused_xent(
                x, w2, hashed, num_buckets=c.num_buckets, bias=bias,
                bucket_select=bucket_select, bucket_proxy=bucket_proxy,
                use_pallas=use_pallas, interpret=interpret)
        return _weighted_mean(nll, weights)

    def bucket_proxy_scores(self, params: dict, x: Any) -> jnp.ndarray:
        """(R, B) dynamic-bucket-selection proxy from a dense or CSR
        batch (the CSR mean is a scatter-add — never densified)."""
        from repro.kernels import ops  # deferred: kernels import core
        w2 = params["w"].reshape(self.dim, -1)
        bias = params["b"].reshape(-1)
        if is_sparse_batch(x):
            return ops.mach_bucket_proxy(
                w=w2, num_buckets=self.cfg.num_buckets, bias=bias,
                csr=(x.indptr, x.indices, x.values))
        return ops.mach_bucket_proxy(
            x, w2, num_buckets=self.cfg.num_buckets, bias=bias)

    def param_count(self) -> int:
        c = self.cfg
        return self.dim * c.num_repetitions * c.num_buckets \
            + c.num_repetitions * c.num_buckets

    # --- embarrassing parallelism (paper §6.1): per-repetition slices ---
    @staticmethod
    def slice_repetition(params: dict, j: int) -> dict:
        """Extract repetition j's independent model (train anywhere)."""
        return {"w": params["w"][:, j], "b": params["b"][j]}

    @staticmethod
    def merge_repetitions(slices: list[dict]) -> dict:
        """Inverse of slice_repetition — merge R separately-trained models."""
        return {
            "w": jnp.stack([s["w"] for s in slices], axis=1),
            "b": jnp.stack([s["b"] for s in slices], axis=0),
        }


# ---------------------------------------------------------------------------
# LM integration: MACH output head replacing the d×V softmax.
# ---------------------------------------------------------------------------

class MACHOutputHead(MACHHead):
    """Drop-in replacement for an LM's unembedding: d -> (R, B) logits.

    The kernel is stored as (d, R*B) so the forward pass is a single
    MXU-friendly matmul; logits are reshaped to (..., R, B) for the loss.
    Sharding: logical axes ("embed", "mach_rb") — the R·B axis shards
    over the model axis exactly like a vocab-sharded softmax, at
    V/(R·B)× less collective volume.
    """

    def __init__(self, cfg: MACHConfig, dim: int, dtype=jnp.float32):
        self.cfg = cfg
        self.dim = dim
        self.dtype = dtype

    @property
    def out_features(self) -> int:
        return self.cfg.num_repetitions * self.cfg.num_buckets

    def init(self, key: jax.Array) -> dict:
        scale = 1.0 / math.sqrt(self.dim)
        return {"kernel": (jax.random.normal(key, (self.dim, self.out_features),
                                             jnp.float32) * scale).astype(self.dtype)}

    def apply(self, params: dict, h: jnp.ndarray) -> jnp.ndarray:
        """(..., d) hidden states -> (..., R, B) logits."""
        out = h @ params["kernel"].astype(h.dtype)
        return out.reshape(out.shape[:-1] + (self.cfg.num_repetitions,
                                             self.cfg.num_buckets))

    def head_logits(self, params: dict, h: jnp.ndarray) -> jnp.ndarray:
        return self.apply(params, h)

    def fused_loss(self, params: dict, h: jnp.ndarray, labels: jnp.ndarray,
                   weights: Optional[jnp.ndarray] = None,
                   bucket_select: Optional[tuple] = None,
                   bucket_proxy: Optional[jnp.ndarray] = None,
                   use_pallas: Optional[bool] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
        """Logit-free counterpart of ``loss``: the projection is fused
        into the hashed cross-entropy (``ops.mach_fused_xent``), so the
        (…, R, B) logits tensor never exists — train-time activation
        memory is O(N·d), not O(N·R·B).  Same value and gradients as
        ``loss`` (the VJP accumulates dW and dh in-kernel).
        ``bucket_select``/``bucket_proxy`` as on ``MACHHead.fused_loss``."""
        from repro.kernels import ops  # deferred: kernels import core
        hashed = jnp.moveaxis(self.cfg.hash_labels(labels), 0, -1)
        nll = ops.mach_fused_xent(h, params["kernel"], hashed,
                                  num_buckets=self.cfg.num_buckets,
                                  bucket_select=bucket_select,
                                  bucket_proxy=bucket_proxy,
                                  use_pallas=use_pallas, interpret=interpret)
        return _weighted_mean(nll, weights)

    def bucket_proxy_scores(self, params: dict, h: jnp.ndarray) -> jnp.ndarray:
        """(R, B) dynamic-bucket-selection proxy from hidden states."""
        from repro.kernels import ops  # deferred: kernels import core
        return ops.mach_bucket_proxy(
            h, params["kernel"], num_buckets=self.cfg.num_buckets)

    def param_count(self) -> int:
        return self.dim * self.out_features

    def full_softmax_param_count(self) -> int:
        return self.dim * self.cfg.num_classes
