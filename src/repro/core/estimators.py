"""MACH probability estimators (paper Eq. 2, 7, 8).

Given the R meta-class probability vectors ``meta_probs`` with shape
(R, ..., B) and the hash table (R, K), each estimator recovers per-class
probability estimates of shape (..., K):

  unbiased  p̂_i = B/(B−1) · [ mean_j P^j_{h_j(i)} − 1/B ]      (Eq. 2)
  min       p̂_i = min_j    P^j_{h_j(i)}                        (Eq. 7, count-min)
  median    p̂_i = median_j P^j_{h_j(i)}                        (Eq. 8, count-median)

The gathered tensor (R, ..., K) is materialized here — this module is
the *reference* path (and the oracle for the Pallas decode kernel, which
never materializes it).  ``argmax`` under the unbiased estimator equals
``argmax`` of the plain sum (the affine map is monotone), which is what
the fused kernel computes.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

ESTIMATORS = ("unbiased", "min", "median")


def gather_class_probs(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """(R, ..., B), (R, K) -> (R, ..., K): P^j_{h_j(i)} for every class i."""
    if meta_probs.shape[0] != table.shape[0]:
        raise ValueError(
            f"R mismatch: meta_probs {meta_probs.shape} vs table {table.shape}")
    return jnp.take_along_axis(
        meta_probs,
        table.reshape(table.shape[:1] + (1,) * (meta_probs.ndim - 2) + table.shape[1:]),
        axis=-1,
    )


def unbiased_estimator(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 2 — unbiased estimate of Pr(y=i|x); shape (..., K)."""
    B = meta_probs.shape[-1]
    g = gather_class_probs(meta_probs, table)  # (R, ..., K)
    return (B / (B - 1.0)) * (jnp.mean(g, axis=0) - 1.0 / B)


def min_estimator(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 7 — count-min sketch estimate; shape (..., K)."""
    g = gather_class_probs(meta_probs, table)
    return jnp.min(g, axis=0)


def median_estimator(meta_probs: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 8 — count-median sketch estimate; shape (..., K)."""
    g = gather_class_probs(meta_probs, table)
    return jnp.median(g, axis=0)


_FNS = {
    "unbiased": unbiased_estimator,
    "min": min_estimator,
    "median": median_estimator,
}


def estimate_class_probs(meta_probs: jnp.ndarray, table: jnp.ndarray,
                         estimator: str = "unbiased") -> jnp.ndarray:
    """Dispatch over the three paper estimators."""
    try:
        fn = _FNS[estimator]
    except KeyError:
        raise ValueError(f"estimator must be one of {ESTIMATORS}, got {estimator!r}")
    return fn(meta_probs, table)


def predict_classes(meta_probs: jnp.ndarray, table: jnp.ndarray,
                    estimator: str = "unbiased") -> jnp.ndarray:
    """argmax_i p̂_i — the paper's classification rule; shape (...,)."""
    return jnp.argmax(estimate_class_probs(meta_probs, table, estimator), axis=-1)


def predict_topk(meta_probs: jnp.ndarray, table: jnp.ndarray, k: int,
                 estimator: str = "unbiased", *,
                 candidate_mode=None,
                 inverted: Optional[jnp.ndarray] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k (p̂ values, class ids) under the chosen estimator.

    meta_probs: (R, ..., B) — same layout as the other estimators here.
    Routes to the fused streaming kernel when available (TPU, or forced
    with ``use_pallas=True``), which never materializes the (..., K)
    score matrix; otherwise the blocked streaming fallback.  Returns
    ((..., k) f32, (..., k) int32).

    ``candidate_mode``: None | "exact" stream all K classes; an (m, t)
    tuple routes through the count-min candidate filter (requires
    ``inverted``, the (R·B, L) table from ``hashing.inverted_table``) —
    cost independent of K, top-k approximate (see ops.mach_topk).
    """
    from repro.kernels import ops  # deferred: kernels sit above core
    return ops.mach_topk(jnp.moveaxis(meta_probs, 0, -2), table,
                         num_classes=table.shape[-1], k=k,
                         estimator=estimator, candidate_mode=candidate_mode,
                         inverted=inverted, use_pallas=use_pallas,
                         interpret=interpret)
