"""MACH core: the paper's contribution as composable JAX modules."""

from repro.core.hashing import (
    CarterWegmanFamily,
    MultShiftFamily,
    indistinguishable_pair_bound,
    make_hash_family,
    memory_reduction,
    r_required,
)
from repro.core.estimators import (
    ESTIMATORS,
    estimate_class_probs,
    gather_class_probs,
    median_estimator,
    min_estimator,
    predict_classes,
    predict_topk,
    unbiased_estimator,
)
from repro.core.mach import (
    MACHConfig,
    MACHHead,
    MACHLinear,
    MACHOutputHead,
    is_sparse_batch,
    mach_loss,
    mach_meta_probs,
)
from repro.core.oaa import OAAClassifier

__all__ = [
    "CarterWegmanFamily", "MultShiftFamily", "make_hash_family",
    "r_required", "indistinguishable_pair_bound", "memory_reduction",
    "ESTIMATORS", "estimate_class_probs", "gather_class_probs",
    "unbiased_estimator", "min_estimator", "median_estimator",
    "predict_classes", "predict_topk",
    "MACHConfig", "MACHHead", "MACHLinear", "MACHOutputHead",
    "is_sparse_batch", "mach_loss", "mach_meta_probs", "OAAClassifier",
]
