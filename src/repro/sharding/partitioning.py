"""Logical-axis partitioning: model code names axes, this module maps
them onto the mesh.

Rules are *candidate lists*; resolution checks (a) divisibility of the
tensor dim by the mesh-axes product and (b) that no mesh axis is used
twice within one PartitionSpec, falling back to replication for that
dim.  This is what lets one rule set cover heads=96 (mistral: 16-way TP)
and heads=10 (recurrentgemma: replicated heads, FSDP on d_model) without
per-arch sharding code.

Parallelism modes expressed purely through rules:
  DP    batch -> ('pod', 'data')
  TP    mlp/heads/vocab/mach_rb/experts -> 'model'
  FSDP  embed (the d_model dim of weights) -> 'data'   [fsdp=True]
  SP    seq -> 'model'                                 [sp=True, prefill]
  EP    experts -> 'model' when E divisible (else expert-TP via mlp)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    fsdp: bool = True
    sp: bool = False
    mach_pod_parallel: bool = False   # MACH R-heads sharded over 'pod'

    def table(self, mesh: Mesh) -> dict:
        has_pod = "pod" in mesh.axis_names
        batch = ("pod", "data") if has_pod else ("data",)
        rules = {
            "batch": [batch, ("data",), None],
            "seq": [("model",), None] if self.sp else [None],
            "embed": [("data",), None] if self.fsdp else [None],
            "mlp": [("model",), None],
            "heads": [("model",), None],
            "kv_heads": [("model",), None],
            "qkv": [None],
            "vocab": [("model",), None],
            "experts": [("model",), None],
            "layers": [None],
            None: [None],
        }
        if self.mach_pod_parallel and has_pod:
            # R·B dim split over (pod, model): pods own disjoint subsets
            # of the R repetitions — the paper's embarrassing parallelism
            rules["mach_rb"] = [("pod", "model"), ("model",), None]
        else:
            rules["mach_rb"] = [("model",), None]
        return rules


# ---------------------------------------------------------------------------
# Activation sharding constraints (SP / residual-stream sharding).
# Model code calls ``constrain(x, ("batch", "seq", None))`` with *logical*
# names; outside an ``activate(mesh, rules)`` context it is a no-op, so
# models stay mesh-agnostic.
# ---------------------------------------------------------------------------

_ACTIVE: list = []


class activate:
    def __init__(self, mesh: Mesh, rules_cfg: "ShardingRules"):
        self.entry = (mesh, rules_cfg.table(mesh))

    def __enter__(self):
        _ACTIVE.append(self.entry)
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def constrain(x: jnp.ndarray, logical_axes) -> jnp.ndarray:
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = resolve_spec(mesh, rules, logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_spec(mesh: Mesh, rules: dict, logical_axes, shape) -> P:
    """(logical axis names per dim, shape) -> PartitionSpec."""
    used: set = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        choice = None
        for cand in rules.get(name, [None]):
            if cand is None:
                break
            if any(a in used for a in cand):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            choice = tuple(cand) if len(cand) > 1 else cand[0]
            used.update(cand)
            break
        out.append(choice)
    # trim trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def eval_shape_with_axes(init_fn, key):
    """eval_shape an ``init(key) -> (params, axes)`` function: the axes
    pytree (tuples of strings, not JAX types) is captured via closure."""
    box = {}

    def only_params(k):
        p, a = init_fn(k)
        box["axes"] = a
        return p

    params_shapes = jax.eval_shape(only_params, key)
    return params_shapes, box["axes"]


def params_shardings(mesh: Mesh, rules_cfg: ShardingRules, axes_tree,
                     shapes_tree) -> Any:
    """axes_tree: pytree of tuples (logical names); shapes_tree: matching
    pytree of jax.ShapeDtypeStruct (from eval_shape) or arrays."""
    rules = rules_cfg.table(mesh)

    def per_leaf(ax, shp):
        return NamedSharding(mesh, resolve_spec(mesh, rules, ax, shp.shape))

    return jax.tree.map(per_leaf, axes_tree, shapes_tree,
                        is_leaf=lambda v: isinstance(v, tuple))


def batch_shardings(mesh: Mesh, rules_cfg: ShardingRules, batch_tree) -> Any:
    """Shard every batch leaf's dim-0 as 'batch' (with divisibility
    fallback); optionally dim-1 as 'seq' when sp=True."""
    rules = rules_cfg.table(mesh)

    def per_leaf(x):
        logical = ["batch"] + (["seq"] if rules_cfg.sp and x.ndim > 1 else
                               [None] * max(0, x.ndim - 1))
        logical += [None] * (x.ndim - len(logical))
        return NamedSharding(mesh, resolve_spec(mesh, rules, logical, x.shape))

    return jax.tree.map(per_leaf, batch_tree)


def state_shardings(mesh: Mesh, rules_cfg: ShardingRules, model, opt,
                    sample_key=None) -> tuple[Any, Any, Any]:
    """Build (state_shapes, state_shardings, params_axes) for a
    TrainState without allocating anything (eval_shape)."""
    from repro.train.train_state import new_train_state

    key = sample_key if sample_key is not None else jax.random.key(0)
    params_shapes, axes = eval_shape_with_axes(model.init, key)

    state_shapes = jax.eval_shape(
        lambda p: new_train_state(p, opt),
        params_shapes)
    p_shard = params_shardings(mesh, rules_cfg, axes, params_shapes)
    rep = NamedSharding(mesh, P())

    # Optimizer moments inherit the parameter sharding.  Every optimizer
    # state here embeds (possibly several) copies of the params tree
    # under some prefix (mu/nu, momentum, master weights), so a moment
    # leaf is matched to its parameter by *tree path*: the longest
    # parameter path that is a suffix of the moment's path, with the
    # shape required to agree (Adafactor's factored vr/vc share the
    # path but not the shape).  Keying by shape alone would silently
    # give two same-shaped, differently-sharded params the first one's
    # sharding.  Parameter paths are indexed by their full component
    # tuple, so each moment leaf probes its own suffixes longest-first —
    # O(depth) dict lookups per leaf, O(params + opt_leaves·depth)
    # total, instead of the old O(params × opt_leaves) scan.  Colliding
    # suffixes (two params whose paths end identically, e.g. every
    # layer's "w") live under *different* full-path keys, so only the
    # exact longest match wins; same-key entries (shouldn't happen for
    # distinct params) fall back to shape agreement.  Anything unmatched
    # (step counts, factored moments) replicates.
    p_paths = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    flat_ps = jax.tree.leaves(p_shard)
    suffix_index: dict = {}
    for (path, leaf), sh in zip(p_paths, flat_ps):
        comps = tuple(str(k) for k in path)
        suffix_index.setdefault(comps, []).append((tuple(leaf.shape), sh))

    opt_paths, opt_tdef = jax.tree_util.tree_flatten_with_path(
        state_shapes.opt_state)

    def moment_sharding(path, leaf):
        comps = tuple(str(k) for k in path)
        shape = tuple(leaf.shape)
        # longest suffix first; the final probe is the empty path (a
        # bare-leaf params tree), preserving the old endswith("") case
        for start in range(len(comps) + 1):
            for pshape, sh in suffix_index.get(comps[start:], ()):
                if pshape == shape:
                    return sh
        return rep

    opt_shard = opt_tdef.unflatten(
        [moment_sharding(path, leaf) for path, leaf in opt_paths])
    state_shard = type(state_shapes)(step=rep, params=p_shard,
                                     opt_state=opt_shard)
    return state_shapes, state_shard, axes
