from repro.sharding.partitioning import (ShardingRules, activate,
                                         batch_shardings, constrain,
                                         params_shardings, resolve_spec,
                                         state_shardings)

__all__ = ["ShardingRules", "activate", "batch_shardings", "constrain",
           "params_shardings", "resolve_spec", "state_shardings"]
