"""Checkpointing: atomic, keep-N, numpy-backed, elastic-restore.

Layout:
    <dir>/step_000000123/
        manifest.json            # leaf paths, shapes, dtypes, step
        arrays.npz               # one entry per leaf (flattened key paths)
    <dir>/LATEST                 # text file: last durable step

Guarantees:
* **Atomicity** — writes go to ``step_N.tmp`` and are renamed only after
  fsync; a crash mid-save never corrupts the latest checkpoint (the
  restart test kills training mid-run and resumes bit-exact).
* **Keep-N** — older checkpoints garbage-collected after a durable save.
* **Elastic restore** — arrays are saved *unsharded* (gathered); restore
  takes an optional ``sharding`` pytree and device_puts each leaf to the
  *new* mesh, so a job restarted on a different topology resumes
  seamlessly (mesh-shape metadata is advisory, not binding).
* **Async** — ``save(..., blocking=False)`` runs serialization on a
  background thread; ``wait()`` joins before the next save (so at most
  one in flight).  A failure on the background thread is captured and
  re-raised by the next ``wait()`` / ``save()`` / ``restore()`` — a
  failed save is never silently reported durable.  Stale ``step_*.tmp``
  directories left by crashed writers are swept on every GC.
* **Retry** — each save attempt is wrapped in a bounded retry with
  exponential backoff (``save_retries`` attempts, ``retry_backoff·2^k``
  sleeps): at pod scale, transient FS errors (NFS hiccups, GCS-fuse
  timeouts) shouldn't kill the run at the next ``wait()``.  Attempts
  are whole-write idempotent (the ``.tmp`` dir is recreated each try);
  only ``OSError`` retries, and the final failure re-raises.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


class CheckpointManager:

    def __init__(self, directory: str, keep: int = 3,
                 save_retries: int = 3, retry_backoff: float = 0.1):
        self.directory = directory
        self.keep = keep
        self.save_retries = max(1, save_retries)
        self.retry_backoff = retry_backoff
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        self.wait()
        # materialize on host *before* handing to the thread so device
        # buffers can't be donated/overwritten underneath it
        leaves, paths, _ = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

        def _write():
            # phase 1 (retryable as a whole): write the step dir; the
            # rename at the end is the durability point
            name = f"step_{step:012d}"
            final = os.path.join(self.directory, name)
            if os.path.exists(final):        # idempotent re-save of a step
                shutil.rmtree(final)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            arrays = {f"leaf_{i}": arr for i, arr in enumerate(host_leaves)}
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "paths": paths,
                "shapes": [list(a.shape) for a in host_leaves],
                "dtypes": [str(a.dtype) for a in host_leaves],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, final)

        def _publish():
            # phase 2 (retryable on its own): LATEST pointer + GC.  The
            # step dir is already durable — a failure here must never
            # re-enter _write, whose first act would rmtree it.
            # (latest_step() falls back to a directory scan, so a stale
            # LATEST is recoverable; the re-raise still surfaces it.)
            with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
                f.write(str(step))
                f.flush()
                os.fsync(f.fileno())
            os.rename(os.path.join(self.directory, "LATEST.tmp"),
                      os.path.join(self.directory, "LATEST"))
            self._gc()

        def _retry(fn):
            # each _write attempt recreates the .tmp dir from scratch,
            # so a half-written attempt never leaks into the next one
            for attempt in range(self.save_retries):
                try:
                    return fn()
                except OSError:
                    if attempt == self.save_retries - 1:
                        raise
                    time.sleep(self.retry_backoff * (2 ** attempt))

        def _write_with_retry():
            _retry(_write)
            _retry(_publish)

        if blocking:
            _write_with_retry()
        else:
            def _guarded():
                try:
                    _write_with_retry()
                except BaseException as e:  # noqa: BLE001 — re-raised on wait()
                    self._exc = e

            self._thread = threading.Thread(target=_guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join any in-flight async save; re-raise its failure if it had
        one (so a failed save cannot be mistaken for a durable one)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _gc(self) -> None:
        # sweep stale .tmp dirs first (crashed writers); the in-flight
        # save's tmp has already been renamed by the time _gc runs
        for d in os.listdir(self.directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                    out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        # prefer the durable LATEST pointer; fall back to directory scan
        p = os.path.join(self.directory, "LATEST")
        if os.path.exists(p):
            with open(p) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.directory, f"step_{s:012d}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> tuple[Any, int]:
        """Restore into ``template``'s structure.  ``shardings`` (same
        structure or a single sharding) re-shards onto the current mesh —
        the elastic-restart path."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
        _, tdef = jax.tree.flatten(template)
        tmpl_leaves = jax.tree.leaves(template)
        if len(tmpl_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, template has "
                f"{len(tmpl_leaves)} — structure changed?")
        if shardings is not None:
            shard_leaves = (jax.tree.leaves(shardings)
                            if not _is_single_sharding(shardings)
                            else [shardings] * len(leaves))
            leaves = [jax.device_put(l, s)
                      for l, s in zip(leaves, shard_leaves)]
        else:
            leaves = [jax.numpy.asarray(l) for l in leaves]
        # preserve template dtypes (e.g. bf16 params round-tripped via f32)
        leaves = [l.astype(t.dtype) if hasattr(t, "dtype") and l.dtype != t.dtype
                  else l for l, t in zip(leaves, tmpl_leaves)]
        return tdef.unflatten(leaves), step


def _is_single_sharding(s: Any) -> bool:
    return isinstance(s, jax.sharding.Sharding)
