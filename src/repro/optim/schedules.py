"""Learning-rate schedules (pure functions of the step count)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  end_value: float = 0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * jnp.minimum(1.0, (s + 1) / max(1, warmup_steps))
        frac = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
        cos = end_value + 0.5 * (peak - end_value) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn


def warmup_rsqrt(peak: float, warmup_steps: int):
    """Transformer-style inverse-sqrt decay."""
    def fn(step):
        s = step.astype(jnp.float32) + 1
        w = max(1, warmup_steps)
        return peak * jnp.minimum(s / w, jnp.sqrt(w / s))
    return fn


def make_schedule(name: str, **kw):
    return {"constant": constant, "linear_warmup": linear_warmup,
            "warmup_cosine": warmup_cosine, "warmup_rsqrt": warmup_rsqrt}[name](**kw)
