from repro.optim.optimizers import (Optimizer, adafactor, adam, adamw,
                                    apply_updates, make_optimizer, sgd)
from repro.optim.schedules import (constant, linear_warmup, make_schedule,
                                   warmup_cosine, warmup_rsqrt)
from repro.optim.grad import (accumulate_grads, clip_by_global_norm,
                              dequantize_8bit, global_norm,
                              init_error_feedback, quantize_8bit,
                              topk_compress)

__all__ = [
    "Optimizer", "sgd", "adam", "adamw", "adafactor", "apply_updates",
    "make_optimizer", "constant", "linear_warmup", "warmup_cosine",
    "warmup_rsqrt", "make_schedule", "accumulate_grads",
    "clip_by_global_norm", "global_norm", "init_error_feedback",
    "topk_compress", "quantize_8bit", "dequantize_8bit",
]
