"""Gradient utilities: clipping, accumulation, compression.

Gradient compression implements the distributed-optimization tricks for
slow cross-pod (DCN) links:

* ``topk_compress`` / ``topk_decompress`` — per-leaf magnitude top-k
  sparsification with **error feedback** (the residual is carried and
  added to the next step's gradient, preserving convergence — Stich et
  al. 2018).
* ``quantize_8bit`` / ``dequantize_8bit`` — per-leaf absmax int8
  quantization (4× wire reduction vs f32, 2× vs bf16).

These act on gradient pytrees *before* the cross-pod all-reduce; the
within-pod reduce-scatter stays full-precision (ICI is not the
bottleneck — see EXPERIMENTS.md §Roofline collective terms).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Accumulation (microbatching)
# ---------------------------------------------------------------------------

def accumulate_grads(loss_fn, params, batch, num_microbatches: int):
    """Split the batch's leading dim into microbatches; lax.scan the
    grad computation and average.  Returns ((loss, metrics), grads)."""
    if num_microbatches <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def reshape(x):
        b = x.shape[0]
        assert b % num_microbatches == 0, (b, num_microbatches)
        return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        g_acc, loss_acc, metr_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
        metr_acc = (metrics if metr_acc is None
                    else jax.tree.map(lambda a, b_: a + b_, metr_acc, metrics))
        return (g_acc, loss_acc + loss, metr_acc), None

    # first microbatch outside scan to seed metric structure
    (loss0, metr0), g0 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, jax.tree.map(lambda x: x[0], micro))
    g0 = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), zero_g, g0)
    rest = jax.tree.map(lambda x: x[1:], micro)
    (g, loss, metr), _ = jax.lax.scan(body, (g0, loss0, metr0), rest)
    n = float(num_microbatches)
    g = jax.tree.map(lambda x: x / n, g)
    metr = jax.tree.map(lambda x: x / n, metr)
    return (loss / n, metr), g


# ---------------------------------------------------------------------------
# Top-k sparsification with error feedback
# ---------------------------------------------------------------------------

class ErrorFeedbackState(NamedTuple):
    residual: Any


def init_error_feedback(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def topk_compress(grads: Any, ef: ErrorFeedbackState, fraction: float = 0.01
                  ) -> tuple[Any, ErrorFeedbackState]:
    """Keep the top-|fraction| entries (by magnitude) of each leaf;
    accumulate the rest into the error-feedback residual."""

    def per_leaf(g, r):
        g = g.astype(jnp.float32) + r
        flat = g.reshape(-1)
        k = max(1, int(flat.size * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = jnp.abs(g) >= thresh
        kept = jnp.where(mask, g, 0.0)
        return kept, g - kept

    flat, tdef = jax.tree.flatten(grads)
    res = tdef.flatten_up_to(ef.residual)
    outs = [per_leaf(g, r) for g, r in zip(flat, res)]
    return (tdef.unflatten([o[0] for o in outs]),
            ErrorFeedbackState(tdef.unflatten([o[1] for o in outs])))


# ---------------------------------------------------------------------------
# 8-bit absmax quantization
# ---------------------------------------------------------------------------

class Quantized(NamedTuple):
    q: Any        # int8 payloads
    scale: Any    # f32 per-leaf absmax scales


def quantize_8bit(grads: Any) -> Quantized:
    def per_leaf(g):
        g = g.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        return jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8), s
    flat, tdef = jax.tree.flatten(grads)
    outs = [per_leaf(g) for g in flat]
    return Quantized(tdef.unflatten([o[0] for o in outs]),
                     tdef.unflatten([o[1] for o in outs]))


def dequantize_8bit(qt: Quantized) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qt.q, qt.scale)
