"""Optimizers, from scratch (no optax in this environment).

API mirrors the (init_fn, update_fn) gradient-transformation style:

    opt = adamw(lr_schedule, weight_decay=0.1)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees (checkpointable, shardable: moments inherit the
parameter's logical axes — see sharding/partitioning.py).
Implemented: sgd (+momentum), adam, adamw, adafactor (factored second
moment — the memory-frugal choice for 100B+ models).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


def _lr(lr: ScalarOrSchedule, count: jnp.ndarray) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


# ---------------------------------------------------------------------------
# SGD (+ momentum)
# ---------------------------------------------------------------------------

class SGDState(NamedTuple):
    count: jnp.ndarray
    momentum: Any


def sgd(lr: ScalarOrSchedule, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        mom = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
               if momentum else None)
        return SGDState(jnp.zeros((), jnp.int32), mom)

    def update(grads, state, params=None):
        step_lr = _lr(lr, state.count)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -(step_lr * (momentum * m + g)), mom, grads)
            else:
                upd = jax.tree.map(lambda m: -step_lr * m, mom)
        else:
            mom = None
            upd = jax.tree.map(lambda g: -step_lr * g, grads)
        return upd, SGDState(state.count + 1, mom)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adamw(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          mask: Optional[Callable[[Any], Any]] = None) -> Optimizer:
    """AdamW with decoupled weight decay.

    mask(params) -> pytree of bools selecting decayed leaves (default:
    decay everything with ndim >= 2, i.e. skip norms/biases).
    """
    def default_mask(params):
        return jax.tree.map(lambda p: p.ndim >= 2, params)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(grads, state, params):
        count = state.count + 1
        step_lr = _lr(lr, state.count)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        decay_mask = (mask or default_mask)(params)
        def upd(m, v, p, dm):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * jnp.where(dm, p.astype(jnp.float32), 0.0)
            return -step_lr * u
        updates = jax.tree.map(upd, mu, nu, params, decay_mask)
        return updates, AdamState(count, mu, nu)

    return Optimizer(init, update)


def adam(lr: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    count: jnp.ndarray
    vr: Any      # row factors (or full v for <2D leaves)
    vc: Any      # col factors (None for <2D leaves)


def adafactor(lr: ScalarOrSchedule, eps: float = 1e-30,
              clip_threshold: float = 1.0, decay_rate: float = 0.8
              ) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018), no first moment; second moment
    factored over the last two dims of ≥2-D leaves — O(n+m) not O(nm)
    optimizer memory, the standard choice at 100 B+ parameters."""

    def init(params):
        def per_leaf_r(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)
        def per_leaf_c(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)
        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(per_leaf_r, params),
                              jax.tree.map(per_leaf_c, params))

    def update(grads, state, params=None):
        count = state.count + 1
        beta = 1.0 - count.astype(jnp.float32) ** (-decay_rate)
        step_lr = _lr(lr, state.count)

        def upd(g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if g.ndim >= 2:
                nvr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                nvc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = nvr / jnp.maximum(
                    jnp.mean(nvr, axis=-1, keepdims=True), eps)
                v = r[..., None] * nvc[..., None, :]
            else:
                nvr = beta * vr + (1 - beta) * g2
                nvc = vc
                v = nvr
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -step_lr * u, nvr, nvc

        flat_g, tdef = jax.tree.flatten(grads)
        flat_vr = tdef.flatten_up_to(state.vr)
        flat_vc = tdef.flatten_up_to(state.vc)
        out = [upd(g, vr, vc) for g, vr, vc in zip(flat_g, flat_vr, flat_vc)]
        updates = tdef.unflatten([o[0] for o in out])
        nvr = tdef.unflatten([o[1] for o in out])
        nvc = tdef.unflatten([o[2] for o in out])
        return updates, AdafactorState(count, nvr, nvc)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Mixed precision: f32 master weights for bf16 params
# ---------------------------------------------------------------------------

class MasterState(NamedTuple):
    master: Any      # f32 copies of the (bf16) params
    inner: Any


def with_master_weights(opt: Optimizer) -> Optimizer:
    """Keep f32 master copies in optimizer state; model params stay bf16
    (halving FSDP all-gather volume and keeping the backward pass free of
    f32 activation copies).  Updates are computed on the masters, then
    re-quantized — tiny updates are never swallowed by bf16 rounding."""

    def init(params):
        master = jax.tree.map(
            lambda p: p.astype(jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        return MasterState(master, opt.init(master))

    def update(grads, state, params):
        g32 = jax.tree.map(
            lambda g: g.astype(jnp.float32)
            if jnp.issubdtype(g.dtype, jnp.floating) else g, grads)
        upd, inner = opt.update(g32, state.inner, state.master)
        new_master = apply_updates(state.master, upd)
        # delta in the *param* dtype: params == cast(old master), so this
        # applies exactly the representable part of the master update
        deltas = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype) - p, new_master, params)
        return deltas, MasterState(new_master, inner)

    return Optimizer(init, update)


def make_optimizer(name: str, lr: ScalarOrSchedule, *,
                   master_weights: bool = False, **kw) -> Optimizer:
    opt = {"sgd": sgd, "adam": adam, "adamw": adamw,
           "adafactor": adafactor}[name](lr, **kw)
    return with_master_weights(opt) if master_weights else opt
